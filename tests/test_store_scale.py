"""10k-scale coordination-plane stress cell (PR 7 acceptance).

Marked ``stress``: the nightly stress job runs these alongside bench-full;
they also ride the tier-1 suite (a few seconds) so the scale contract can't
rot between nightlies.
"""

import threading

import pytest

from benchmarks.bench_scale import coordination_cell
from repro.core.coordination import CoordinationStore


@pytest.mark.stress
def test_10k_cus_100_pilots_per_event_cost_flat():
    """Per-event store cost at 10k CUs / 100 pilots stays flat vs the 1k
    cell — the sharded plane's prefix-indexed subscriptions, striped
    locks, and bisect scans hold per-op cost constant as the workload and
    the subscriber table scale 10×.  (The CI-gated bench claim uses ±20%;
    the test allows ±35% to stay robust on loaded shared runners.)"""
    small = coordination_cell(1_000, 10)
    large = coordination_cell(10_000, 100)
    ratio = large["per_event_us"] / small["per_event_us"]
    assert 0.65 <= ratio <= 1.35, (
        f"per-event cost not flat: 1k={small['per_event_us']:.2f}us "
        f"10k={large['per_event_us']:.2f}us ratio={ratio:.2f}"
    )


@pytest.mark.stress
def test_100_pilot_queues_with_racing_producers_and_consumers():
    """100 per-pilot queues, 8 producer threads, 100 consumer drains:
    exactly-once delivery across stripes under real contention."""
    store = CoordinationStore()
    n_pilots, n_producers, per_producer = 100, 8, 500
    barrier = threading.Barrier(n_producers)

    def producer(tid: int) -> None:
        barrier.wait()
        for i in range(per_producer):
            store.push(f"queue:pilot:p{(tid * per_producer + i) % n_pilots}", (tid, i))

    threads = [
        threading.Thread(target=producer, args=(t,)) for t in range(n_producers)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    seen = set()
    for p in range(n_pilots):
        while True:
            item = store.pop(f"queue:pilot:p{p}")
            if item is None:
                break
            assert item not in seen, f"duplicate delivery: {item}"
            seen.add(item)
    assert len(seen) == n_producers * per_producer
    store.close()
