"""Self-healing data layer: failure-domain-aware recovery, replication-
factor enforcement, lineage recomputation, SUSPECT grace periods, and the
orphan-requeue regression fixes."""

import time

import pytest

from repro.core import (
    ComputeUnit,
    ComputeUnitDescription,
    CUState,
    ComputeFailedError,
    DataUnit,
    DataUnitDescription,
    DUState,
    FaultManager,
    FUNCTIONS,
    HeartbeatMonitor,
    PilotManager,
    PilotState,
    RuntimeContext,
    Session,
    StragglerMitigator,
    Topology,
    CoordinationStore,
    make_tpu_fleet_topology,
    requeue_orphans,
)
from repro.core.pilot import HEARTBEATS_KEY


MB = 1_000_000


@pytest.fixture()
def topo():
    t, _ = make_tpu_fleet_topology(pods=3, hosts_per_pod=2)
    return t


def _wait_until(pred, timeout=10.0, interval=0.005):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return pred()


# --------------------------------------------------------------- kill-pilot
def test_kill_pilot_mid_running_recovers_elsewhere(topo):
    with Session(
        topology=topo, enable_fault_manager=True, heartbeat_timeout_s=0.3
    ) as s:
        def slow(cu_ctx):
            time.sleep(0.6)
            return "survived"

        FUNCTIONS.register("ft-slow-run", slow)
        p0 = s.start_pilot(resource_url="sim://cluster:pod0:host0")
        p1 = s.start_pilot(resource_url="sim://cluster:pod1:host0")
        p0.wait_active(), p1.wait_active()
        cu = s.submit_cu(executable="ft-slow-run", pilot=p1, max_retries=3)
        assert _wait_until(lambda: cu.state == CUState.RUNNING, timeout=5)
        p1.fail()  # crash mid-RUNNING: heartbeats stop, store untouched
        assert cu.result(timeout=30) == "survived"
        assert cu.pilot_id == p0.id
        assert p1.id in s.heartbeat_monitor.failures
        # the FaultManager processed the failure (purge + requeue audit)
        assert _wait_until(
            lambda: any(e["pilot"] == p1.id for e in s.fault_manager.log),
            timeout=5,
        )


def test_kill_pilot_mid_staging_recovers_elsewhere():
    topo = Topology()
    topo.register("wan:sitea", bandwidth=0.5 * MB, latency=0.05)
    topo.register("wan:siteb", bandwidth=0.5 * MB, latency=0.05)
    with Session(
        topology=topo,
        enable_fault_manager=True,
        heartbeat_timeout_s=0.3,
        time_scale=0.2,
    ) as s:
        def read_all(cu_ctx):
            du = cu_ctx.input_dus()[0]
            return sum(
                len(cu_ctx.read_input(du.id, r)) for r in du.manifest
            )

        FUNCTIONS.register("ft-read-all", read_all)
        pd = s.start_pilot_data(
            service_url="sharedfs://wan:sitea/scratch", affinity="wan:sitea"
        )
        pa = s.start_pilot(resource_url="sim://wan:sitea")
        pb = s.start_pilot(resource_url="sim://wan:siteb")
        pa.wait_active(), pb.wait_active()
        du = s.submit_du(
            name="big", files={"d": b"x" * MB}, target=pd
        ).result()
        # pinned to siteb: staging must cross the 0.5 MB/s WAN link
        # (~2 sim-s -> ~0.4 wall-s at time_scale), so the kill lands
        # mid-STAGING
        cu = s.submit_cu(
            executable="ft-read-all", input_data=[du], pilot=pb,
            max_retries=3,
        )
        assert _wait_until(lambda: cu.state == CUState.STAGING, timeout=5)
        pb.fail()
        assert cu.result(timeout=30) == MB
        assert cu.pilot_id == pa.id
        # the dead sandbox was purged from the DU's replica bookkeeping
        assert pb.sandbox.id not in du.locations
        assert pb.sandbox.id not in du.chunk_holders()


# ------------------------------------------------------- stale-replica purge
def test_purge_invalidates_transfer_cache_and_placement(topo):
    with Session(
        topology=topo, enable_fault_manager=True, heartbeat_timeout_s=0.3
    ) as s:
        def read_one(cu_ctx):
            du = cu_ctx.input_dus()[0]
            return len(cu_ctx.read_input(du.id, "a"))

        FUNCTIONS.register("ft-read-one", read_one)
        p0 = s.start_pilot(resource_url="sim://cluster:pod0:host0")
        p1 = s.start_pilot(resource_url="sim://cluster:pod1:host0")
        p0.wait_active(), p1.wait_active()
        du_f = s.submit_du(name="d", files={"a": b"z" * 65536})
        du = du_f.du
        cu = s.submit_cu(executable="ft-read-one", input_data=[du_f], pilot=p1)
        assert cu.result(timeout=20) == 65536
        assert du.locations == [p1.sandbox.id]
        ts = s.transfer
        # prime the replica-resolution cache with the (soon dead) holder
        pd, _ = ts.resolve_access(du, p0.affinity)
        assert pd.id == p1.sandbox.id
        cached_cost = ts.estimate_stage_cost(du, p0.affinity, p0.sandbox)
        assert cached_cost > 0.0
        p1.fail()
        assert _wait_until(
            lambda: any(e["pilot"] == p1.id for e in s.fault_manager.log),
            timeout=5,
        )
        assert ts.is_dead(p1.sandbox.id)
        # holdings purged -> placement/locality no longer sees the dead PD
        assert p1.sandbox.id not in du.locations
        assert p1.sandbox.id not in du.chunk_holders()
        # the cached resolution must not serve the dead PD again; the
        # buffer-backed DU was re-replicated onto a live PD by recovery
        assert _wait_until(lambda: len(du.locations) >= 1, timeout=5)
        pd2, _ = ts.resolve_access(du, p0.affinity)
        assert pd2 is not None and pd2.id != p1.sandbox.id


# ------------------------------------------------- replication-factor healing
def test_replication_factor_healing_from_partial_sources(topo):
    with PilotManager(topology=topo) as mgr:
        p2 = mgr.start_pilot(resource_url="sim://cluster:pod2:host0")
        p2.wait_active()
        pd_a = mgr.start_pilot_data(
            service_url="sharedfs://cluster:pod0/a", affinity="cluster:pod0"
        )
        pd_b = mgr.start_pilot_data(
            service_url="sharedfs://cluster:pod1/b", affinity="cluster:pod1"
        )
        desc = DataUnitDescription(
            name="r2",
            files={"blob": b"r" * 8192},
            chunk_size=1024,
            replication_factor=2,
        )
        du = mgr.cds.submit_data_unit(desc, target=p2.sandbox)
        assert du.wait() == DUState.READY and du.n_chunks == 8
        # partial replicas: each explicit PD holds half the chunks
        pd_a.copy_chunks_from(du, p2.sandbox, [0, 1, 2, 3])
        pd_b.copy_chunks_from(du, p2.sandbox, [4, 5, 6, 7])
        du.drop_local_buffer()  # healing must come from chunk holders
        assert du.locations == [p2.sandbox.id]

        fm = FaultManager(mgr.ctx, cds=mgr.cds)
        try:
            mgr.store.hset(f"pilot:{p2.id}", "state", PilotState.FAILED)
            fm._handle_failure(p2.id)
            # sole full replica died; the two partial holders still cover
            # every chunk -> chunk-striped healing rebuilds full replicas
            # (failure-domain-aware: one per surviving site)
            assert p2.sandbox.id not in du.locations
            assert _wait_until(
                lambda: {pd_a.id, pd_b.id} <= set(du.locations), timeout=10
            )
            assert pd_a.verify_du(du) and pd_b.verify_du(du)
            heals = [
                r for r in mgr.transfer.records()
                if r.du_id == du.id and r.chunks
                and r.src_pd in (pd_a.id, pd_b.id)
            ]
            assert heals, "healing must fetch from the partial holders"
            # chunk-level: each heal moved only the 4 missing chunks, not
            # a whole-DU copy
            assert {r.chunks for r in heals} == {4}
            actions = fm.log[-1]["actions"]
            assert actions[du.id] == "healed"
        finally:
            fm.stop()


def test_replication_factor_enforced_at_submission(topo):
    """factor=2 at submission: the ReplicaManager proactively creates the
    second replica in a different failure domain."""
    with Session(topology=topo, enable_fault_manager=True) as s:
        pd_a = s.start_pilot_data(
            service_url="sharedfs://cluster:pod0/a", affinity="cluster:pod0"
        )
        pd_b = s.start_pilot_data(
            service_url="sharedfs://cluster:pod1/b", affinity="cluster:pod1"
        )
        du_f = s.submit_du(
            name="r2", files={"x": b"q" * 4096}, replication_factor=2
        )
        assert du_f.wait() == DUState.READY
        du = du_f.du
        assert _wait_until(lambda: len(du.locations) >= 2, timeout=10)
        # failure-domain-aware: one replica per site, not two in one domain
        assert set(du.locations) == {pd_a.id, pd_b.id}
        assert s.fault_manager.replicas.heals


# ------------------------------------------------------ lineage recomputation
def test_lineage_recomputation_two_stage_dag(topo):
    with Session(
        topology=topo, enable_fault_manager=True, heartbeat_timeout_s=0.3
    ) as s:
        runs = []

        def produce(cu_ctx):
            runs.append(1)
            time.sleep(0.3)  # keep the RECOVERING window observable
            du = cu_ctx.input_dus()[0]
            data = cu_ctx.read_input(du.id, "src")
            cu_ctx.write_output("y", data.upper())
            return len(runs)

        def consume(cu_ctx):
            du = cu_ctx.input_dus()[0]
            return cu_ctx.read_input(du.id, "y")

        FUNCTIONS.register("ft-produce", produce)
        FUNCTIONS.register("ft-consume", consume)
        p1 = s.start_pilot(resource_url="sim://cluster:pod0:host0")
        p2 = s.start_pilot(resource_url="sim://cluster:pod1:host0")
        p1.wait_active(), p2.wait_active()
        src = s.submit_du(name="src", files={"src": b"abc" * 1000})
        prod = s.submit_cu(
            executable="ft-produce",
            input_data=[src],
            output_data=[DataUnitDescription(name="inter")],
            pilot=p1,
        )
        inter = prod.output
        assert prod.result(timeout=20) == 1
        inter_du = inter.result(timeout=10)
        # content now lives ONLY in the dead-pilot-to-be's sandbox
        inter_du.drop_local_buffer()
        assert inter_du.locations == [p1.sandbox.id]
        p1.fail()
        # every replica is gone -> RECOVERING surfaces on the future while
        # the recorded producer is re-queued (lineage recomputation)
        assert _wait_until(lambda: inter.recovering, timeout=10)
        assert not inter.done()
        assert inter.id in s.recovering_dus()
        cons = s.submit_cu(executable="ft-consume", input_data=[inter])
        assert cons.result(timeout=30) == b"ABC" * 1000
        assert len(runs) == 2  # producer really re-ran
        assert prod.id in s.fault_manager.recomputed
        assert inter.state == DUState.READY and inter.sealed
        assert p1.sandbox.id not in inter.locations


def test_recover_du_reattaches_store_only_handle(topo):
    """Reconnected-manager scenario (§4.2): the DU exists only in the
    store.  Recovery must re-attach a live handle from the persisted
    manifest and heal — not skip and leave a READY DU with no replicas."""
    with PilotManager(topology=topo) as mgr:
        p = mgr.start_pilot(resource_url="sim://cluster:pod2:host0")
        p.wait_active()
        pd_a = mgr.start_pilot_data(
            service_url="sharedfs://cluster:pod0/a", affinity="cluster:pod0"
        )
        pd_b = mgr.start_pilot_data(
            service_url="sharedfs://cluster:pod1/b", affinity="cluster:pod1"
        )
        du = mgr.cds.submit_data_unit(
            DataUnitDescription(
                name="remote", files={"blob": b"m" * 8192}, chunk_size=1024
            ),
            target=p.sandbox,
        )
        assert du.wait() == DUState.READY
        pd_a.copy_chunks_from(du, p.sandbox, [0, 1, 2, 3])
        pd_b.copy_chunks_from(du, p.sandbox, [4, 5, 6, 7])
        # simulate a reconnected manager: no live handle anywhere
        mgr.ctx.objects.pop(du.id)
        fm = FaultManager(mgr.ctx, cds=mgr.cds)
        try:
            mgr.store.hset(f"pilot:{p.id}", "state", PilotState.FAILED)
            fm._handle_failure(p.id)
            assert fm.log[-1]["actions"][du.id] == "healed"
            locs = mgr.store.hget(f"du:{du.id}", "locations", [])
            assert locs and p.sandbox.id not in locs
            # the re-attached handle was registered for later resolution
            assert du.id in mgr.ctx.objects
        finally:
            fm.stop()


def test_lineage_unrecoverable_without_producer_fails(topo):
    """A sealed source DU with no producer, no buffer and no replicas is
    unrecoverable: it must FAIL loudly, not hang consumers."""
    with PilotManager(topology=topo) as mgr:
        p = mgr.start_pilot(resource_url="sim://cluster:pod0:host0")
        p.wait_active()
        du = mgr.cds.submit_data_unit(
            DataUnitDescription(name="orphaned", files={"a": b"x" * 1024}),
            target=p.sandbox,
        )
        assert du.wait() == DUState.READY
        du.drop_local_buffer()
        fm = FaultManager(mgr.ctx, cds=mgr.cds)
        try:
            mgr.store.hset(f"pilot:{p.id}", "state", PilotState.FAILED)
            fm._handle_failure(p.id)
            assert du.state == DUState.FAILED
            assert "no producer" in mgr.store.hget(f"du:{du.id}", "error")
            assert fm.log[-1]["actions"][du.id] == "lost"
        finally:
            fm.stop()


# ---------------------------------------------------- SUSPECT grace periods
def test_suspect_grace_period_then_reinstate_then_fail():
    store = CoordinationStore()
    ctx = RuntimeContext(store=store, topology=Topology())
    suspects, failures = [], []
    store.hset("pilot:px", "state", PilotState.ACTIVE)
    now = time.monotonic()
    mon = HeartbeatMonitor(
        ctx,
        timeout_s=0.5,
        suspect_timeout_s=0.1,
        on_suspect=suspects.append,
        on_failure=failures.append,
    )
    try:
        # fresh heartbeat: stays ACTIVE
        store.hset(HEARTBEATS_KEY, "px", now)
        mon._tick(now=now + 0.05)
        assert store.hget("pilot:px", "state") == PilotState.ACTIVE
        # grace window: SUSPECT, not FAILED
        mon._tick(now=now + 0.2)
        assert store.hget("pilot:px", "state") == PilotState.SUSPECT
        assert suspects == ["px"] and failures == []
        # heartbeats resume inside the grace window: reinstated
        store.hset(HEARTBEATS_KEY, "px", now + 0.25)
        mon._tick(now=now + 0.3)
        assert store.hget("pilot:px", "state") == PilotState.ACTIVE
        # hard silence: SUSPECT then FAILED
        mon._tick(now=now + 0.45)
        assert store.hget("pilot:px", "state") == PilotState.SUSPECT
        mon._tick(now=now + 0.8)
        assert store.hget("pilot:px", "state") == PilotState.FAILED
        assert failures == ["px"]
    finally:
        mon.stop()


def test_suspect_pilot_is_not_placeable(topo):
    with Session(topology=topo) as s:
        def echo(cu_ctx):
            return "ok"

        FUNCTIONS.register("ft-echo", echo)
        p0 = s.start_pilot(resource_url="sim://cluster:pod0:host0", slots=2)
        p1 = s.start_pilot(resource_url="sim://cluster:pod1:host0", slots=2)
        p0.wait_active(), p1.wait_active()
        s.store.hset(f"pilot:{p1.id}", "state", PilotState.SUSPECT)
        cus = [s.submit_cu(executable="ft-echo") for _ in range(4)]
        for cu in cus:
            assert cu.wait(timeout=20) == CUState.DONE
            # placement skipped the suspect pilot AND its agent claimed
            # nothing new off the global queue
            assert cu.pilot_id == p0.id
        # reinstated: pinned work flows again
        s.store.hset(f"pilot:{p1.id}", "state", PilotState.ACTIVE)
        cu = s.submit_cu(executable="ft-echo", pilot=p1)
        assert cu.wait(timeout=20) == CUState.DONE
        assert cu.pilot_id == p1.id


def test_falsely_failed_pilot_hands_work_back(topo):
    """Monitor false positive AFTER the recovery purge: a pilot marked
    FAILED whose sandbox was purged — while its agent is actually alive —
    must neither claim new work nor black-hole its in-flight CU; the
    declined attempt is handed back and completes elsewhere."""
    with Session(topology=topo) as s:
        def slowish(cu_ctx):
            time.sleep(0.4)
            return "done"

        FUNCTIONS.register("ft-slowish", slowish)
        p0 = s.start_pilot(resource_url="sim://cluster:pod0:host0")
        p1 = s.start_pilot(resource_url="sim://cluster:pod1:host0")
        p0.wait_active(), p1.wait_active()
        cu = s.submit_cu(executable="ft-slowish", pilot=p1, max_retries=3)
        assert _wait_until(lambda: cu.state == CUState.RUNNING, timeout=5)
        # false positive hardened all the way: pilot FAILED + sandbox
        # purged by recovery, but the agent never actually died
        s.store.hset(f"pilot:{p1.id}", "state", PilotState.FAILED)
        s.store.hset(f"pd:{p1.sandbox.id}", "state", PilotState.FAILED)
        assert cu.result(timeout=30) == "done"
        assert cu.pilot_id == p0.id  # the live survivor won it
        # the falsely-failed agent stopped claiming entirely
        cu2 = s.submit_cu(executable="ft-slowish")
        assert cu2.result(timeout=30) == "done"
        assert cu2.pilot_id == p0.id


# ------------------------------------------- orphan-requeue regression fixes
def test_requeue_orphans_bumps_store_attempts_without_live_handle(topo):
    """A crash-looping pilot must NOT retry an orphan forever when no live
    ComputeUnit handle resolves (regression: attempts were only bumped via
    ctx.lookup)."""
    with PilotManager(topology=topo) as mgr:
        store, ctx = mgr.store, mgr.ctx
        out = DataUnit(DataUnitDescription(name="out"), store)
        ctx.register(out)
        desc = ComputeUnitDescription(
            executable="nope", max_retries=2, output_data=[out.id]
        )
        cu = ComputeUnit(desc, store)  # NOT registered: lookup raises
        store.hset(f"du:{out.id}", "producer", cu.id)
        rounds = 0
        while store.hget(f"cu:{cu.id}", "state") != CUState.FAILED:
            rounds += 1
            assert rounds <= 10, "orphan requeued forever (attempts not bumped)"
            # simulate the crash-looping pilot re-claiming the CU and dying
            store.hset(f"cu:{cu.id}", "state", CUState.RUNNING)
            store.hset(f"cu:{cu.id}", "pilot", "pc-crashloop")
            requeue_orphans(ctx, "pc-crashloop")
        assert rounds == 3  # initial + max_retries, then terminal
        assert int(store.hget(f"cu:{cu.id}", "attempts")) == 3
        # cascade reached the output DU even with no live CU handle
        assert store.hget(f"du:{out.id}", "state") == DUState.FAILED
        assert cu.id in store.hget(f"du:{out.id}", "error")


def test_exhausted_orphan_cascades_to_waiting_consumers(topo):
    """Orphan retries exhausted -> CU FAILED through the full dataflow
    cascade: output DUs FAILED, parked consumers released with the cause
    (regression: _set_state(FAILED) bypassed the cascade and consumers
    hung)."""
    with Session(
        topology=topo, enable_fault_manager=True, heartbeat_timeout_s=0.3
    ) as s:
        def doomed(cu_ctx):
            time.sleep(0.6)
            cu_ctx.write_output("y", b"never")
            return 1

        def reader(cu_ctx):
            return 1

        FUNCTIONS.register("ft-doomed", doomed)
        FUNCTIONS.register("ft-reader", reader)
        p0 = s.start_pilot(resource_url="sim://cluster:pod0:host0")
        p1 = s.start_pilot(resource_url="sim://cluster:pod1:host0")
        p0.wait_active(), p1.wait_active()
        prod = s.submit_cu(
            executable="ft-doomed",
            output_data=[DataUnitDescription(name="never")],
            pilot=p1,
            max_retries=0,
        )
        cons = s.submit_cu(executable="ft-reader", input_data=[prod.output])
        assert _wait_until(lambda: cons.state == CUState.WAITING, timeout=5)
        assert _wait_until(lambda: prod.state == CUState.RUNNING, timeout=5)
        p1.fail()
        assert prod.wait(timeout=20) == CUState.FAILED
        assert "retries are exhausted" in prod.error
        assert prod.output.state == DUState.FAILED
        assert cons.wait(timeout=20) == CUState.FAILED
        with pytest.raises(ComputeFailedError) as exc:
            cons.result(timeout=5)
        assert prod.output.id in str(exc.value)


# ----------------------------------------------------- O(changes) monitors
def test_heartbeat_monitor_tick_is_single_scan():
    store = CoordinationStore()
    ctx = RuntimeContext(store=store, topology=Topology())
    now = time.monotonic()
    for i in range(50):
        store.hset(f"pilot:p{i}", "state", PilotState.ACTIVE)
        store.hset(HEARTBEATS_KEY, f"p{i}", now)
    mon = HeartbeatMonitor(ctx, timeout_s=10.0)
    try:
        before = store.ops_total
        mon._tick(now=now)
        quiet_50 = store.ops_total - before
        for i in range(50, 200):
            store.hset(f"pilot:p{i}", "state", PilotState.ACTIVE)
            store.hset(HEARTBEATS_KEY, f"p{i}", now)
        before = store.ops_total
        mon._tick(now=now)
        quiet_200 = store.ops_total - before
        # one hgetall regardless of pilot count
        assert quiet_50 == quiet_200 == 1
    finally:
        mon.stop()


def test_straggler_tick_is_o_changes():
    store = CoordinationStore()
    ctx = RuntimeContext(store=store, topology=Topology())
    mit = StragglerMitigator(ctx, min_samples=1)
    try:
        # feed completions + a large RUNNING population via events
        for i in range(100):
            desc = ComputeUnitDescription(executable="x", sim_compute_s=0.0)
            cu = ComputeUnit(desc, store)
            ctx.register(cu)
            store.hset(f"cu:{cu.id}", "state", CUState.RUNNING)
        store.hset(
            "cu:done-sample", "timings", {"t_c": 100.0}
        )  # huge median -> nothing past threshold
        before = store.ops_total
        mit._tick()
        assert store.ops_total - before == 0  # quiet tick: zero store ops
    finally:
        mit.stop()


def test_concurrent_ensure_does_not_over_replicate(topo):
    """ensure() no longer holds _ensure_lock across heal transfers (the
    PD-L002 finding); the per-DU gate must still close the original race:
    N concurrent passes over one under-replicated DU create exactly the
    missing replicas, never factor+k."""
    import threading

    from repro.core.recovery import ReplicaManager

    with PilotManager(topology=topo) as mgr:
        pd_a = mgr.start_pilot_data(
            service_url="sharedfs://cluster:pod0/a", affinity="cluster:pod0"
        )
        mgr.start_pilot_data(
            service_url="sharedfs://cluster:pod1/b", affinity="cluster:pod1"
        )
        mgr.start_pilot_data(
            service_url="sharedfs://cluster:pod2/c", affinity="cluster:pod2"
        )
        desc = DataUnitDescription(
            name="r2",
            files={"blob": b"r" * 4096},
            chunk_size=1024,
            replication_factor=2,
        )
        inner = mgr.cds.submit_data_unit(desc, target=pd_a)
        assert inner.wait() == DUState.READY
        rm = ReplicaManager(mgr.ctx, cds=mgr.cds)
        try:
            base = len(inner.locations)
            assert base in (1, 2)
            barrier = threading.Barrier(4)
            made = []

            def racer():
                barrier.wait(timeout=10)
                made.append(rm.ensure(inner))

            threads = [threading.Thread(target=racer) for _ in range(4)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=30)
            assert len(made) == 4
            # exactly the missing replicas were created: the first pass
            # through the gate heals, everyone parked on it re-reads the
            # updated locations and no-ops
            assert sum(made) == 2 - base
            assert len(inner.locations) == 2
        finally:
            rm.stop()
