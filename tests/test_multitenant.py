"""Multi-tenant QoS: admission control, quota parking, fair-share drain,
queued-only preemption, tenant-aware eviction, and the single-tenant
backward-compat guarantee.

The tenancy layer must be invisible to single-tenant callers (the default
tenant is a strict pass-through preserving the pre-QoS release order) and
must never convert quota pressure into burned retries: a parked CU stays
``Pending`` with zero attempts until its tenant has room again.
"""

import threading
import time

import pytest

from repro.core import (
    CoordinationStore,
    CUState,
    DataUnit,
    DataUnitDescription,
    FUNCTIONS,
    PilotData,
    PilotDataDescription,
    PilotManager,
    ResourceQuota,
    RuntimeContext,
    Session,
    TierManager,
    Topology,
    TransferService,
)
from repro.core.tenancy import DEFAULT_TENANT

SITE = "grid:site0"
CHUNK = 64
DU_BYTES = 4 * CHUNK


def _topo(*labels) -> Topology:
    topo = Topology()
    for lbl in labels or (SITE,):
        topo.register(lbl, bandwidth=30e6, latency=0.01)
    return topo


def _register_probe():
    """``mt-probe`` records finish order and live concurrency per tag."""
    state = {
        "lock": threading.Lock(),
        "live": {},
        "max_live": {},
        "finished": [],
    }

    def probe(cu_ctx, tag="?"):
        with state["lock"]:
            state["live"][tag] = state["live"].get(tag, 0) + 1
            state["max_live"][tag] = max(
                state["max_live"].get(tag, 0), state["live"][tag]
            )
        time.sleep(0.02)
        with state["lock"]:
            state["live"][tag] -= 1
            state["finished"].append((tag, cu_ctx.cu.id))
        return tag

    FUNCTIONS.register("mt-probe", probe)
    return state


def _wait_until(predicate, timeout=10.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(0.01)
    return predicate()


# ------------------------------------------------- single-tenant passthrough
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_default_tenant_is_exact_passthrough(mode):
    """Single-tenant callers need zero changes: nothing parks, nothing
    preempts, and every submitted CU flows through admission in order."""
    _register_probe()
    with Session(topology=_topo(), scheduler_mode=mode) as s:
        p = s.start_pilot(resource_url=f"sim://{SITE}", slots=2)
        p.wait_active()
        cus = [
            s.submit_cu(executable="mt-probe", kwargs={"tag": "d"})
            for _ in range(4)
        ]
        assert [c.result(timeout=30) for c in cus] == ["d"] * 4
        adm = s.cds.admission
        assert not adm.registry.multi_tenant
        assert adm.parked_total == 0
        assert adm.preemptions == []
        assert adm.parked() == {}
        # every CU passed the gate, synchronously, in submission order
        assert adm.admission_log == [c.id for c in cus]
        for c in cus:
            assert s.store.hget(f"cu:{c.id}", "admission") == "admitted"
            assert c.description.tenant == DEFAULT_TENANT


def test_session_stamps_tenant_on_dus_and_cus():
    mgr = PilotManager(topology=_topo())
    try:
        ten = Session(manager=mgr, tenant="acme", priority=3)
        _register_probe()
        p = ten.start_pilot(resource_url=f"sim://{SITE}", slots=1)
        p.wait_active()
        du = ten.submit_du(name="in", files={"x": b"z" * 64})
        cu = ten.submit_cu(
            executable="mt-probe", kwargs={"tag": "a"}, input_data=[du]
        )
        assert cu.result(timeout=30) == "a"
        assert mgr.store.hget(f"du:{du.id}", "tenant") == "acme"
        assert mgr.store.hget(f"cu:{cu.id}", "tenant") == "acme"
        reg = mgr.cds.admission.registry
        assert reg.get("acme").priority == 3
        assert reg.multi_tenant
        ten.close()
    finally:
        mgr.shutdown()


# --------------------------------------------------------- quota admission
def test_cu_slot_quota_parks_without_burning_retries():
    """A tenant over its cu_slots quota has surplus CUs *parked*: they stay
    Pending with zero attempts (no retry burned, no quota_waits), run at
    most quota-wide, and all finish as capacity turns over."""
    release = threading.Event()

    def blocker(cu_ctx):
        release.wait(timeout=30)
        return "blocked"

    FUNCTIONS.register("mt-blocker", blocker)
    state = _register_probe()
    mgr = PilotManager(topology=_topo())
    try:
        ten = Session(
            manager=mgr, tenant="capped", quota=ResourceQuota(cu_slots=1)
        )
        p = ten.start_pilot(resource_url=f"sim://{SITE}", slots=4)
        p.wait_active()
        first = ten.submit_cu(executable="mt-blocker")
        assert _wait_until(
            lambda: mgr.store.hget(f"cu:{first.id}", "state")
            == CUState.RUNNING
        )
        cus = [
            ten.submit_cu(executable="mt-probe", kwargs={"tag": "c"})
            for _ in range(5)
        ]
        adm = mgr.cds.admission
        # the blocker holds the single quota slot: every probe parked,
        # Pending, off every queue, zero attempts — deterministic because
        # no terminal event can drain the park while the blocker runs
        assert adm.parked()["capped"] == [c.id for c in cus]
        assert adm.parked_total == 5
        for c in cus:
            assert mgr.store.hget(f"cu:{c.id}", "state") == CUState.PENDING
            assert mgr.store.hget(f"cu:{c.id}", "admission") == "parked"
            assert int(mgr.store.hget(f"cu:{c.id}", "attempts", 0)) == 0
        release.set()
        assert first.result(timeout=30) == "blocked"
        assert [c.result(timeout=60) for c in cus] == ["c"] * 5
        # quota-wide concurrency bound held despite 4 free pilot slots
        assert state["max_live"]["c"] == 1
        # nothing was ever retried or counted as quota backpressure
        for c in cus:
            assert c.cu.attempts <= 1
            assert int(mgr.store.hget(f"cu:{c.id}", "quota_waits", 0)) == 0
        # FIFO within the tenant: admission order == submission order
        ids = {c.id for c in cus}
        admitted = [i for i in adm.admission_log if i in ids]
        assert admitted == [c.id for c in cus]
        ten.close()
    finally:
        release.set()
        mgr.shutdown()


def test_requeue_parks_only_when_own_tenant_over_quota():
    """The agent's sandbox-backpressure requeue re-enters admission: a
    tenant over its own byte quota parks (front of its line) instead of
    hot-looping through the global queue; an under-quota tenant goes
    straight back to the global queue as before."""
    _register_probe()
    mgr = PilotManager(topology=_topo())
    try:
        ten = Session(
            manager=mgr,
            tenant="fat",
            quota=ResourceQuota(sandbox_bytes=10 * DU_BYTES),
        )
        ten.start_pilot_data(service_url=f"mem://{SITE}/pd", affinity=SITE)
        p = ten.start_pilot(resource_url=f"sim://{SITE}", slots=1)
        p.wait_active()
        # one staged DU makes the tenant's resident bytes non-zero
        du = ten.submit_du(name="resident", files={"x": b"r" * DU_BYTES})
        cu = ten.submit_cu(executable="mt-probe", kwargs={"tag": "f"})
        assert cu.result(timeout=30) == "f"
        adm = mgr.cds.admission
        assert adm.registry.resident_bytes("fat") >= DU_BYTES
        # tighten the quota below what is resident: requeue must park
        adm.registry.register(
            "fat", quota=ResourceQuota(sandbox_bytes=DU_BYTES)
        )
        handle = mgr.ctx.lookup(cu.id)
        before = adm.parked_total
        assert adm.requeue(handle) is False  # over quota: parked, front
        assert adm.parked_total == before + 1
        assert adm.parked()["fat"][0] == cu.id
        assert mgr.store.hget(f"cu:{cu.id}", "admission") == "parked"
        # loosen the quota: the same requeue now passes straight through
        adm.registry.register(
            "fat", quota=ResourceQuota(sandbox_bytes=10 * DU_BYTES)
        )
        adm._parked["fat"].clear()
        assert adm.requeue(handle) is True
        assert du.result(timeout=10).sealed
        ten.close()
    finally:
        mgr.shutdown()


# ------------------------------------------------------ starvation freedom
def test_light_tenant_not_starved_by_flooding_tenant():
    """A capped heavy tenant flooding the system cannot starve a light
    tenant submitted afterwards: every light CU finishes before the heavy
    backlog drains."""
    state = _register_probe()
    mgr = PilotManager(topology=_topo())
    try:
        heavy = Session(
            manager=mgr, tenant="heavy", quota=ResourceQuota(cu_slots=2)
        )
        light = Session(manager=mgr, tenant="light")
        p = heavy.start_pilot(resource_url=f"sim://{SITE}", slots=2)
        p.wait_active()
        hs = [
            heavy.submit_cu(executable="mt-probe", kwargs={"tag": "h"})
            for _ in range(12)
        ]
        ls = [
            light.submit_cu(executable="mt-probe", kwargs={"tag": "l"})
            for _ in range(3)
        ]
        assert [c.result(timeout=120) for c in ls] == ["l"] * 3
        assert [c.result(timeout=120) for c in hs] == ["h"] * 12
        assert state["max_live"]["h"] <= 2
        order = [tag for tag, _ in state["finished"]]
        last_light = max(i for i, t in enumerate(order) if t == "l")
        last_heavy = max(i for i, t in enumerate(order) if t == "h")
        assert last_light < last_heavy, order
        heavy.close(), light.close()
    finally:
        mgr.shutdown()


# ------------------------------------------------------ queued preemption
def test_high_priority_preempts_queued_not_running():
    """A starved high-priority tenant takes a queue slot from the lowest
    priority tenant's *queued* CU (qremove is the claim-race CAS); the
    running CU is never touched and the victim re-admits later, nothing
    burned."""
    release = threading.Event()

    def blocker(cu_ctx):
        release.wait(timeout=30)
        return "blocked"

    FUNCTIONS.register("mt-blocker-2", blocker)
    _register_probe()
    mgr = PilotManager(topology=_topo())
    try:
        low = Session(manager=mgr, tenant="low", priority=0)
        high = Session(manager=mgr, tenant="high", priority=5)
        p = low.start_pilot(resource_url=f"sim://{SITE}", slots=1)
        p.wait_active()
        running = low.submit_cu(executable="mt-blocker-2")
        assert _wait_until(
            lambda: mgr.store.hget(f"cu:{running.id}", "state")
            == CUState.RUNNING
        )
        # direct-bound: these sit on the pilot queue behind the blocker
        q1 = low.submit_cu(
            executable="mt-probe", kwargs={"tag": "q"}, pilot=p
        )
        q2 = low.submit_cu(
            executable="mt-probe", kwargs={"tag": "q"}, pilot=p
        )
        assert _wait_until(lambda: mgr.store.qlen(p.queue_name) >= 2)
        adm = mgr.cds.admission
        hp = high.submit_cu(executable="mt-probe", kwargs={"tag": "hp"})
        assert _wait_until(lambda: len(adm.preemptions) == 1)
        ev = adm.preemptions[0]
        # most-recently-queued victim of the lowest-priority tenant;
        # the running blocker was never a candidate
        assert ev["cu"] == q2.id
        assert ev["tenant"] == "low" and ev["by_tenant"] == "high"
        assert ev["by"] == hp.id and ev["pilot"] == p.id
        assert mgr.store.hget(f"cu:{q2.id}", "admission") == "preempted"
        assert mgr.store.hget(f"cu:{q2.id}", "state") == CUState.PENDING
        # the high-priority CU took the vacated queue position
        queued = [
            i["cu"] if isinstance(i, dict) else i
            for i in mgr.store.qpeek(p.queue_name)
        ]
        assert hp.id in queued and q2.id not in queued
        release.set()
        assert running.result(timeout=30) == "blocked"
        assert hp.result(timeout=30) == "hp"
        # the victim re-admitted from park and completed; zero burned
        assert q1.result(timeout=30) == "q"
        assert q2.result(timeout=30) == "q"
        assert int(mgr.store.hget(f"cu:{q2.id}", "quota_waits", 0)) == 0
        low.close(), high.close()
    finally:
        release.set()
        mgr.shutdown()


def test_no_preemption_between_equal_priority_tenants():
    _register_probe()
    mgr = PilotManager(topology=_topo())
    try:
        a = Session(manager=mgr, tenant="a", priority=1)
        b = Session(manager=mgr, tenant="b", priority=1)
        p = a.start_pilot(resource_url=f"sim://{SITE}", slots=1)
        p.wait_active()
        cus = [
            s.submit_cu(executable="mt-probe", kwargs={"tag": t})
            for s, t in ((a, "a"), (b, "b"), (a, "a"), (b, "b"))
        ]
        assert [c.result(timeout=30) for c in cus] == ["a", "b", "a", "b"]
        assert mgr.cds.admission.preemptions == []
        a.close(), b.close()
    finally:
        mgr.shutdown()


# -------------------------------------------------- tenant-aware eviction
def _mk_ctx(*labels):
    ctx = RuntimeContext(store=CoordinationStore(), topology=_topo(*labels))
    TransferService(ctx)
    return ctx


def _mk_pd(ctx, url, affinity, quota=1 << 40):
    pd = PilotData(
        PilotDataDescription(
            service_url=url, affinity=affinity, size_quota=quota
        ),
        ctx,
    )
    return ctx.register(pd)


def _mk_du(ctx, name, fill, tenant):
    du = DataUnit(
        DataUnitDescription(
            name=name,
            files={"x": fill * DU_BYTES},
            chunk_size=CHUNK,
            tenant=tenant,
        ),
        ctx.store,
    )
    return ctx.register(du)


def test_eviction_prefers_requestors_own_chunks():
    """Under tenant-aware make_room, a tenant's space request is served
    from its OWN redundant chunks first; the rival's replica survives when
    evicting own bytes suffices."""
    ctx = _mk_ctx("t:s0", "t:s1")
    tm = TierManager(ctx, auto_promote=False)
    base = _mk_pd(ctx, "sharedfs://t:s0/base", "t:s0")
    edge = _mk_pd(ctx, "mem://t:s1/edge", "t:s1")
    mine = _mk_du(ctx, "mine", b"A", tenant="alpha")
    theirs = _mk_du(ctx, "theirs", b"B", tenant="beta")
    base.put_du(mine), base.put_du(theirs)
    edge.copy_du_from(mine, base)
    edge.copy_du_from(theirs, base)
    freed = tm.make_room(edge, DU_BYTES, tenant="alpha")
    assert freed >= DU_BYTES
    assert mine.id not in edge.du_ids()
    assert theirs.id in edge.du_ids()  # rival untouched: own bytes sufficed
    assert tm.cross_tenant_evictions_total == 0
    tm.stop()


def test_eviction_never_drops_another_tenants_pinned_working_set():
    """Another tenant's pinned DU is off-limits even when the requestor
    needs more than its own bytes: make_room frees what it legally can and
    the pinned replica survives (the caller then backpressures)."""
    ctx = _mk_ctx("t:s0", "t:s1")
    tm = TierManager(ctx, auto_promote=False)
    base = _mk_pd(ctx, "sharedfs://t:s0/base", "t:s0")
    edge = _mk_pd(ctx, "mem://t:s1/edge", "t:s1")
    mine = _mk_du(ctx, "mine", b"A", tenant="alpha")
    pinned = _mk_du(ctx, "pinned", b"B", tenant="beta")
    base.put_du(mine), base.put_du(pinned)
    edge.copy_du_from(mine, base)
    edge.copy_du_from(pinned, base)
    # a live consumer of tenant beta pins its working set
    ctx.store.hset("cu:beta-live", "state", CUState.RUNNING)
    tm.pins.pin(pinned.id, "beta-live")
    freed = tm.make_room(edge, 3 * DU_BYTES, tenant="alpha")
    assert freed == DU_BYTES  # only alpha's own redundant chunks
    assert pinned.id in edge.du_ids()
    assert pinned.has_full_coverage()
    assert tm.cross_tenant_pinned_evictions == 0
    # the audit trail attributes every eviction to owner + requestor
    for entry in tm.evictions:
        assert entry["tenant"] == "alpha"
        assert entry["requestor"] == "alpha"
    # the tenant fence aside, the pin alone already protects it on the
    # single-tenant path too
    assert all(v.du_id != pinned.id for v in tm.evictable_victims(edge))
    tm.stop()


def test_cross_tenant_eviction_allowed_for_unpinned_redundant_chunks():
    """Tenant-awareness is an ordering + pin fence, not a hard partition:
    with no own bytes left, another tenant's UNPINNED redundant replica is
    fair game (counted in the audit)."""
    ctx = _mk_ctx("t:s0", "t:s1")
    tm = TierManager(ctx, auto_promote=False)
    base = _mk_pd(ctx, "sharedfs://t:s0/base", "t:s0")
    edge = _mk_pd(ctx, "mem://t:s1/edge", "t:s1")
    theirs = _mk_du(ctx, "theirs", b"B", tenant="beta")
    base.put_du(theirs)
    edge.copy_du_from(theirs, base)
    freed = tm.make_room(edge, DU_BYTES, tenant="alpha")
    assert freed >= DU_BYTES
    assert tm.cross_tenant_evictions_total >= 1
    assert tm.cross_tenant_pinned_evictions == 0
    tm.stop()


# ------------------------------------------------------- teardown ordering
def test_close_session_with_parked_waiting_cus():
    """Closing a session (and its manager) while CUs are parked Waiting on
    a never-produced DU must drain cleanly: dispatcher and admission
    threads stop before the store dispatcher, no hang, no error."""
    _register_probe()
    s = Session(topology=_topo())
    p = s.start_pilot(resource_url=f"sim://{SITE}", slots=1)
    p.wait_active()
    hole = s.create_du(name="never-produced")
    waiting = s.submit_cu(
        executable="mt-probe", kwargs={"tag": "w"}, input_data=[hole]
    )
    assert _wait_until(
        lambda: s.store.hget(f"cu:{waiting.id}", "state") == CUState.WAITING
    )
    s.close()  # must not hang or raise
    assert s.manager._sessions == []


def test_close_attached_sessions_drained_by_manager_shutdown():
    """Sessions attached via Session(manager=...) are tracked: manager
    shutdown drains their dispatcher threads even when the caller forgot
    to close them (the pre-fix leak)."""
    mgr = PilotManager(topology=_topo())
    s1 = Session(manager=mgr, tenant="x")
    s2 = Session(manager=mgr, tenant="y")
    assert s1 in mgr._sessions and s2 in mgr._sessions
    mgr.shutdown()  # must stop both dispatchers before the store closes
    assert mgr._sessions == []
    assert not s1._dispatcher._pump._thread.is_alive()
    assert not s2._dispatcher._pump._thread.is_alive()
