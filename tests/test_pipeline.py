"""Pipeline parallelism over a fake multi-device mesh (subprocess — device
count must be set before jax init)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp

    from repro.distributed.pipeline import bubble_fraction, pipeline_apply

    from repro.distributed.compat import make_mesh
    mesh = make_mesh((4, 2), ("pod", "data"))
    n_stages, n_micro, mb, d = 4, 8, 2, 16

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (n_stages, d, d)) * 0.5
    x = jax.random.normal(jax.random.fold_in(key, 1), (n_micro, mb, d))

    out = pipeline_apply(stage_fn, ws, x, mesh, axis="pod")

    # reference: sequential application of all stages per microbatch
    ref = x
    for i in range(n_stages):
        ref = jnp.tanh(ref @ ws[i])
    err = float(jnp.abs(out - ref).max())
    print(json.dumps({
        "err": err,
        "bubble": bubble_fraction(n_stages, n_micro),
        "shape_ok": out.shape == ref.shape,
    }))
    """
)


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_pipeline_matches_sequential(result):
    assert result["shape_ok"]
    assert result["err"] < 1e-5


def test_bubble_fraction(result):
    assert result["bubble"] == pytest.approx(3 / 11)
