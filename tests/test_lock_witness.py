"""Runtime lock-order witness: inversion detection with a usable trace,
re-entrancy, the injectable lock factory in coordination.py, and the
cross-validation of observed edges against the static PD-L005 graph."""

import os
import subprocess
import sys
import threading
from pathlib import Path

import pytest

from repro.analysis.lockgraph import build_lock_graph
from repro.analysis.model import build_project
from repro.analysis.witness import (
    LockOrderViolation,
    Witness,
    WitnessedLock,
    active_witness,
    install,
    uninstall,
)
from repro.core import coordination
from repro.core.coordination import CoordinationStore

ROOT = Path(__file__).resolve().parent.parent
COORDINATION_PY = ROOT / "src" / "repro" / "core" / "coordination.py"


@pytest.fixture(autouse=True)
def _plain_locks_after():
    yield
    uninstall()


# ------------------------------------------------------------- inversions
def test_same_thread_inversion_trips_with_trace():
    w = Witness()
    a = WitnessedLock("locks.A", False, w)
    b = WitnessedLock("locks.B", False, w)
    with a:
        with b:
            pass
    with pytest.raises(LockOrderViolation) as exc:
        with b:
            with a:
                pass
    msg = str(exc.value)
    assert "locks.A" in msg and "locks.B" in msg
    assert "test_lock_witness.py" in msg  # acquisition sites are included
    assert w.violations  # recorded for post-mortem dumps too


def test_two_thread_inversion_is_caught_on_first_execution():
    """The witness needs one *execution* of each order, not an actual
    deadlock: thread 1 finishes A→B entirely before thread 2 runs B→A."""
    w = Witness()
    a = WitnessedLock("locks.A", False, w)
    b = WitnessedLock("locks.B", False, w)
    caught = []

    def forward():
        with a:
            with b:
                pass

    def backward():
        try:
            with b:
                with a:
                    pass
        except LockOrderViolation as e:
            caught.append(e)

    t1 = threading.Thread(target=forward)
    t1.start()
    t1.join()
    t2 = threading.Thread(target=backward)
    t2.start()
    t2.join()
    assert len(caught) == 1
    assert "locks.A" in str(caught[0])


def test_reentrant_and_repeated_nesting_are_not_violations():
    w = Witness()
    r = WitnessedLock("locks.R", True, w)
    a = WitnessedLock("locks.A", False, w)
    with r:
        with r:  # same RLock instance: no self-edge
            with a:
                pass
    for _ in range(3):  # repeating a consistent order is fine
        with r:
            with a:
                pass
    assert w.violations == []


def test_nonblocking_acquire_paths():
    w = Witness()
    a = WitnessedLock("locks.A", False, w)
    assert a.acquire(blocking=False)  # timeout=-1 must not be forwarded
    assert not a.acquire(blocking=False)
    a.release()
    assert a.acquire(timeout=0.5)
    a.release()
    assert w.held_names() == []


# ------------------------------------------------------ store under witness
def test_store_workload_observes_only_static_edges(tmp_path):
    """Everything the witness sees in a real store workload must be
    explained by the static lock graph — any unexplained edge is a hole
    in the PD-L005 model (or a new, unreviewed nesting)."""
    w = install()
    assert active_witness() is w
    store = CoordinationStore(
        dispatch="inline", wal_path=str(tmp_path / "store.wal")
    )
    assert type(store._shards[0].lock).__name__ == "WitnessedLock"
    seen = []
    store.subscribe(lambda ev: seen.append(ev))
    for i in range(50):
        store.set(f"cu:{i}", i)
        store.hset(f"du:{i}", "state", "READY")
    store.push("q", "item")
    assert store.pop("q", timeout=1.0) == "item"
    store.flush_events()
    store.flush_wal()
    assert store.keys("cu:") == sorted(f"cu:{i}" for i in range(50))
    store.close()
    assert w.violations == []
    assert seen

    project = build_project([COORDINATION_PY])
    static = set(build_lock_graph(project).edges)
    assert w.observed_class_edges(), "workload should nest at least once"
    assert w.unexplained_edges(static) == set()


def test_injected_inversion_trips_through_the_store_factory():
    """A deliberate inversion against a store-internal lock is caught even
    when the store side was acquired by coordination.py itself."""
    w = install()
    store = CoordinationStore(dispatch="inline")
    outside = WitnessedLock("test.outside", False, w)

    # consistent order first: outside → (store internals, incl. the
    # inline drain lock — hset publishes, so the mutating thread drains)
    store.subscribe(lambda ev: None)
    with outside:
        store.hset("prime", "f", 1)

    # inversion: a callback grabs `outside` inside inline dispatch, i.e.
    # while the store's drain lock is held
    def cb(ev):
        if ev.key == "trip":
            with outside:
                pass

    store.subscribe(cb)
    # the dispatcher contains broken subscribers by design, so the raise
    # is swallowed there — but the witness records the trace first
    store.hset("trip", "f", 2)
    store.flush_events()
    assert len(w.violations) == 1
    assert "test.outside" in w.violations[0]
    assert "CoordinationStore._inline_lock" in w.violations[0]


def test_env_hook_installs_witness_in_fresh_interpreter():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env["REPRO_LOCK_WITNESS"] = "1"
    code = (
        "from repro.core.coordination import CoordinationStore\n"
        "from repro.analysis.witness import active_witness\n"
        "s = CoordinationStore()\n"
        "print(type(s._shards[0].lock).__name__)\n"
        "print(active_witness() is not None)\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=120,
    )
    assert proc.returncode == 0, proc.stderr
    assert proc.stdout.split() == ["WitnessedLock", "True"]


def test_uninstall_restores_plain_locks():
    install()
    uninstall()
    assert active_witness() is None
    store = CoordinationStore()
    assert type(store._shards[0].lock).__name__ != "WitnessedLock"
    assert coordination._LOCK_FACTORY is None
