"""Property tests for the chunk-streaming invariants: published prefixes
are monotone and gap-free under arbitrary producer action interleavings,
read frontiers never move backward, and a rolled-back (failed) producer
attempt leaves zero published chunks behind."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core import (
    CoordinationStore,
    CoordinationUnavailable,
    CUState,
    DataUnit,
    DataUnitDescription,
    RuntimeContext,
    Topology,
    TransferService,
)
from repro.core.tiering import PinRegistry

CSIZE = 64

#: one producer action: append a file of this many bytes (0 allowed), or
#: attempt to publish up to this absolute prefix (clamping is the DU's job)
_actions = st.lists(
    st.one_of(
        st.tuples(st.just("add"), st.integers(min_value=0, max_value=300)),
        st.tuples(st.just("publish"), st.integers(min_value=0, max_value=40)),
    ),
    min_size=1,
    max_size=30,
)


def _streaming_du(store=None) -> DataUnit:
    return DataUnit(
        DataUnitDescription(name="p", streaming=True, chunk_size=CSIZE),
        store or CoordinationStore(),
    )


@settings(max_examples=60, deadline=None)
@given(actions=_actions)
def test_published_prefix_monotone_and_gap_free(actions):
    """However adds and publishes interleave, the published prefix (a) never
    moves backward, (b) never exceeds the number of *complete* chunks whose
    bytes have actually been appended (no consumer can be released toward a
    chunk that does not fully exist), and (c) after seal equals n_chunks."""
    du = _streaming_du()
    last_published = 0
    nfile = 0
    for kind, arg in actions:
        if kind == "add":
            du.add_file(f"f{nfile:04d}", b"x" * arg)
            nfile += 1
        else:
            du.publish_prefix(arg)
        published = du.published
        assert published >= last_published  # monotone
        assert published <= du.size // CSIZE  # only fully-written chunks
        assert du.available_chunks() <= du.n_chunks
        last_published = published
    du.seal()
    assert du.published == du.n_chunks == du.available_chunks()


@settings(max_examples=60, deadline=None)
@given(actions=_actions)
def test_reset_stream_rolls_back_to_zero(actions):
    """A failed producer attempt (abort path) publishes nothing durable:
    after reset the DU is indistinguishable from a fresh stream, and a
    second attempt streams into it cleanly."""
    du = _streaming_du()
    nfile = 0
    for kind, arg in actions:
        if kind == "add":
            du.add_file(f"f{nfile:04d}", b"y" * arg)
            nfile += 1
        else:
            du.publish_prefix(arg)
    version_before = du.locations_version
    du.reset_stream()
    assert du.published == 0 and du.n_chunks == 0 and du.size == 0
    assert du.manifest == {} and not du.sealed
    assert du.locations_version > version_before  # stale chunk plans invalidated
    # the retry writes fresh content into the same DU id
    du.add_file("retry", b"z" * (2 * CSIZE))
    du.publish_prefix(2)
    assert du.published == 2 and du.available_chunks() == 2
    du.seal()
    assert du.published == du.n_chunks == 2
    assert du.read("retry") == b"z" * (2 * CSIZE)


_frontier_ops = st.lists(
    st.tuples(
        st.sampled_from(["c0", "c1", "c2"]),
        st.integers(min_value=0, max_value=20),
    ),
    min_size=1,
    max_size=40,
)


@settings(max_examples=80, deadline=None)
@given(
    ops=_frontier_ops,
    owners=st.sets(st.sampled_from(["c0", "c1", "c2"]), min_size=1),
)
def test_read_frontier_monotone_under_arbitrary_advances(ops, owners):
    """With a fixed set of live pinning consumers, the DU-wide read
    frontier (min over owners) never decreases as advance reports arrive in
    any order — an eviction decision taken at an earlier reading stays
    safe."""
    ctx = RuntimeContext(store=CoordinationStore(), topology=Topology())
    TransferService(ctx)
    pins = PinRegistry(ctx)
    for owner in owners:
        ctx.store.hset(f"cu:{owner}", "state", CUState.RUNNING)
        pins.pin("du-s", owner)
    per_owner = {o: 0 for o in owners}
    last = pins.read_frontier("du-s")
    assert last == 0
    for owner, upto in ops:
        got = pins.advance_frontier("du-s", owner, upto)
        if owner in per_owner:
            per_owner[owner] = max(per_owner[owner], upto)
            assert got == per_owner[owner]  # per-owner max-merge
        frontier = pins.read_frontier("du-s")
        assert frontier >= last  # global monotonicity
        assert frontier == min(per_owner.values())
        last = frontier
    # a consumer finishing only ever makes eviction MORE permissive: the
    # min over remaining live owners rises, or — when it was the last live
    # owner — the frontier collapses to the unconstrained sentinel (-1,
    # semantically +infinity)
    done = sorted(owners)[0]
    ctx.store.hset(f"cu:{done}", "state", CUState.DONE)
    after = pins.read_frontier("du-s")
    assert after >= last or after == -1


def test_publish_on_sealed_nonstream_du_raises():
    """Guard rails outside the property sweep: prefix APIs reject misuse."""
    store = CoordinationStore()
    du = DataUnit(DataUnitDescription(name="plain", files={"a": b"xy" * CSIZE}), store)
    assert not du.streaming
    assert du.available_chunks() == du.n_chunks
    with pytest.raises((RuntimeError, CoordinationUnavailable)):
        du.reset_stream()
