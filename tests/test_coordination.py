"""Coordination store: queues, CAS, durability (WAL replay), outages."""

import threading
import time

import pytest

from repro.core import CoordinationStore, CoordinationUnavailable, with_retry


def test_kv_and_hash_roundtrip():
    st = CoordinationStore()
    st.set("a", {"x": 1})
    assert st.get("a") == {"x": 1}
    st.hset("h", "f1", [1, 2])
    st.hset("h", "f2", "v")
    assert st.hget("h", "f1") == [1, 2]
    assert st.hgetall("h") == {"f1": [1, 2], "f2": "v"}
    st.hdel("h", "f1")
    assert st.hget("h", "f1") is None
    st.delete("a")
    assert st.get("a") is None


def test_queue_fifo_and_multi_queue_priority():
    st = CoordinationStore()
    st.push("q1", "a")
    st.push("q1", "b")
    st.push("q2", "c")
    # pop_any prefers earlier-listed queues (pilot queue before global).
    assert st.pop_any(["q1", "q2"]) == "a"
    assert st.pop_any(["q1", "q2"]) == "b"
    assert st.pop_any(["q1", "q2"]) == "c"
    assert st.pop_any(["q1", "q2"], timeout=0.01) is None


def test_blocking_pop_wakes_on_push():
    st = CoordinationStore()
    got = []

    def consumer():
        got.append(st.pop("q", timeout=2.0))

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.05)
    st.push("q", 42)
    t.join(timeout=3.0)
    assert got == [42]


def test_cas_exactly_once():
    st = CoordinationStore()
    st.hset("cu:1", "winner", None)
    wins = [st.hcas("cu:1", "winner", None, f"agent{i}") for i in range(5)]
    assert wins.count(True) == 1
    assert st.hget("cu:1", "winner") == "agent0"


def test_qremove():
    st = CoordinationStore()
    st.push("q", "a")
    st.push("q", "b")
    assert st.qremove("q", "a")
    assert not st.qremove("q", "zz")
    assert st.qpeek("q") == ["b"]


def test_wal_replay(tmp_path):
    wal = str(tmp_path / "wal.jsonl")
    st = CoordinationStore(wal_path=wal)
    st.set("k", "v")
    st.hset("h", "f", 7)
    st.push("q", "item1")
    st.push("q", "item2")
    assert st.pop("q") == "item1"
    st.close()
    # A fresh store replaying the WAL sees identical state (restart story).
    st2 = CoordinationStore(wal_path=wal)
    assert st2.get("k") == "v"
    assert st2.hget("h", "f") == 7
    assert st2.qpeek("q") == ["item2"]
    st2.close()


def test_transient_outage_and_retry():
    st = CoordinationStore()
    st.fail_for(0.15)
    with pytest.raises(CoordinationUnavailable):
        st.set("k", 1)
    # with_retry rides out the outage (the paper's "survive transient
    # Redis failures").
    with_retry(lambda: st.set("k", 1))
    assert st.get("k") == 1


def test_snapshot_restore():
    st = CoordinationStore()
    st.set("a", 1)
    st.push("q", "x")
    snap = st.snapshot()
    st.set("a", 2)
    assert st.pop("q") == "x"
    st.restore(snap)
    assert st.get("a") == 1
    assert st.qpeek("q") == ["x"]
