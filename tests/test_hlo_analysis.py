"""HLO analyzer unit tests on synthetic HLO text fixtures (no jax)."""

from repro.launch.hlo_analysis import (
    analyze_hlo,
    comp_multipliers,
    parse_computations,
    shape_bytes,
)

SCANNED = """
%body (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,128] get-tuple-element(%p), index=1
  %w = f32[128,128] constant({...})
  %d = f32[128,128] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,128]) tuple(%ni, %d)
}
%cond (p: (s32[], f32[128,128])) -> pred[] {
  %p = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(8)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}
ENTRY %main (a: f32[128,128]) -> f32[128,128] {
  %a = f32[128,128] parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[128,128]) tuple(%z, %a)
  %w8 = (s32[], f32[128,128]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"8"}}
  ROOT %out = f32[128,128] get-tuple-element(%w8), index=1
}
"""

ELEMENTWISE_CHAIN = """
ENTRY %main (a: f32[1000,1000], b: f32[1000,1000]) -> f32[1000,1000] {
  %a = f32[1000,1000] parameter(0)
  %b = f32[1000,1000] parameter(1)
  %c1 = f32[1000,1000] multiply(%a, %b)
  %c2 = f32[1000,1000] add(%c1, %a)
  %c3 = f32[1000,1000] exponential(%c2)
  ROOT %c4 = f32[1000,1000] subtract(%c3, %b)
}
"""

COLLECTIVES = """
ENTRY %main (x: f32[64,64]) -> f32[64,64] {
  %x = f32[64,64] parameter(0)
  %ag = f32[64,64] all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%sum
  ROOT %cp = f32[64,64] collective-permute(%ag), source_target_pairs={{0,16},{16,32}}
}
%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[128,128]") == 128 * 128 * 4
    assert shape_bytes("bf16[2,4,8]{2,1,0}") == 64 * 2
    assert shape_bytes("(f32[2,2], s32[4])") == 16 + 16
    assert shape_bytes("pred[]") == 1


def test_trip_count_multiplier():
    comps, entry = parse_computations(SCANNED)
    assert entry == "main"
    mult = comp_multipliers(comps, entry)
    assert mult["body"] == 8.0


def test_scanned_flops_trip_aware():
    a = analyze_hlo(SCANNED)
    # 8 iterations × 2·128³ dot flops
    assert a["flops"] == 8 * 2 * 128**3


def test_elementwise_chain_fuses():
    a = analyze_hlo(ELEMENTWISE_CHAIN)
    mb = 1000 * 1000 * 4
    # fused region: reads a, b once; writes the root once = 3 buffers —
    # NOT 4 ops × (2 reads + 1 write) = 12 buffers
    assert a["hbm_bytes"] == 3 * mb


def test_collective_axis_classification():
    a = analyze_hlo(COLLECTIVES, {"data": 16, "model": 16})
    per_axis = a["collective_per_axis"]
    nb = 64 * 64 * 4
    # iota groups [16,16]<=[256] row-major → consecutive ids → model axis
    assert per_axis.get("model") == nb
    # permute pairs stride 16 → data axis
    assert per_axis.get("data") == nb
    assert a["collective_per_op"]["all-reduce"] == nb
    assert a["collective_per_op"]["collective-permute"] == nb
