"""Property tests for the chunk manifest: split→reassemble is identity and
checksums are stable across recomputation, for arbitrary file sets and
chunk sizes."""

import zlib

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core import CoordinationStore, DataUnit, DataUnitDescription

_files = st.dictionaries(
    keys=st.text(
        alphabet="abcdefgh123", min_size=1, max_size=8
    ).filter(lambda s: ".." not in s),
    values=st.binary(min_size=0, max_size=2048),
    min_size=0,
    max_size=6,
)


@settings(max_examples=60, deadline=None)
@given(files=_files, chunk_size=st.integers(min_value=1, max_value=4096))
def test_split_reassemble_is_identity(files, chunk_size):
    store = CoordinationStore()
    du = DataUnit(
        DataUnitDescription(files=files, chunk_size=chunk_size), store
    )
    stream = b"".join(du.chunk_data(i) for i in range(du.n_chunks))
    assert stream == b"".join(files[k] for k in sorted(files))
    assert sum(c.size for c in du.chunks) == du.size
    # every file's byte range slices back out of the stream
    for rel, data in files.items():
        lo, hi = du.file_range(rel)
        assert stream[lo:hi] == data
    # all chunks but the last are exactly chunk_size
    for c in du.chunks[:-1]:
        assert c.size == chunk_size


@settings(max_examples=40, deadline=None)
@given(files=_files, chunk_size=st.integers(min_value=1, max_value=512))
def test_chunk_checksums_stable(files, chunk_size):
    store = CoordinationStore()
    d1 = DataUnit(
        DataUnitDescription(files=files, chunk_size=chunk_size), store
    )
    d2 = DataUnit(
        DataUnitDescription(files=dict(files), chunk_size=chunk_size), store
    )
    assert [(c.size, c.checksum) for c in d1.chunks] == [
        (c.size, c.checksum) for c in d2.chunks
    ]
    for c in d1.chunks:
        assert zlib.crc32(d1.chunk_data(c.index)) == c.checksum


@settings(max_examples=40, deadline=None)
@given(files=_files.filter(bool), chunk_size=st.integers(min_value=1, max_value=256))
def test_incremental_add_matches_batch(files, chunk_size):
    """Adding files one-by-one re-chunks to the same table as constructing
    the DU with all files up front."""
    store = CoordinationStore()
    batch = DataUnit(
        DataUnitDescription(files=files, chunk_size=chunk_size), store
    )
    inc = DataUnit(DataUnitDescription(chunk_size=chunk_size), store)
    for rel, data in files.items():
        inc.add_file(rel, data)
    assert [(c.size, c.checksum) for c in inc.chunks] == [
        (c.size, c.checksum) for c in batch.chunks
    ]
