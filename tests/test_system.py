"""End-to-end behaviour tests for the Pilot-Data runtime (threaded, real
execution; simulated transfer clock) — driven through the Pilot-API v2
:class:`Session` facade (typed futures, object-wired data dependencies)."""

import time

import pytest

from repro.core import (
    CUState,
    DUState,
    DemandReplicator,
    FUNCTIONS,
    PilotManager,
    PilotState,
    QuotaExceeded,
    Session,
    make_tpu_fleet_topology,
    replicate_group,
    replicate_sequential,
)
from repro.core.data_unit import DataUnitDescription


@pytest.fixture()
def topo():
    t, _ = make_tpu_fleet_topology(pods=2, hosts_per_pod=2)
    return t


@pytest.fixture()
def sess(topo):
    with Session(topology=topo) as s:
        yield s


def _register_echo():
    def echo(cu_ctx, payload="ok"):
        return payload

    FUNCTIONS.register("echo", echo)
    return echo


def test_pilot_lifecycle(sess):
    p = sess.start_pilot(resource_url="sim://cluster:pod0:host0", slots=2)
    assert p.wait_active() == PilotState.ACTIVE
    p.cancel()
    assert p.state == PilotState.CANCELED


def test_cu_executes_and_returns(sess):
    _register_echo()
    p = sess.start_pilot(resource_url="sim://cluster:pod0:host0")
    p.wait_active()
    cu = sess.submit_cu(executable="echo", kwargs={"payload": 7})
    assert cu.wait() == CUState.DONE
    assert cu.result() == 7


def test_du_staged_to_affine_pd_and_linked(sess):
    """DU at pod0 shared FS → pod0 pilot links (no bytes), pod1 copies."""
    sess.start_pilot_data(
        service_url="sharedfs://cluster:pod0/scratch", affinity="cluster:pod0"
    )
    p0 = sess.start_pilot(resource_url="sim://cluster:pod0:host0")
    p1 = sess.start_pilot(resource_url="sim://cluster:pod1:host0")
    p0.wait_active(), p1.wait_active()
    du = sess.submit_du(name="ref", files={"a": b"z" * 4096})
    assert du.wait() == DUState.READY

    def read_len(cu_ctx):
        return len(cu_ctx.read_input(du.id, "a"))

    FUNCTIONS.register("read_len", read_len)
    c0 = sess.submit_cu(executable="read_len", input_data=[du], pilot=p0)
    c1 = sess.submit_cu(executable="read_len", input_data=[du], pilot=p1)
    assert c0.wait() == CUState.DONE and c1.wait() == CUState.DONE
    assert c0.result() == c1.result() == 4096
    recs = {(r.dst_pd, r.linked) for r in sess.transfer.records() if r.du_id == du.id}
    linked = [r for r in sess.transfer.records() if r.du_id == du.id and r.linked]
    copied = [
        r
        for r in sess.transfer.records()
        if r.du_id == du.id and not r.linked and r.src_pd is not None
    ]
    assert linked, recs  # pod0 pilot used the logical link
    assert copied  # pod1 pilot had to move bytes


def test_affinity_constraint_respected(sess):
    _register_echo()
    p0 = sess.start_pilot(resource_url="sim://cluster:pod0:host0")
    p1 = sess.start_pilot(resource_url="sim://cluster:pod1:host0")
    p0.wait_active(), p1.wait_active()
    cus = [
        sess.submit_cu(executable="echo", affinity="cluster:pod1")
        for _ in range(4)
    ]
    for cu in cus:
        assert cu.wait() == CUState.DONE
        assert cu.pilot_id == p1.id


def test_scheduler_places_cu_near_data(sess):
    """No explicit binding: the CDS should pick the data-local pilot."""
    _register_echo()
    sess.start_pilot_data(
        service_url="sharedfs://cluster:pod1/scratch", affinity="cluster:pod1"
    )
    p0 = sess.start_pilot(resource_url="sim://cluster:pod0:host0")
    p1 = sess.start_pilot(resource_url="sim://cluster:pod1:host0")
    p0.wait_active(), p1.wait_active()
    du = sess.submit_du(name="big", files={"blob": b"q" * (1 << 20)})
    assert du.wait() == DUState.READY
    cu = sess.submit_cu(executable="echo", input_data=[du])
    assert cu.wait() == CUState.DONE
    assert cu.pilot_id == p1.id
    decision = [d for d in sess.decisions() if d["cu"] == cu.id][0]
    assert decision["pilot"] == p1.id


def test_push_mode_prestages(topo):
    with Session(topology=topo, data_mode="push") as s:
        _register_echo()
        p = s.start_pilot(resource_url="sim://cluster:pod0:host0")
        p.wait_active()
        du = s.submit_du(name="d", files={"a": b"x" * 128})
        # In push mode the manager stages before queueing; once the CU
        # starts, its sandbox already holds the DU.
        cu = s.submit_cu(executable="echo", input_data=[du])
        assert cu.wait() == CUState.DONE
        assert p.sandbox.has_du(du.id)


def test_pilot_cache_reuse(sess):
    """Second CU on the same pilot must not re-transfer the DU."""
    _register_echo()
    p = sess.start_pilot(resource_url="sim://cluster:pod1:host0", slots=1)
    p.wait_active()
    du = sess.submit_du(name="d", files={"a": b"x" * 2048})
    cu1 = sess.submit_cu(executable="echo", input_data=[du], pilot=p)
    assert cu1.wait() == CUState.DONE
    n_before = len([r for r in sess.transfer.records() if r.du_id == du.id])
    cu2 = sess.submit_cu(executable="echo", input_data=[du], pilot=p)
    assert cu2.wait() == CUState.DONE
    n_after = len([r for r in sess.transfer.records() if r.du_id == du.id])
    assert n_after == n_before  # cache hit: zero new transfers


def test_output_du_flow(sess):
    p = sess.start_pilot(resource_url="sim://cluster:pod0:host0")
    p.wait_active()
    du_in = sess.submit_du(name="in", files={"x": b"abc"})

    def transform(cu_ctx):
        data = cu_ctx.read_input(du_in.id, "x")
        cu_ctx.write_output("y", data.upper())

    FUNCTIONS.register("transform", transform)
    cu = sess.submit_cu(
        executable="transform",
        input_data=[du_in],
        output_data=[DataUnitDescription(name="out")],
    )
    assert cu.wait() == CUState.DONE
    du_out = cu.output
    assert du_out.state == DUState.READY
    assert du_out.sealed
    pd = sess.ctx.lookup(du_out.locations[0])
    assert pd.fetch_du_file(du_out.id, "y") == b"ABC"


def test_cu_failure_retries_then_fails(sess):
    attempts = []

    def flaky(cu_ctx):
        attempts.append(1)
        raise ValueError("boom")

    FUNCTIONS.register("flaky", flaky)
    p = sess.start_pilot(resource_url="sim://cluster:pod0:host0")
    p.wait_active()
    cu = sess.submit_cu(executable="flaky", max_retries=2)
    assert cu.wait(timeout=20) == CUState.FAILED
    assert len(attempts) == 3  # initial + 2 retries
    assert "boom" in cu.error


def test_heartbeat_failure_recovery(topo):
    with Session(
        topology=topo, enable_heartbeat_monitor=True, heartbeat_timeout_s=0.3
    ) as s:

        def slow(cu_ctx):
            time.sleep(0.4)
            return "done"

        FUNCTIONS.register("slow2", slow)
        p0 = s.start_pilot(resource_url="sim://cluster:pod0:host0")
        p1 = s.start_pilot(resource_url="sim://cluster:pod1:host0")
        p0.wait_active(), p1.wait_active()
        cu = s.submit_cu(executable="slow2", pilot=p1, max_retries=3)
        time.sleep(0.15)
        p1.fail()  # crash: heartbeats stop, store untouched
        assert cu.wait(timeout=30) == CUState.DONE
        assert cu.pilot_id == p0.id  # recovered elsewhere
        assert p1.id in s.heartbeat_monitor.failures
        assert s.pilot_states()[p1.id] == PilotState.FAILED


def test_straggler_duplication_exactly_once(topo):
    with Session(
        topology=topo,
        enable_straggler_mitigation=True,
        straggler_factor=2.0,
    ) as s:
        s.straggler_mitigator.min_samples = 2

        def fast(cu_ctx):
            time.sleep(0.02)
            return "fast"

        slow_calls = []

        def sometimes_slow(cu_ctx):
            # slow only on the straggler pilot
            slow_calls.append(1)
            if len(slow_calls) == 1:
                time.sleep(1.5)
            return len(slow_calls)

        FUNCTIONS.register("fast", fast)
        FUNCTIONS.register("sometimes_slow", sometimes_slow)
        p0 = s.start_pilot(resource_url="sim://cluster:pod0:host0", slots=2)
        p1 = s.start_pilot(resource_url="sim://cluster:pod1:host0", slots=2)
        p0.wait_active(), p1.wait_active()
        for _ in range(3):
            assert s.submit_cu(executable="fast").wait() == CUState.DONE
        cu = s.submit_cu(executable="sometimes_slow", pilot=p0)
        assert cu.wait(timeout=30) == CUState.DONE
        assert cu.id in s.straggler_mitigator.duplicates
        # winner CAS: exactly one completion recorded
        assert s.store.hget(f"cu:{cu.id}", "winner") is not None


def test_walltime_requeues(topo):
    with Session(topology=topo) as s:

        def sleepy(cu_ctx):
            time.sleep(0.3)
            return 1

        FUNCTIONS.register("sleepy", sleepy)
        p_short = s.start_pilot(
            resource_url="sim://cluster:pod0:host0", walltime_s=0.1
        )
        p_long = s.start_pilot(resource_url="sim://cluster:pod1:host0")
        p_short.wait_active(), p_long.wait_active()
        cu = s.submit_cu(executable="sleepy", pilot=p_short, max_retries=3)
        assert cu.wait(timeout=30) == CUState.DONE
        # The short pilot retired; someone (usually p_long) finished the CU.
        assert s.pilot_states()[p_short.id] == PilotState.DONE


def test_pd_quota(sess):
    pd = sess.start_pilot_data(
        service_url="mem://cluster:pod0:host0/tiny",
        affinity="cluster:pod0:host0",
        size_quota=10,
    )
    du = sess.submit_du(name="toolarge", files={"a": b"x" * 100}, target=None)
    with pytest.raises(QuotaExceeded):
        pd.put_du(du.du)


def test_replication_strategies_on_live_pds(sess):
    src = sess.start_pilot_data(
        service_url="sharedfs://cluster:pod0/src", affinity="cluster:pod0"
    )
    targets = [
        sess.start_pilot_data(
            service_url=f"mem://cluster:pod1:host{h}/repl",
            affinity=f"cluster:pod1:host{h}",
        )
        for h in range(2)
    ]
    du = sess.submit_du(
        name="data", files={"blob": b"r" * (1 << 16)}, target=src
    ).result()
    t_grp = replicate_group(du, src, targets, sess.ctx)
    assert all(t.has_du(du.id) for t in targets)
    assert all(t.verify_du(du) for t in targets)
    assert set(du.locations) == {src.id, *[t.id for t in targets]}
    # sequential on fresh targets for comparison
    targets2 = [
        sess.start_pilot_data(
            service_url=f"mem://cluster:pod1:host{h}/repl2",
            affinity=f"cluster:pod1:host{h}",
        )
        for h in range(2)
    ]
    t_seq = replicate_sequential(du, src, targets2, sess.ctx)
    assert t_grp <= t_seq + 1e-9


def test_demand_replicator(sess):
    src = sess.start_pilot_data(
        service_url="sharedfs://cluster:pod0/src2", affinity="cluster:pod0"
    )
    pod1_pd = sess.start_pilot_data(
        service_url="sharedfs://cluster:pod1/cache", affinity="cluster:pod1"
    )
    du = sess.submit_du(
        name="popular", files={"b": b"p" * 1024}, target=src
    ).result()
    rep = DemandReplicator(sess.ctx, threshold=2)
    rep.observe_staging(du, "cluster:pod1:host0")
    assert rep.maybe_replicate(du, "cluster:pod1:host0", [pod1_pd]) is None
    rep.observe_staging(du, "cluster:pod1:host1")
    t = rep.maybe_replicate(du, "cluster:pod1:host1", [pod1_pd])
    assert t is not None and pod1_pd.has_du(du.id)


def test_reconnect_second_manager_sees_state(topo):
    """A second client attached to the same store resolves CU/pilot state
    (the paper's re-connect-via-URL semantics)."""
    with Session(topology=topo) as s:
        _register_echo()
        p = s.start_pilot(resource_url="sim://cluster:pod0:host0")
        p.wait_active()
        cu = s.submit_cu(executable="echo")
        assert cu.wait() == CUState.DONE
        with PilotManager(topology=topo, store=s.store) as m2:
            assert m2.cu_states()[cu.id] == CUState.DONE
            assert m2.pilot_states()[p.id] == PilotState.ACTIVE


def test_store_outage_survival(topo):
    with Session(topology=topo) as s:
        _register_echo()
        p = s.start_pilot(resource_url="sim://cluster:pod0:host0")
        p.wait_active()
        s.store.fail_for(0.2)  # transient outage mid-flight
        cu = None
        # submission may need to ride out the outage
        deadline = time.monotonic() + 5
        while cu is None and time.monotonic() < deadline:
            try:
                cu = s.submit_cu(executable="echo")
            except Exception:
                time.sleep(0.05)
        assert cu is not None
        assert cu.wait(timeout=20) == CUState.DONE
