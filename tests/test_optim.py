"""Optimizer substrate: AdamW, schedules, clipping, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim import (
    AdamWConfig,
    adamw_update,
    clip_by_global_norm,
    dequantize_int8,
    global_norm,
    init_adamw,
    quantize_int8,
    warmup_cosine,
)
from repro.optim.schedules import constant, linear_decay


def test_adamw_converges_on_quadratic():
    """min ||x - t||²: AdamW must reach the target."""
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    cfg = AdamWConfig(weight_decay=0.0)
    state = init_adamw(params, cfg)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum((p["x"] - target) ** 2))(params)
        return adamw_update(grads, state, params, jnp.float32(0.05), cfg)

    for _ in range(400):
        params, state = step(params, state)
    np.testing.assert_allclose(params["x"], target, atol=1e-2)


def test_adamw_mixed_precision_master_drives_bf16():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    cfg = AdamWConfig()
    state = init_adamw(params, cfg)
    assert state["master"]["w"].dtype == jnp.float32
    grads = {"w": jnp.full((4,), 1e-3, jnp.float32)}
    new_params, new_state = adamw_update(grads, state, params, jnp.float32(1e-3), cfg)
    assert new_params["w"].dtype == jnp.bfloat16
    # master moved even though the bf16 cast may round
    assert (new_state["master"]["w"] != state["master"]["w"]).all()
    assert int(new_state["step"]) == 1


def test_clip_by_global_norm():
    grads = {"a": jnp.ones((3,)) * 100.0}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(100.0 * 3**0.5, rel=1e-5)
    small = {"a": jnp.ones((3,)) * 1e-3}
    out, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(out["a"], small["a"])  # untouched


def test_schedules_shapes():
    steps = jnp.arange(0, 1000, 50)
    lr = warmup_cosine(steps, 1e-3, warmup_steps=100, total_steps=1000)
    assert float(lr[0]) == 0.0
    assert float(lr[2]) == pytest.approx(1e-3, rel=1e-5)  # step 100: peak
    assert float(lr[-1]) > 0  # final_frac floor
    assert (lr[2:] <= lr[2] + 1e-9).all()  # non-increasing after peak
    assert float(constant(jnp.int32(5), 1e-4)) == pytest.approx(1e-4)
    lr2 = linear_decay(jnp.float32(1000), 1e-3, 100, 1000)
    assert float(lr2) == pytest.approx(0.0, abs=1e-8)


@settings(max_examples=30, deadline=None)
@given(scale=st.floats(1e-4, 1e3), seed=st.integers(0, 1000))
def test_prop_quantize_roundtrip_bounded(scale, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) / 2 + 1e-9  # half-ULP of the quant grid


def test_error_feedback_reduces_bias():
    """With error feedback, the accumulated compressed sum tracks the true
    sum over steps (residual carried forward)."""
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (128,)) * 0.01
    err = jnp.zeros_like(x)
    acc_c, acc_t = jnp.zeros_like(x), jnp.zeros_like(x)
    for i in range(20):
        xi = x * (1 + 0.1 * i)
        q, s = quantize_int8(xi + err)
        deq = dequantize_int8(q, s)
        err = (xi + err) - deq
        acc_c += deq
        acc_t += xi
    # residual is bounded by one quantization step, not 20
    assert float(jnp.abs(acc_c - acc_t).max()) <= float(s) + 1e-9
