"""Property tests for the sharded coordination plane.

The contracts under test:

  * per-subscriber delivery order equals ``StoreEvent.seq`` order — and
    seq order is consistent with per-key mutation order — under writers
    racing across shards;
  * a prefix subscription sees exactly the matching subsequence of the
    store-wide event stream;
  * ``keys()``/``hkeys()`` bisect range scans agree with a brute-force
    reference model under arbitrary mutate/delete interleavings;
  * the group-commit WAL round-trips: a crash (no ``close()``) loses at
    most the unflushed tail, an explicit flush makes everything written so
    far replayable, and ``close()`` loses nothing.
"""

import threading

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.coordination import CoordinationStore


# ------------------------------------------------ delivery-order property
@settings(max_examples=25, deadline=None)
@given(
    n_writers=st.integers(min_value=2, max_value=4),
    n_ops=st.integers(min_value=5, max_value=60),
    shards=st.sampled_from([1, 4, 16]),
)
def test_delivery_order_equals_seq_order_under_racing_writers(
    n_writers, n_ops, shards
):
    store = CoordinationStore(shards=shards)
    all_seen = []
    cu_seen = []
    store.subscribe(all_seen.append, prefix="")
    store.subscribe(cu_seen.append, prefix="cu:")
    prefixes = ["cu:", "du:", "pilot:", "pd:"]
    barrier = threading.Barrier(n_writers)

    def writer(tid: int):
        barrier.wait()
        for i in range(n_ops):
            # each writer owns its keys: per-key order is its program order
            key = f"{prefixes[(tid + i) % len(prefixes)]}w{tid}-{i % 3}"
            store.hset(key, "state", (tid, i))

    threads = [threading.Thread(target=writer, args=(t,)) for t in range(n_writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert store.flush_events()

    # store-wide total order: strictly increasing seq, no drops, no dups
    seqs = [ev.seq for ev in all_seen]
    assert seqs == sorted(seqs)
    assert len(set(seqs)) == len(seqs)
    assert len(all_seen) == n_writers * n_ops

    # the prefix subscriber saw exactly the matching subsequence, in order
    expect_cu = [ev for ev in all_seen if ev.key.startswith("cu:")]
    assert [(ev.seq, ev.key, ev.value) for ev in cu_seen] == [
        (ev.seq, ev.key, ev.value) for ev in expect_cu
    ]

    # per-key: seq order is consistent with the owning writer's program
    # order (each key is written by exactly one thread)
    per_key = {}
    for ev in all_seen:
        per_key.setdefault(ev.key, []).append(ev.value)
    for key, values in per_key.items():
        assert values == sorted(values), f"per-key order violated on {key}"


@settings(max_examples=20, deadline=None)
@given(
    pushes=st.lists(
        st.tuples(st.sampled_from(["q:a", "q:b", "q:c"]), st.integers()),
        min_size=1,
        max_size=40,
    ),
    shards=st.sampled_from([1, 8]),
)
def test_queue_events_and_fifo_agree_with_reference(pushes, shards):
    store = CoordinationStore(shards=shards)
    seen = []
    store.subscribe(seen.append, prefix="q:")
    model = {}
    for q, v in pushes:
        store.push(q, v)
        model.setdefault(q, []).append(v)
    store.flush_events()
    assert [(ev.key, ev.value) for ev in seen] == pushes
    for q, expected in model.items():
        drained = [store.pop(q) for _ in range(len(expected))]
        assert drained == expected
        assert store.pop(q) is None


# -------------------------------------------------- prefix-scan property
_key = st.tuples(
    st.sampled_from(["cu:", "du:", "pilot:", "pd:", ""]),
    st.text(alphabet="abc0", min_size=0, max_size=3),
).map(lambda t: t[0] + t[1])


@settings(max_examples=60, deadline=None)
@given(
    ops=st.lists(
        st.tuples(st.sampled_from(["set", "delete", "hset", "hdel"]), _key),
        max_size=60,
    ),
    probe=st.sampled_from(["", "cu:", "du:", "pilot:p", "a"]),
    shards=st.sampled_from([1, 4, 16]),
)
def test_prefix_scans_agree_with_reference_model(ops, probe, shards):
    store = CoordinationStore(shards=shards)
    kv, hashes = set(), set()
    for op, key in ops:
        if op == "set":
            store.set(key, 1)
            kv.add(key)
        elif op == "delete":
            store.delete(key)
            kv.discard(key)
        elif op == "hset":
            store.hset(key, "f", 1)
            hashes.add(key)
        else:
            store.hdel(key, "f")  # hash record survives (legacy semantics)
    assert store.keys(probe) == sorted(k for k in kv if k.startswith(probe))
    assert store.hkeys(probe) == sorted(k for k in hashes if k.startswith(probe))


# ------------------------------------------- WAL group-commit round-trip
def _apply(store, ops):
    for op, key, val in ops:
        if op == "set":
            store.set(key, val)
        elif op == "hset":
            store.hset(key, "state", val)
        else:
            store.push(key, val)


_wal_op = st.tuples(
    st.sampled_from(["set", "hset", "push"]),
    st.sampled_from(["cu:a", "du:b", "q:c", "pilot:d"]),
    st.integers(min_value=0, max_value=99),
)


@settings(max_examples=25, deadline=None)
@given(
    ops=st.lists(_wal_op, min_size=1, max_size=50),
    wal_batch=st.sampled_from([1, 7, 64]),
)
def test_wal_group_commit_crash_replay_roundtrip(tmp_path_factory, ops, wal_batch):
    tmp = tmp_path_factory.mktemp("wal")
    path = str(tmp / "wal.log")
    store = CoordinationStore(wal_path=path, wal_batch=wal_batch)
    _apply(store, ops)
    store.flush_wal()  # group commit: everything so far becomes durable
    _apply(store, [("set", "cu:tail", -1)])  # may sit in the buffer

    # crash: no close(). Replay what reached disk — a prefix of the op
    # stream containing at least everything before the explicit flush
    # (the background flusher may or may not have caught the tail).
    survivor = CoordinationStore(wal_path=path, replay=True, shards=4)
    got = survivor.snapshot()
    survivor.close()

    reference = CoordinationStore()
    _apply(reference, ops)
    without_tail = reference.snapshot()
    _apply(reference, [("set", "cu:tail", -1)])
    with_tail = reference.snapshot()
    reference.close()
    assert got in (without_tail, with_tail)

    # clean close after more ops loses nothing
    _apply(store, [("hset", "du:final", 7)])
    store.close()
    replayed = CoordinationStore(wal_path=path, replay=True)
    assert replayed.hget("du:final", "state") == 7
    assert replayed.get("cu:tail") == -1
    replayed.close()


def test_wal_replay_equals_snapshot_across_shard_counts(tmp_path):
    """The WAL format is shard-agnostic: a log written by a 16-shard store
    replays identically into a 1-shard store and vice versa."""
    path = str(tmp_path / "wal.log")
    store = CoordinationStore(wal_path=path, shards=16, wal_batch=32)
    _apply(
        store,
        [("set", f"cu:{i}", i) for i in range(25)]
        + [("hset", f"du:{i}", i) for i in range(25)]
        + [("push", "q:a", i) for i in range(5)],
    )
    snap = store.snapshot()
    store.close()
    replayed = CoordinationStore(wal_path=path, replay=True, shards=1)
    assert replayed.snapshot() == snap
    replayed.close()
