"""Pilot-API v2 dataflow semantics: declarative sessions, typed futures,
DU-readiness gating, and the failure cascade.

Covers the edge cases the redesign exists for: whole DAGs submitted in one
shot (no user-side waits), diamond dependencies, consumers submitted before
their producers, multi-output CUs, failed producers cancelling downstream
waiters with a clear error, identical release ordering across scheduler
modes, and the output-DU failure path (partial writes never leak into a
retry or a FAILED CU's outputs)."""

import threading
import time

import pytest

from repro.core import (
    ComputeFailedError,
    CUState,
    DataUnitDescription,
    DataUnitFailedError,
    DUState,
    FUNCTIONS,
    FutureTimeoutError,
    PilotManager,
    Session,
    Topology,
    gather,
)

SITE_A, SITE_B = "grid:sitea", "grid:siteb"


def _topo() -> Topology:
    topo = Topology()
    topo.register(SITE_A, bandwidth=20e6, latency=0.05)
    topo.register(SITE_B, bandwidth=20e6, latency=0.05)
    return topo


@pytest.fixture(params=["sync", "async"])
def sess(request):
    with Session(topology=_topo(), scheduler_mode=request.param) as s:
        yield s


def _register_wordlen_pipeline():
    """map: uppercase each input file; reduce: total byte count."""

    def mapper(cu_ctx):
        for du in cu_ctx.input_dus():
            for rel in du.manifest:
                cu_ctx.write_output(rel, cu_ctx.read_input(du.id, rel).upper())
        return "mapped"

    def reducer(cu_ctx):
        total = 0
        for du in cu_ctx.input_dus():
            for rel in du.manifest:
                data = cu_ctx.read_input(du.id, rel)
                assert data == data.upper()  # upstream really ran first
                total += len(data)
        if cu_ctx.cu.description.output_data:
            cu_ctx.write_output("total", str(total).encode())
        return total

    FUNCTIONS.register("df-map", mapper)
    FUNCTIONS.register("df-reduce", reducer)


# --------------------------------------------------------------- happy DAGs
def test_three_stage_dag_one_shot(sess):
    """map → shuffle → reduce submitted upfront, wired by object; the
    runtime alone sequences the stages (acceptance criterion for both
    scheduler modes via the fixture)."""
    _register_wordlen_pipeline()
    sess.start_pilot_data(service_url=f"mem://{SITE_B}/pd", affinity=SITE_B)
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=2)
    p.wait_active()
    parts = [
        sess.submit_du(name=f"part{i}", files={f"p{i}": b"ab" * (100 + i)})
        for i in range(3)
    ]
    maps = [
        sess.submit_cu(
            executable="df-map",
            input_data=[part],
            output_data=[DataUnitDescription(name=f"inter{i}")],
        )
        for i, part in enumerate(parts)
    ]
    shuffle = sess.submit_cu(
        executable="df-map",
        input_data=[m.output for m in maps],
        output_data=[DataUnitDescription(name="shuffled")],
    )
    reduce_ = sess.submit_cu(
        executable="df-reduce",
        input_data=[shuffle.output],
        output_data=[DataUnitDescription(name="result")],
    )
    # no user-side waits above this line
    expected = sum(2 * (100 + i) for i in range(3))
    assert reduce_.result(timeout=60) == expected
    assert [m.result() for m in maps] == ["mapped"] * 3
    out = reduce_.output.result()
    assert out.sealed and out.state == DUState.READY
    pd = sess.ctx.lookup(out.locations[0])
    assert pd.fetch_du_file(out.id, "total") == str(expected).encode()


def test_diamond_dag(sess):
    """A → (B, C) → D: D must observe both branch outputs."""
    _register_wordlen_pipeline()
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=2)
    p.wait_active()
    src = sess.submit_du(name="src", files={"x": b"seed-bytes"})
    a = sess.submit_cu(
        executable="df-map",
        input_data=[src],
        output_data=[DataUnitDescription(name="a-out")],
    )
    b = sess.submit_cu(
        executable="df-map",
        input_data=[a.output],
        output_data=[DataUnitDescription(name="b-out")],
    )
    c = sess.submit_cu(
        executable="df-map",
        input_data=[a.output],
        output_data=[DataUnitDescription(name="c-out")],
    )
    d = sess.submit_cu(
        executable="df-reduce",
        input_data=[b.output, c.output],
        output_data=[DataUnitDescription(name="d-out")],
    )
    assert d.result(timeout=60) == 2 * len(b"seed-bytes")


def test_consumer_submitted_before_producer(sess):
    """The ISSUE's race: a consumer must park in Waiting, not stage an
    unsealed DU immediately."""
    _register_wordlen_pipeline()
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=2)
    p.wait_active()
    placeholder = sess.create_du(name="future-data")
    consumer = sess.submit_cu(executable="df-reduce", input_data=[placeholder])
    deadline = time.monotonic() + 5
    while consumer.state != CUState.WAITING and time.monotonic() < deadline:
        time.sleep(0.005)
    assert consumer.state == CUState.WAITING
    assert consumer.id in sess.cds.deps.waiting()
    src = sess.submit_du(name="late-src", files={"f": b"xyz"})
    sess.submit_cu(
        executable="df-map", input_data=[src], output_data=[placeholder]
    )
    assert consumer.result(timeout=60) == 3
    assert consumer.id not in sess.cds.deps.waiting()


def test_multi_output_cu(sess):
    def splitter(cu_ctx):
        cu_ctx.write_output("evens", b"02468", index=0)
        cu_ctx.write_output("odds", b"13579", index=1)
        return "split"

    FUNCTIONS.register("df-split", splitter)
    _register_wordlen_pipeline()
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=2)
    p.wait_active()
    split = sess.submit_cu(
        executable="df-split",
        output_data=[
            DataUnitDescription(name="evens"),
            DataUnitDescription(name="odds"),
        ],
    )
    consumers = [
        sess.submit_cu(executable="df-reduce", input_data=[out])
        for out in split.outputs
    ]
    assert gather(consumers, timeout=60) == [5, 5]
    assert {o.result().manifest.popitem()[0] for o in split.outputs} == {
        "evens",
        "odds",
    }


# ------------------------------------------------------------ failure paths
def test_failed_producer_fails_downstream_waiters(sess):
    _register_wordlen_pipeline()

    def boom(cu_ctx):
        cu_ctx.write_output("half", b"junk")  # partial write, then crash
        raise RuntimeError("disk on fire")

    FUNCTIONS.register("df-boom", boom)
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=2)
    p.wait_active()
    bad = sess.submit_cu(
        executable="df-boom",
        max_retries=0,
        output_data=[DataUnitDescription(name="bad-out")],
    )
    mid = sess.submit_cu(
        executable="df-map",
        input_data=[bad.output],
        output_data=[DataUnitDescription(name="mid-out")],
    )
    leaf = sess.submit_cu(executable="df-reduce", input_data=[mid.output])
    # the whole downstream chain fails with the upstream cause in the error
    with pytest.raises(ComputeFailedError, match="disk on fire"):
        mid.result(timeout=30)
    with pytest.raises(ComputeFailedError, match="failed"):
        leaf.result(timeout=30)
    assert leaf.state == CUState.FAILED
    # the failed producer's output DU: FAILED, unsealed, and NO partial
    # content leaked from the failed attempt
    with pytest.raises(DataUnitFailedError):
        bad.output.result(timeout=5)
    assert bad.output.state == DUState.FAILED
    assert not bad.output.sealed
    assert bad.output.manifest == {}
    # workload is fully terminal: session wait returns promptly
    assert sess.wait(timeout=10)


def test_input_already_failed_fails_at_submit(sess):
    _register_wordlen_pipeline()

    FUNCTIONS.register("df-boom2", lambda cu_ctx: 1 / 0)
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=1)
    p.wait_active()
    bad = sess.submit_cu(
        executable="df-boom2",
        max_retries=0,
        output_data=[DataUnitDescription(name="bad2-out")],
    )
    bad.wait(timeout=30)
    late = sess.submit_cu(executable="df-reduce", input_data=[bad.output])
    assert late.state == CUState.FAILED
    assert "failed" in late.error


def test_cancel_waiting_consumer_and_cascade(sess):
    _register_wordlen_pipeline()
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=1)
    p.wait_active()
    placeholder = sess.create_du(name="never-coming")
    consumer = sess.submit_cu(
        executable="df-map",
        input_data=[placeholder],
        output_data=[DataUnitDescription(name="consumer-out")],
    )
    deadline = time.monotonic() + 5
    while consumer.state != CUState.WAITING and time.monotonic() < deadline:
        time.sleep(0.005)
    consumer.cancel()
    assert consumer.state == CUState.CANCELED
    # cancellation cascades: its own output DU fails so *its* consumers
    # are released too instead of hanging
    assert consumer.output.state == DUState.FAILED
    with pytest.raises(ComputeFailedError, match="canceled"):
        consumer.result(timeout=5)


def test_retry_does_not_append_onto_partial_outputs(sess):
    """Regression (ISSUE satellite): a CU that raises after partial
    write_output calls must not leave half-written files for the retry to
    append onto — the final output contains exactly the winning attempt's
    files."""
    attempts = []

    def flaky_writer(cu_ctx):
        attempts.append(1)
        if len(attempts) == 1:
            cu_ctx.write_output("stale-partial", b"BAD")
            raise IOError("transient")
        cu_ctx.write_output("good", b"GOOD")
        return len(attempts)

    FUNCTIONS.register("df-flaky-writer", flaky_writer)
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=1)
    p.wait_active()
    cu = sess.submit_cu(
        executable="df-flaky-writer",
        max_retries=2,
        output_data=[DataUnitDescription(name="flaky-out")],
    )
    assert cu.result(timeout=60) == 2
    out = cu.output.result()
    assert out.manifest == {"good": 4}  # no 'stale-partial' leak
    assert out.sealed
    pd = sess.ctx.lookup(out.locations[0])
    assert pd.fetch_du_file(out.id, "good") == b"GOOD"


def test_sealed_du_rejected_as_output(sess):
    _register_wordlen_pipeline()
    src = sess.submit_du(name="sealed-src", files={"a": b"x"})
    sess.start_pilot_data(service_url=f"mem://{SITE_A}/pd", affinity=SITE_A)
    du = sess.submit_du(name="sealed", files={"b": b"y"}).result()
    if not du.sealed:
        du.seal()
    with pytest.raises(ValueError, match="sealed"):
        sess.submit_cu(
            executable="df-map", input_data=[src], output_data=[du]
        )


def test_output_du_is_single_writer(sess):
    _register_wordlen_pipeline()
    out = sess.create_du(name="contested")
    sess.submit_cu(executable="df-map", output_data=[out])
    with pytest.raises(ValueError, match="single-writer"):
        sess.submit_cu(executable="df-map", output_data=[out])


def test_unknown_input_du_rejected_without_zombie(sess):
    """Regression: a submission rejected for a bad data reference must
    leave NO tracked non-terminal CU (which would wedge wait() forever)
    and NO orphaned producer claim on output DUs."""
    from repro.core import ComputeUnitDescription

    _register_wordlen_pipeline()
    out = sess.create_du(name="clean-out")
    with pytest.raises(KeyError, match="unknown input DU"):
        sess.cds.submit_compute_unit(
            ComputeUnitDescription(
                executable="df-map",
                input_data=["du-does-not-exist"],
                output_data=[out.id],
            )
        )
    t0 = time.monotonic()
    assert sess.wait(timeout=5)  # no zombie CU poisons the wait
    assert time.monotonic() - t0 < 1.0
    # the output DU was not claimed by the rejected CU: a corrected
    # resubmission may still produce it
    assert sess.store.hget(f"du:{out.id}", "producer") is None
    src = sess.submit_du(name="ok-src", files={"a": b"zz"})
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=1)
    p.wait_active()
    cu = sess.submit_cu(
        executable="df-map", input_data=[src], output_data=[out]
    )
    assert cu.result(timeout=30) == "mapped"


# ------------------------------------------------------------ release order
def test_sync_and_async_release_ordering_identical():
    """The DU-readiness gate releases consumers in DU-materialization
    order, and both scheduler modes share one gate implementation — with
    producer completion order pinned externally, the release sequences
    must match across modes."""
    _register_wordlen_pipeline()
    completion_order = [2, 0, 3, 1]

    def run(mode):
        gates = [threading.Event() for _ in range(4)]

        def gated_producer(cu_ctx, i):
            assert gates[i].wait(timeout=30)
            cu_ctx.write_output("out", bytes([i]) * 16)
            return i

        FUNCTIONS.register("df-gated", gated_producer)
        with Session(topology=_topo(), scheduler_mode=mode) as s:
            p = s.start_pilot(resource_url=f"sim://{SITE_A}", slots=4)
            p.wait_active()
            tags = {}
            consumers = []
            for i in range(4):
                prod = s.submit_cu(
                    executable="df-gated",
                    args=(i,),
                    output_data=[DataUnitDescription(name=f"o{i}")],
                )
                cons = s.submit_cu(
                    executable="df-reduce", input_data=[prod.output]
                )
                tags[cons.id] = f"consumer-{i}"
                consumers.append(cons)
            for i in completion_order:
                gates[i].set()
                time.sleep(0.3)  # let seal → release settle before the next
            assert s.wait(timeout=60)
            assert all(c.state == CUState.DONE for c in consumers)
            return [tags[c] for c in s.cds.deps.release_log if c in tags]

    order_sync = run("sync")
    order_async = run("async")
    assert order_sync == [f"consumer-{i}" for i in completion_order]
    assert order_sync == order_async


# ------------------------------------------------------- futures & shims
def test_future_api_surface(sess):
    _register_wordlen_pipeline()
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=1)
    p.wait_active()
    src = sess.submit_du(name="fsrc", files={"a": b"abc"})
    cu = sess.submit_cu(
        executable="df-map",
        input_data=[src],
        output_data=[DataUnitDescription(name="fout")],
    )
    hits = []
    cu.add_done_callback(lambda f: hits.append(("cu", f.done())))
    cu.output.add_done_callback(lambda f: hits.append(("du", f.done())))
    assert cu.result(timeout=30) == "mapped"
    deadline = time.monotonic() + 5
    while len(hits) < 2 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert sorted(hits) == [("cu", True), ("du", True)]
    # a callback added after completion fires immediately, on the caller
    late = []
    cu.add_done_callback(lambda f: late.append(threading.get_ident()))
    assert late and cu.done()
    # timeout semantics
    stuck = sess.submit_cu(
        executable="df-map", input_data=[sess.create_du(name="never")]
    )
    with pytest.raises(FutureTimeoutError):
        stuck.result(timeout=0.1)
    stuck.cancel()


def test_v1_shims_warn_and_still_work():
    _register_echo = FUNCTIONS.register("df-echo", lambda cu_ctx: "v1")
    with PilotManager(topology=_topo()) as m:
        p = m.start_pilot(resource_url=f"sim://{SITE_A}", slots=1)
        p.wait_active()
        with pytest.warns(DeprecationWarning, match="Pilot-API v1"):
            du = m.submit_du(name="v1du", files={"a": b"z" * 64})
        with pytest.warns(DeprecationWarning, match="Pilot-API v1"):
            cu = m.submit_cu(executable="df-echo", input_data=[du.id])
        assert cu.wait(timeout=30) == CUState.DONE
        assert cu.result == "v1"  # v1 handle: result is the attribute


def test_empty_source_du_does_not_gate(sess):
    """Regression: a v1-style empty DU from submit_du (no files, no
    producer) is vacuously consumable — only explicit create_du
    placeholders and declared outputs gate consumers."""
    _register_wordlen_pipeline()
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=1)
    p.wait_active()
    empty = sess.submit_du(name="empty-src")
    cu = sess.submit_cu(executable="df-reduce", input_data=[empty])
    assert cu.result(timeout=30) == 0


def test_empty_session_wait_returns_immediately():
    with Session(topology=_topo()) as s:
        t0 = time.monotonic()
        assert s.wait(timeout=5)
        assert time.monotonic() - t0 < 1.0
