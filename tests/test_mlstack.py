"""ML stack on the modern runtime: one-shot training DAGs, runtime-healed
checkpoint DUs, tier-cached serving cold-start, streaming-shard prefetch.

These are the integration contracts of the ML-stack refactor:

  * the trainer submits the WHOLE chunk DAG through the Session API before
    any chunk runs, and sync/async scheduler modes produce identical
    training trajectories (the data path is mode-independent);
  * checkpoint DUs carry ``replication_factor`` and survive a mid-run
    pilot kill purely through the runtime's ReplicaManager;
  * serving replicas cold-start through the mem-tier cache;
  * a Waiting chunk CU's already-ready shard input is speculatively
    prefetched while its checkpoint producer still runs.
"""

import threading
import time

import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.configs.base import reduced
from repro.core import FUNCTIONS, Session, Topology, make_tpu_fleet_topology
from repro.serving import params_from_input
from repro.training.trainer import PilotTrainer

TINY = dict(
    total_steps=6,
    chunk_steps=2,
    batch=4,
    seq=32,
    peak_lr=3e-3,
    n_shards=2,
    tokens_per_shard=4_000,
)


def tiny_cfg():
    return reduced(
        get_config("h2o-danube-1.8b"),
        n_layers=2,
        d_model=32,
        n_heads=2,
        n_kv_heads=1,
        d_ff=64,
        vocab_size=128,
        head_dim=16,
    )


def _two_pod_session(**kw) -> Session:
    topo, _ = make_tpu_fleet_topology(pods=2, hosts_per_pod=1)
    return Session(topology=topo, **kw)


def _start_fleet(s: Session):
    s.start_pilot_data(
        service_url="sharedfs://cluster:pod0/s0", affinity="cluster:pod0"
    )
    s.start_pilot_data(
        service_url="sharedfs://cluster:pod1/s1", affinity="cluster:pod1"
    )
    p0 = s.start_pilot(resource_url="sim://cluster:pod0:host0", slots=1)
    p1 = s.start_pilot(resource_url="sim://cluster:pod1:host0", slots=1)
    p0.wait_active(), p1.wait_active()
    return p0, p1


# ------------------------------------------------------------ one-shot DAG
def test_trainer_submits_whole_dag_upfront():
    with _two_pod_session() as s:
        _start_fleet(s)
        tr = PilotTrainer(tiny_cfg(), s, run_name="m-dag", **TINY)
        tr.stage_data(affinities=["cluster:pod0", "cluster:pod1"])
        chunks = tr.submit_dag()
        # all three chunk CUs exist before any result is collected, and the
        # tail of the chain cannot be done yet (its ckpt producer is still
        # unsealed) — submission really was one shot, not submit-wait
        assert len(chunks) == 3
        assert not chunks[-1][3].done()
        for _, _, _, cu in chunks:
            assert cu.result(timeout=300)["losses"]
        # every chunk's output sealed: the checkpoint chain is complete
        assert all(cu.output.sealed for _, _, _, cu in chunks)


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_oneshot_dag_trains_in_both_modes(mode):
    with _two_pod_session(scheduler_mode=mode) as s:
        _start_fleet(s)
        tr = PilotTrainer(tiny_cfg(), s, run_name=f"m-{mode}", **TINY)
        tr.stage_data(affinities=["cluster:pod0", "cluster:pod1"])
        summary = tr.run()
        assert summary["steps"] == TINY["total_steps"]
        assert summary["improved"], summary
        assert len(tr.ckpt_dus) == summary["chunks"] + 1


def test_sync_async_training_trajectories_identical():
    """The streaming shard reader cuts step-indexed windows, so the data a
    chunk sees cannot depend on scheduling mode — byte-identical losses."""

    def run(mode):
        with _two_pod_session(scheduler_mode=mode) as s:
            _start_fleet(s)
            tr = PilotTrainer(tiny_cfg(), s, run_name=f"m-par-{mode}", **TINY)
            tr.stage_data(affinities=["cluster:pod0", "cluster:pod1"])
            return [h["losses"] for h in tr.run()["history"]]

    assert run("sync") == run("async")


# ------------------------------------------------- healed checkpoint chain
def test_checkpoint_chain_heals_and_survives_pilot_kill():
    """Kill a pilot mid-run: the chunk replays from the surviving
    checkpoint replica (replication_factor=2 + ReplicaManager), the run
    completes, and the FULL step count is restorable from the catalog."""
    with _two_pod_session(enable_fault_manager=True, heartbeat_timeout_s=0.5) as s:
        p0, p1 = _start_fleet(s)
        tr = PilotTrainer(tiny_cfg(), s, run_name="m-kill", ckpt_replication=2, **TINY)
        tr.stage_data(affinities=["cluster:pod0", "cluster:pod1"])
        killer = threading.Timer(1.0, p0.fail)
        killer.start()
        try:
            summary = tr.run(timeout_per_chunk=600)
        finally:
            killer.cancel()
        assert summary["improved"], summary
        # the dead pilot is not the only one that ever ran a chunk
        assert p1.id in summary["pilots_used"]
        # the checkpoint catalog restores the final step from a replica
        # that survived the kill
        ck = Checkpointer(s, run_name="m-kill")
        assert ck.latest_step() == TINY["total_steps"]
        step, params, opt = ck.restore()
        assert step == TINY["total_steps"]
        assert "embed" in params and opt is not None


# ------------------------------------------------- tier-cached serving
def test_serving_cold_start_hits_tier_cache():
    """Repeated weight loads at one site promote the checkpoint DU into
    the site's mem-tier cache; later replicas stage from the hot copy."""
    topo = Topology()
    topo.register("tier:site0", bandwidth=10e6, latency=0.01)
    topo.register("tier:site1", bandwidth=10e6, latency=0.01)
    with Session(
        topology=topo,
        tier_cache_bytes=64 * 1024 * 1024,
        tier_auto_promote=False,  # drained explicitly: deterministic
    ) as s:
        cold = s.start_pilot_data(
            service_url="sharedfs://tier:site1/cold", affinity="tier:site1"
        )
        pilot = s.start_pilot(resource_url="sim://tier:site0", slots=1)
        pilot.wait_active()
        weights = {"w": np.arange(4096, dtype=np.float32), "b": np.ones(8)}
        ck = Checkpointer(s, run_name="m-serve")
        du = ck.save(0, weights, target=cold)

        def load_weights(cu_ctx, weights_du):
            p = params_from_input(cu_ctx, weights_du)
            return float(p["w"].sum() + p["b"].sum())

        FUNCTIONS.register("m-serve-load", load_weights)
        expect = float(weights["w"].sum() + weights["b"].sum())
        tm = s.tier_manager
        for _ in range(2):  # two cold-start loads at site0
            cu = s.submit_cu(
                executable="m-serve-load",
                args=(du.id,),
                input_data=[du],
                pilot=pilot,
            )
            assert cu.result(timeout=60) == expect
        tm.drain_promotions()
        assert tm.promotions_total >= 1
        cache_ids = {pd.id for pd in tm.cache_pds.values()}
        assert cache_ids & set(du.locations), (
            f"ckpt DU not promoted into a mem-tier cache: {du.locations}"
        )
        # the NEXT replica's weight load still verifies end-to-end
        cu = s.submit_cu(
            executable="m-serve-load",
            args=(du.id,),
            input_data=[du],
            pilot=pilot,
        )
        assert cu.result(timeout=60) == expect


# ------------------------------------- speculative prefetch while Waiting
def test_waiting_chunk_prefetch_overlaps_producer_compute():
    """A CU parked Waiting on its checkpoint producer gets its READY shard
    input staged toward the predicted winner while the producer is still
    running — the stage-in no longer serializes behind the chain."""
    topo = Topology()
    topo.register("ov:site0", bandwidth=2e6, latency=0.01)
    topo.register("ov:site1", bandwidth=2e6, latency=0.01)
    with Session(topology=topo, scheduler_mode="async", time_scale=0.05) as s:
        s.start_pilot_data(service_url="sharedfs://ov:site1/data", affinity="ov:site1")
        pilot = s.start_pilot(resource_url="sim://ov:site0", slots=1)
        pilot.wait_active()
        shard = s.submit_du(
            name="ov-shard",
            files={"x.bin": b"\x01" * (256 * 1024)},
            chunk_size=32 * 1024,
        )
        FUNCTIONS.register("ov-produce", lambda cu_ctx: cu_ctx.write_output("w", b"k"))
        FUNCTIONS.register(
            "ov-consume",
            lambda cu_ctx: sum(
                len(cu_ctx.read_input(d.id, rel))
                for d in cu_ctx.input_dus()
                for rel in d.manifest
            ),
        )
        # consumer needs BOTH the big ready shard and the producer's output
        producer = s.submit_cu(
            executable="ov-produce",
            sim_compute_s=20.0,  # 1s wall at time_scale=0.05
            output_data=[_desc("ov-ckpt")],
        )
        t_done = {}
        producer.add_done_callback(lambda f: t_done.setdefault("t", time.monotonic()))
        consumer = s.submit_cu(
            executable="ov-consume",
            input_data=[shard, producer.output],
        )
        assert consumer.result(timeout=120) == 256 * 1024 + 1
        ts = s.ctx.transfer_service
        spec = [
            r
            for r in ts.records()
            if r.du_id == shard.id and r.dst_pd == pilot.sandbox.id
        ]
        assert spec, "shard never staged into the winner sandbox"
        assert "t" in t_done
        # the earliest shard transfer began BEFORE the producer finished:
        # stage-in overlapped the producer's (simulated) compute
        assert min(r.wall_start for r in spec) < t_done["t"], (
            f"no overlap: first shard transfer at "
            f"{min(r.wall_start for r in spec)}, producer done {t_done['t']}"
        )


def _desc(name):
    from repro.core import DataUnitDescription

    return DataUnitDescription(name=name)
