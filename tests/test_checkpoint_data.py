"""Checkpointer-as-DU + data pipeline tests."""

import threading
import time

import numpy as np
import pytest

from repro.checkpoint import (
    Checkpointer,
    CheckpointError,
    CheckpointTimeout,
)
from repro.core import (
    DUState,
    PilotManager,
    make_tpu_fleet_topology,
)
from repro.data import (
    Prefetcher,
    ShardReader,
    decode_raw_tokens,
    decode_tokens,
    encode_raw_tokens,
    encode_tokens,
    make_token_shards,
    shard_dus,
)


@pytest.fixture()
def mgr():
    topo, _ = make_tpu_fleet_topology(pods=2, hosts_per_pod=2)
    m = PilotManager(topology=topo)
    yield m
    m.shutdown()


@pytest.fixture()
def healing_mgr():
    topo, _ = make_tpu_fleet_topology(pods=2, hosts_per_pod=2)
    m = PilotManager(topology=topo, enable_fault_manager=True, heartbeat_timeout_s=0.5)
    yield m
    m.shutdown()


def test_token_roundtrip():
    t = np.arange(100, dtype=np.int32)
    assert (decode_tokens(encode_tokens(t)) == t).all()


def test_raw_token_roundtrip_and_prefix_decode():
    t = np.arange(100, dtype=np.int32)
    data = encode_raw_tokens(t)
    assert (decode_raw_tokens(data) == t).all()
    # any byte prefix decodes to a token prefix (the chunk-stream property)
    assert (decode_raw_tokens(data[: 4 * 17]) == t[:17]).all()
    assert (decode_raw_tokens(data[: 4 * 17 + 3]) == t[:17]).all()


def test_make_token_shards_shapes():
    shards = make_token_shards(3, 1000, vocab_size=50, files_per_shard=2)
    assert len(shards) == 3
    for files in shards:
        assert len(files) == 2
        total = sum(len(decode_tokens(d)) for d in files.values())
        assert total == 1000
        for d in files.values():
            toks = decode_tokens(d)
            assert toks.min() >= 0 and toks.max() < 50


def test_make_token_shards_raw_format():
    shards = make_token_shards(2, 800, vocab_size=32, fmt="raw")
    for files in shards:
        assert all(rel.endswith(".bin") for rel in files)
        total = sum(len(decode_raw_tokens(d)) for d in files.values())
        assert total == 800
    with pytest.raises(ValueError):
        make_token_shards(1, 100, vocab_size=8, fmt="parquet")


def test_shard_reader_batches():
    shards = make_token_shards(1, 2000, vocab_size=64)
    reader = ShardReader(shards[0], seed=1)
    it = reader.batches(batch=4, seq=32)
    b1 = next(it)
    assert b1["tokens"].shape == (4, 32) and b1["labels"].shape == (4, 32)
    # next-token alignment
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()


def test_shard_reader_resume_matches_continuation():
    """batches(start_step=k) replays the SAME data an uninterrupted run
    sees at step k — the checkpoint/restart determinism contract."""
    shards = make_token_shards(1, 3000, vocab_size=64)
    full = ShardReader(shards[0], seed=7).batches(batch=2, seq=16)
    straight = [next(full) for _ in range(6)]
    resumed_it = ShardReader(shards[0], seed=7).batches(batch=2, seq=16, start_step=3)
    resumed = [next(resumed_it) for _ in range(3)]
    for a, b in zip(straight[3:], resumed):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["labels"], b["labels"])


def test_prefetcher_order_and_close():
    pf = Prefetcher(iter(range(10)), depth=3)
    assert list(pf) == list(range(10))
    pf2 = Prefetcher(iter(range(1000)), depth=2)
    next(pf2)
    pf2.close()


def test_prefetcher_close_reclaims_blocked_producer():
    """Regression: an abandoned iterator with depth=1 leaves the producer
    parked in a full-queue put; close() must still reclaim the thread."""
    pf = Prefetcher(iter(range(10_000)), depth=1)
    next(pf)  # producer now blocked on the full queue
    time.sleep(0.05)
    pf.close()
    assert not pf._thread.is_alive()
    # and closing is idempotent / iteration after close terminates
    pf.close()
    with pytest.raises(StopIteration):
        next(pf)


def test_prefetcher_close_without_consuming():
    before = threading.active_count()
    readers = [Prefetcher(iter(range(100)), depth=1) for _ in range(8)]
    for r in readers:
        r.close()
    deadline = time.monotonic() + 2.0
    while time.monotonic() < deadline and threading.active_count() > before:
        time.sleep(0.01)
    assert all(not r._thread.is_alive() for r in readers)


def test_prefetcher_propagates_errors():
    def gen():
        yield 1
        raise ValueError("boom")

    pf = Prefetcher(gen(), depth=2)
    assert next(pf) == 1
    with pytest.raises(ValueError):
        list(pf)


def test_shard_dus_affinity_roundrobin(mgr):
    shards = make_token_shards(4, 500, vocab_size=32)
    dus = shard_dus(shards, mgr.store, affinities=["cluster:pod0", "cluster:pod1"])
    assert [du.affinity for du in dus] == [
        "cluster:pod0",
        "cluster:pod1",
        "cluster:pod0",
        "cluster:pod1",
    ]


def test_checkpoint_save_restore_roundtrip(mgr):
    pd = mgr.start_pilot_data(
        service_url="sharedfs://cluster:pod0/ck", affinity="cluster:pod0"
    )
    params = {"layer": {"w": np.ones((4, 4), np.float32) * 3}}
    opt = {"step": np.int32(7), "m": {"layer": {"w": np.zeros((4, 4), np.float32)}}}
    ck = Checkpointer(mgr.ctx, run_name="r1")
    du = ck.save(7, params, opt, target=pd)
    assert du.state == DUState.READY
    step, p2, o2 = ck.restore()
    assert step == 7
    np.testing.assert_array_equal(p2["layer"]["w"], params["layer"]["w"])
    assert int(o2["step"]) == 7


def test_checkpoint_healed_across_pods(healing_mgr):
    """replication_factor=2 + seal → the runtime's ReplicaManager disperses
    the checkpoint across failure domains; no checkpoint-layer code."""
    mgr = healing_mgr
    pd0 = mgr.start_pilot_data(
        service_url="sharedfs://cluster:pod0/ck", affinity="cluster:pod0"
    )
    pd1 = mgr.start_pilot_data(
        service_url="sharedfs://cluster:pod1/ck", affinity="cluster:pod1"
    )
    ck = Checkpointer(mgr.session, run_name="r2", replication_factor=2)
    du = ck.save(1, {"w": np.zeros((2,), np.float32)})
    deadline = time.monotonic() + 10
    while time.monotonic() < deadline and len(du.locations) < 2:
        time.sleep(0.02)
    assert set(du.locations) == {pd0.id, pd1.id}
    # pod-local read resolves to the pod-local replica
    step, params, _ = ck.restore(location="cluster:pod1:host0")
    assert step == 1


def test_checkpoint_async(mgr):
    pd = mgr.start_pilot_data(
        service_url="mem://cluster:pod0:host0/ck", affinity="cluster:pod0:host0"
    )
    ck = Checkpointer(mgr.ctx, run_name="r3")
    du = ck.save(2, {"w": np.ones((8,), np.float32)}, target=pd, asynchronous=True)
    ck.wait()
    assert du.state == DUState.READY
    assert ck.latest_step() == 2


def test_checkpoint_async_failure_surfaces_on_wait(mgr):
    """Regression: a failed async commit (quota-starved ingest target) must
    raise from wait(), not vanish in a daemon thread."""
    tiny = mgr.start_pilot_data(
        service_url="mem://cluster:pod0:host0/tiny",
        affinity="cluster:pod0:host0",
        size_quota=16,  # a few-KB checkpoint can never ingest
    )
    ck = Checkpointer(mgr.ctx, run_name="r4")
    ck.save(1, {"w": np.ones((64,), np.float32)}, target=tiny, asynchronous=True)
    with pytest.raises(CheckpointError):
        ck.wait(timeout=10)
    # the failure is consumed: a later wait with nothing pending is clean
    ck.wait(timeout=1)


def test_checkpoint_async_failure_surfaces_on_next_save(mgr):
    tiny = mgr.start_pilot_data(
        service_url="mem://cluster:pod0:host0/tiny2",
        affinity="cluster:pod0:host0",
        size_quota=16,
    )
    good = mgr.start_pilot_data(
        service_url="sharedfs://cluster:pod0/ok", affinity="cluster:pod0"
    )
    ck = Checkpointer(mgr.ctx, run_name="r5")
    ck.save(1, {"w": np.ones((64,), np.float32)}, target=tiny, asynchronous=True)
    deadline = time.monotonic() + 10
    while time.monotonic() < deadline and not all(f.done() for f in ck._pending):
        time.sleep(0.02)
    with pytest.raises(CheckpointError):
        ck.save(2, {"w": np.ones((64,), np.float32)}, target=good)
    # error consumed — the next save proceeds normally
    du = ck.save(2, {"w": np.ones((64,), np.float32)}, target=good)
    assert du.state == DUState.READY


def test_checkpoint_wait_raises_on_timeout(mgr):
    pd = mgr.start_pilot_data(
        service_url="sharedfs://cluster:pod0/slow", affinity="cluster:pod0"
    )
    ck = Checkpointer(mgr.ctx, run_name="r6")
    release = threading.Event()
    orig_ingest = mgr.ctx.transfer_service.ingest

    def slow_ingest(du, dst, **kw):
        release.wait(timeout=30)
        return orig_ingest(du, dst, **kw)

    mgr.ctx.transfer_service.ingest = slow_ingest
    try:
        ck.save(1, {"w": np.zeros((4,), np.float32)}, target=pd, asynchronous=True)
        with pytest.raises(CheckpointTimeout):
            ck.wait(timeout=0.2)
        release.set()
        ck.wait(timeout=10)  # the still-pending commit stays waitable
        assert ck.latest_step() == 1
    finally:
        mgr.ctx.transfer_service.ingest = orig_ingest
        release.set()
        ck.close()
