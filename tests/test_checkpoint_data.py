"""Checkpointer-as-DU + data pipeline tests."""

import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.core import (
    DUState,
    PilotManager,
    make_tpu_fleet_topology,
)
from repro.data import (
    Prefetcher,
    ShardReader,
    decode_tokens,
    encode_tokens,
    make_token_shards,
    shard_dus,
)


@pytest.fixture()
def mgr():
    topo, _ = make_tpu_fleet_topology(pods=2, hosts_per_pod=2)
    m = PilotManager(topology=topo)
    yield m
    m.shutdown()


def test_token_roundtrip():
    t = np.arange(100, dtype=np.int32)
    assert (decode_tokens(encode_tokens(t)) == t).all()


def test_make_token_shards_shapes():
    shards = make_token_shards(3, 1000, vocab_size=50, files_per_shard=2)
    assert len(shards) == 3
    for files in shards:
        assert len(files) == 2
        total = sum(len(decode_tokens(d)) for d in files.values())
        assert total == 1000
        for d in files.values():
            toks = decode_tokens(d)
            assert toks.min() >= 0 and toks.max() < 50


def test_shard_reader_batches():
    shards = make_token_shards(1, 2000, vocab_size=64)
    reader = ShardReader(shards[0], seed=1)
    it = reader.batches(batch=4, seq=32)
    b1 = next(it)
    assert b1["tokens"].shape == (4, 32) and b1["labels"].shape == (4, 32)
    # next-token alignment
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()


def test_prefetcher_order_and_close():
    pf = Prefetcher(iter(range(10)), depth=3)
    assert list(pf) == list(range(10))
    pf2 = Prefetcher(iter(range(1000)), depth=2)
    next(pf2)
    pf2.close()


def test_prefetcher_propagates_errors():
    def gen():
        yield 1
        raise ValueError("boom")

    pf = Prefetcher(gen(), depth=2)
    assert next(pf) == 1
    with pytest.raises(ValueError):
        list(pf)


def test_shard_dus_affinity_roundrobin(mgr):
    shards = make_token_shards(4, 500, vocab_size=32)
    dus = shard_dus(
        shards, mgr.store, affinities=["cluster:pod0", "cluster:pod1"]
    )
    assert [du.affinity for du in dus] == [
        "cluster:pod0", "cluster:pod1", "cluster:pod0", "cluster:pod1",
    ]


def test_checkpoint_save_restore_roundtrip(mgr):
    pd = mgr.start_pilot_data(
        service_url="sharedfs://cluster:pod0/ck", affinity="cluster:pod0"
    )
    params = {"layer": {"w": np.ones((4, 4), np.float32) * 3}}
    opt = {"step": np.int32(7), "m": {"layer": {"w": np.zeros((4, 4), np.float32)}}}
    ck = Checkpointer(mgr.ctx, run_name="r1")
    du = ck.save(7, params, opt, target=pd)
    assert du.state == DUState.READY
    step, p2, o2 = ck.restore()
    assert step == 7
    np.testing.assert_array_equal(p2["layer"]["w"], params["layer"]["w"])
    assert int(o2["step"]) == 7


def test_checkpoint_replicated_across_pods(mgr):
    pd0 = mgr.start_pilot_data(
        service_url="sharedfs://cluster:pod0/ck", affinity="cluster:pod0"
    )
    pd1 = mgr.start_pilot_data(
        service_url="sharedfs://cluster:pod1/ck", affinity="cluster:pod1"
    )
    ck = Checkpointer(mgr.ctx, run_name="r2", replicate_to=[pd1])
    du = ck.save(1, {"w": np.zeros((2,), np.float32)}, target=pd0)
    assert set(du.locations) == {pd0.id, pd1.id}
    # pod-local read resolves to the pod-local replica
    step, params, _ = ck.restore(location="cluster:pod1:host0")
    assert step == 1


def test_checkpoint_async(mgr):
    pd = mgr.start_pilot_data(
        service_url="mem://cluster:pod0:host0/ck", affinity="cluster:pod0:host0"
    )
    ck = Checkpointer(mgr.ctx, run_name="r3")
    du = ck.save(2, {"w": np.ones((8,), np.float32)}, target=pd, asynchronous=True)
    ck.wait()
    assert du.state == DUState.READY
    assert ck.latest_step() == 2
