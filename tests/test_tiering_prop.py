"""Property tests for eviction invariants: under arbitrary interleavings
of put / read / pin / evict operations, a sealed DU never loses the last
copy of any chunk, a DU never drops below its replication factor, pinned
inputs are never evicted, and every PD ends each step within its quota."""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core import (
    CoordinationStore,
    DataUnit,
    DataUnitDescription,
    PilotData,
    PilotDataDescription,
    QuotaExceeded,
    RuntimeContext,
    TierManager,
    Topology,
    TransferService,
    Victim,
    list_eviction_policies,
    make_eviction_policy,
)

CHUNK = 64
DU_CHUNKS = 4
DU_BYTES = DU_CHUNKS * CHUNK
N_DUS = 4


def _build(policy: str):
    topo = Topology()
    topo.register("p:base", bandwidth=30e6, latency=0.01)
    topo.register("p:edge", bandwidth=30e6, latency=0.01)
    ctx = RuntimeContext(store=CoordinationStore(), topology=topo)
    TransferService(ctx)
    tm = TierManager(ctx, eviction_policy=policy, auto_promote=False)
    base = ctx.register(
        PilotData(
            PilotDataDescription(service_url="sharedfs://p:base/b", affinity="p:base"),
            ctx,
        )
    )
    cache = ctx.register(
        PilotData(
            PilotDataDescription(
                service_url="mem://p:edge/c",
                affinity="p:edge",
                # holds the factor=2 resident plus ~1.5 more DUs, so
                # copying the rest of the working set forces churn
                size_quota=2 * DU_BYTES + 2 * CHUNK,
            ),
            ctx,
        )
    )
    dus = []
    for i in range(N_DUS):
        du = ctx.register(
            DataUnit(
                DataUnitDescription(
                    name=f"p{i}",
                    files={"x": bytes([i + 1]) * DU_BYTES},
                    chunk_size=CHUNK,
                    # one DU carries factor=2: both copies load-bearing
                    replication_factor=2 if i == 0 else 1,
                ),
                ctx.store,
            )
        )
        base.put_du(du)
        dus.append(du)
    cache.copy_du_from(dus[0], base)  # factor=2 DU starts at its factor
    return ctx, tm, base, cache, dus


_op = st.one_of(
    st.tuples(st.just("copy"), st.integers(0, N_DUS - 1)),
    st.tuples(st.just("pin"), st.integers(0, N_DUS - 1)),
    st.tuples(st.just("unpin"), st.integers(0, N_DUS - 1)),
    st.tuples(st.just("access"), st.integers(0, N_DUS - 1)),
    st.tuples(st.just("evict_cache"), st.integers(1, 2 * DU_BYTES)),
    st.tuples(st.just("evict_base"), st.integers(1, 2 * DU_BYTES)),
)


@settings(max_examples=40, deadline=None)
@given(
    ops=st.lists(_op, min_size=1, max_size=25),
    policy=st.sampled_from(["lru", "lfu", "largest-first"]),
)
def test_eviction_invariants_under_interleavings(ops, policy):
    ctx, tm, base, cache, dus = _build(policy)
    ts = ctx.transfer_service
    pinned_snapshots = {}
    for op, arg in ops:
        if op == "copy":
            du = dus[arg]
            try:
                # multi-source heal: works from partial holders too (an
                # earlier evict_base may have demoted the base replica)
                ts.heal_replica(du, cache)
            except QuotaExceeded:
                pass  # invariants forbade enough eviction: acceptable
        elif op == "pin":
            du = dus[arg]
            ctx.store.hset(f"cu:c{arg}", "state", "Running")
            tm.pins.pin(du.id, f"c{arg}")
            pinned_snapshots[du.id] = {
                pd_id: set(idxs)
                for pd_id, idxs in du.chunk_holders().items()
            }
        elif op == "unpin":
            du = dus[arg]
            tm.pins.unpin_owner(f"c{arg}")
            pinned_snapshots.pop(du.id, None)
        elif op == "access":
            ts._note_access(dus[arg], "p:edge")
        elif op == "evict_cache":
            tm.make_room(cache, arg)
        elif op == "evict_base":
            tm.make_room(base, arg)

        # ---- invariants, after every single operation ----
        for du in dus:
            # a sealed DU never loses the last copy of any chunk: the
            # union of all registered holders still covers every chunk
            assert du.has_full_coverage(), (op, du.id)
            # never below the declared replication factor
            assert len(du.locations) >= du.replication_factor, (op, du.id)
        for pd in (base, cache):
            assert pd.used_bytes <= pd.description.size_quota
            # local accounting agrees with the store-side registry for
            # registered holdings
            for du in dus:
                registered = set(du.chunk_holders().get(pd.id, []))
                assert registered <= set(pd.chunks_held(du.id))
        # pinned DUs keep every chunk they had at pin time, per holder
        for du_id, snapshot in pinned_snapshots.items():
            now = {
                pd_id: set(idxs)
                for pd_id, idxs in ctx.store.hgetall(f"du:{du_id}:chunks").items()
            }
            for pd_id, idxs in snapshot.items():
                assert idxs <= now.get(pd_id, set()), (op, du_id, pd_id)
    tm.stop()


@settings(max_examples=60, deadline=None)
@given(
    victims=st.lists(
        st.builds(
            Victim,
            du_id=st.text(alphabet="abcdef", min_size=1, max_size=4),
            indices=st.just([0]),
            nbytes=st.integers(1, 10_000),
            last_access=st.integers(0, 100),
            access_count=st.integers(0, 100),
        ),
        max_size=8,
    ),
    policy=st.sampled_from(["lru", "lfu", "largest-first"]),
)
def test_policies_are_deterministic_permutations(victims, policy):
    p = make_eviction_policy(policy)
    ranked = p.rank(None, victims)
    assert sorted(v.du_id for v in ranked) == sorted(v.du_id for v in victims)
    assert [v.du_id for v in p.rank(None, victims)] == [v.du_id for v in ranked]


def test_policy_registry_is_complete():
    for name in list_eviction_policies():
        assert make_eviction_policy(name).name == name
