"""pdlint — the concurrency-contract static analyzer: one bad/good fixture
pair per rule, suppression comments, CLI exit codes, and the self-check
that the shipped tree is clean."""

import os
import subprocess
import sys
from pathlib import Path

from repro.analysis.model import build_project
from repro.analysis.pdlint import (
    EXIT_CLEAN,
    EXIT_ERROR,
    EXIT_FINDINGS,
    main,
    run,
)
from repro.analysis.rules import list_rules

ROOT = Path(__file__).resolve().parent.parent
CORE = ROOT / "src" / "repro" / "core"
ANALYSIS = ROOT / "src" / "repro" / "analysis"


def lint(tmp_path, sources, select=None):
    paths = []
    for name, src in sources.items():
        p = tmp_path / name
        p.write_text(src, encoding="utf-8")
        paths.append(p)
    findings, _ = run(paths, select=select)
    return findings


MINI_STORE_PREFIX = """\
import threading


class MiniStore:
    def __init__(self):
        self._lock = threading.Lock()
        self.kv = {}

    def hset(self, key, field, value):
        self.kv[(key, field)] = value

    def push(self, name, item):
        self.kv.setdefault(name, []).append(item)

    def pop_any(self, names, timeout=None):
        return None

    def get(self, key, default=None):
        return self.kv.get(key, default)
"""


# ------------------------------------------------------------------ PD-L001
def test_l001_store_op_under_store_lock(tmp_path):
    bad = MINI_STORE_PREFIX + """
    def rebalance(self):
        with self._lock:
            return self.get("cursor")
"""
    findings = lint(tmp_path, {"bad.py": bad}, select=["PD-L001"])
    assert [f.rule for f in findings] == ["PD-L001"]
    assert "self.get()" in findings[0].message

    good = MINI_STORE_PREFIX + """
    def rebalance(self):
        with self._lock:
            cursor_key = "cursor"
        return self.get(cursor_key)
"""
    assert lint(tmp_path, {"good.py": good}, select=["PD-L001"]) == []


# ------------------------------------------------------------------ PD-L002
L002_BAD = """\
import threading
import time

_lock = threading.Lock()


def tick():
    with _lock:
        time.sleep(0.1)


def _wait_for_disk():
    time.sleep(0.5)


def drain():
    with _lock:
        _wait_for_disk()
"""


def test_l002_blocking_under_lock_direct_and_transitive(tmp_path):
    findings = lint(tmp_path, {"bad.py": L002_BAD}, select=["PD-L002"])
    assert len(findings) == 2
    direct, transitive = findings
    assert "time.sleep" in direct.message
    assert "_wait_for_disk()" in transitive.message  # via the call graph

    good = """\
import threading
import time

_lock = threading.Lock()


def tick():
    with _lock:
        deadline = 0.1
    time.sleep(deadline)
"""
    assert lint(tmp_path, {"good.py": good}, select=["PD-L002"]) == []


# ------------------------------------------------------------------ PD-L003
def test_l003_mutating_subscriber_callback(tmp_path):
    bad = """\
class Listener:
    def __init__(self, store):
        self.store = store
        self.store.subscribe(self._on_event)

    def _on_event(self, ev):
        self.store.hset("seen", ev.key, 1)
"""
    findings = lint(tmp_path, {"bad.py": bad}, select=["PD-L003"])
    assert [f.rule for f in findings] == ["PD-L003"]
    assert "store.hset" in findings[0].message

    good = """\
import queue


class Listener:
    def __init__(self, store):
        self.store = store
        self.q = queue.Queue()
        self.store.subscribe(self._on_event)

    def _on_event(self, ev):
        self.q.put(ev)  # hand off to our own thread: sanctioned
"""
    assert lint(tmp_path, {"good.py": good}, select=["PD-L003"]) == []


# ------------------------------------------------------------------ PD-L004
def test_l004_mutate_then_read_without_barrier(tmp_path):
    bad = """\
class StateCache:
    def __init__(self, store):
        self.store = store
        self._state = None
        store.subscribe(self._on_event)

    def _on_event(self, ev):
        self._state = ev.value

    def poll(self):
        self.store.hset("pilot:1", "state", "ACTIVE")
        return self._state
"""
    findings = lint(tmp_path, {"bad.py": bad}, select=["PD-L004"])
    assert [f.rule for f in findings] == ["PD-L004"]
    assert "'_state'" in findings[0].message
    assert "store.hset" in findings[0].message

    good = bad.replace(
        '        self.store.hset("pilot:1", "state", "ACTIVE")\n',
        '        self.store.hset("pilot:1", "state", "ACTIVE")\n'
        "        self.store.flush_events()\n",
    )
    assert lint(tmp_path, {"good.py": good}, select=["PD-L004"]) == []


# ------------------------------------------------------------------ PD-L005
def test_l005_same_file_inversion(tmp_path):
    bad = """\
import threading

lock_a = threading.Lock()
lock_b = threading.Lock()


def forward():
    with lock_a:
        with lock_b:
            pass


def backward():
    with lock_b:
        with lock_a:
            pass
"""
    findings = lint(tmp_path, {"bad.py": bad}, select=["PD-L005"])
    assert len(findings) == 1
    assert "lock-order inversion" in findings[0].message
    assert "lock_a" in findings[0].message and "lock_b" in findings[0].message
    # the hint carries both witnessing sites so the trace is actionable
    assert "forward()" in findings[0].hint and "backward()" in findings[0].hint

    good = bad.replace(
        "with lock_b:\n        with lock_a:",
        "with lock_a:\n        with lock_b:",
    )
    assert lint(tmp_path, {"good.py": good}, select=["PD-L005"]) == []


def test_l005_cross_module_inversion(tmp_path):
    left = """\
import threading

from right import Right


class Left:
    def __init__(self):
        self._lock = threading.Lock()
        self.right = Right()

    def poke(self):
        with self._lock:
            self.right.absorb()
"""
    right = """\
import threading


class Right:
    def __init__(self):
        self._lock = threading.Lock()

    def absorb(self):
        with self._lock:
            pass

    def kick(self, left: "Left"):
        with self._lock:
            left.poke()
"""
    findings = lint(
        tmp_path, {"left.py": left, "right.py": right}, select=["PD-L005"]
    )
    cycles = [f for f in findings if "lock-order inversion" in f.message]
    assert cycles, findings
    assert "Left._lock" in cycles[0].message
    assert "Right._lock" in cycles[0].message


# ------------------------------------------------------------------ PD-L006
def test_l006_scan_materialization_under_stripe(tmp_path):
    bad = MINI_STORE_PREFIX + """
    def keys(self, prefix=""):
        out = []
        with self._lock:
            out.extend(sorted(self.kv))
        return out
"""
    findings = lint(tmp_path, {"bad.py": bad}, select=["PD-L006"])
    assert {f.rule for f in findings} == {"PD-L006"}
    assert any("sorted()" in f.message for f in findings)

    good = MINI_STORE_PREFIX + """
    def keys(self, prefix=""):
        with self._lock:
            part = list(self.kv)
        return sorted(part)
"""
    assert lint(tmp_path, {"good.py": good}, select=["PD-L006"]) == []


# -------------------------------------------------------------- suppression
def test_suppression_trailing_comment(tmp_path):
    src = L002_BAD.replace(
        "        time.sleep(0.1)",
        "        time.sleep(0.1)  # pdlint: disable=PD-L002",
    )
    findings = lint(tmp_path, {"s.py": src}, select=["PD-L002"])
    assert [f.line for f in findings] == [18]  # only the transitive one left


def test_suppression_preceding_comment_line(tmp_path):
    src = L002_BAD.replace(
        "        time.sleep(0.1)",
        "        # pdlint: disable=PD-L002\n        time.sleep(0.1)",
    )
    findings = lint(tmp_path, {"s.py": src}, select=["PD-L002"])
    assert all("_wait_for_disk" in f.message for f in findings)


def test_suppression_wrong_rule_is_ignored(tmp_path):
    src = L002_BAD.replace(
        "        time.sleep(0.1)",
        "        time.sleep(0.1)  # pdlint: disable=PD-L001",
    )
    findings = lint(tmp_path, {"s.py": src}, select=["PD-L002"])
    assert len(findings) == 2  # PD-L001 token does not silence PD-L002


# ---------------------------------------------------------------------- CLI
def _cli(*args, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.pdlint", *args],
        capture_output=True,
        text=True,
        env=env,
        cwd=cwd or ROOT,
        timeout=120,
    )


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(L002_BAD, encoding="utf-8")
    good = tmp_path / "good.py"
    good.write_text("x = 1\n", encoding="utf-8")

    proc = _cli(str(good))
    assert proc.returncode == EXIT_CLEAN, proc.stderr
    proc = _cli(str(bad))
    assert proc.returncode == EXIT_FINDINGS
    assert "PD-L002" in proc.stdout
    proc = _cli(str(tmp_path / "missing.py"))
    assert proc.returncode == EXIT_ERROR
    proc = _cli("--select", "PD-L999", str(good))
    assert proc.returncode == EXIT_ERROR
    proc = _cli()  # no paths
    assert proc.returncode == EXIT_ERROR


def test_cli_markdown_summary(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(L002_BAD, encoding="utf-8")
    out = tmp_path / "summary.md"
    proc = _cli("--markdown", str(out), str(bad))
    assert proc.returncode == EXIT_FINDINGS
    text = out.read_text(encoding="utf-8")
    assert "| rule |" in text and "PD-L002" in text


def test_cli_parse_error_exits_2(tmp_path):
    broken = tmp_path / "broken.py"
    broken.write_text("def f(:\n", encoding="utf-8")
    proc = _cli(str(broken))
    assert proc.returncode == EXIT_ERROR
    assert "parse error" in proc.stderr


def test_list_rules_covers_all_contracts():
    expected = {
        "PD-L001",
        "PD-L002",
        "PD-L003",
        "PD-L004",
        "PD-L005",
        "PD-L006",
    }
    assert set(list_rules()) == expected
    proc = _cli("--list-rules")
    assert proc.returncode == EXIT_CLEAN
    assert set(proc.stdout.split()) == expected


# ---------------------------------------------------------------- self-check
def test_shipped_tree_is_clean():
    """The contracts hold on the codebase that defines them (unsuppressed
    findings here mean a regression slipped into the coordination plane)."""
    findings, project = run([CORE, ANALYSIS], select=None)
    assert project.errors == []
    assert findings == [], "\n".join(f.format() for f in findings)


def test_in_process_main_matches_run(capsys, tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(L002_BAD, encoding="utf-8")
    assert main([str(bad)]) == EXIT_FINDINGS
    out = capsys.readouterr().out
    assert "PD-L002" in out


def test_project_model_sees_store_classes():
    project = build_project([CORE / "coordination.py"])
    assert "CoordinationStore" in project.store_classes
