"""Chunk-streaming dataflow: prefix-released consumers, chunk-granular
stage-in, exactly-once streamed publishes, read-frontier eviction, and
the windowed shuffle operator.

The invariants under test (ISSUE tentpole):

  * a consumer of a streaming DU is released at ``ready_chunks`` published
    chunks — before the producer seals — and map/reduce genuinely overlap;
  * a released prefix-consumer never observes a chunk gap (chunks are
    registered in the producer's sandbox before the publish event fires);
  * exactly-once survives streaming: a failed producer attempt leaves zero
    published chunks behind, a duplicate attempt racing a live stream
    writer publishes nothing, and a dead writer's claim is stolen with the
    half-written stream rolled back to zero;
  * streamed chunks are evictable only below every live consumer's read
    frontier (the backpressure valve).
"""

import threading
import time

import pytest

from repro.core import (
    ComputeFailedError,
    CoordinationStore,
    CUState,
    DataUnit,
    DataUnitDescription,
    FUNCTIONS,
    PilotData,
    PilotDataDescription,
    PilotState,
    RuntimeContext,
    Session,
    TierManager,
    Topology,
    TransferService,
)
from repro.data import decode_records, windowed_shuffle

SITE_A = "grid:sitea"
CSIZE = 1024  # streaming chunk size used throughout


def _topo() -> Topology:
    t = Topology()
    t.register(SITE_A, bandwidth=20e6, latency=0.01)
    return t


@pytest.fixture(params=["sync", "async"])
def sess(request):
    with Session(topology=_topo(), scheduler_mode=request.param) as s:
        yield s


def _chunk_producer(tag: str, n_chunks: int, gates=None):
    """Register a producer that streams ``n_chunks`` one flush at a time,
    optionally blocking on ``gates[i]`` after publishing chunk i."""

    def producer(cu_ctx):
        for i in range(n_chunks):
            cu_ctx.write_output(f"f{i:03d}", bytes([65 + i]) * CSIZE, index=0)
            assert cu_ctx.flush_output(0)
            if gates is not None and i in gates:
                assert gates[i].wait(timeout=30)
        return n_chunks

    FUNCTIONS.register(tag, producer)
    return producer


# ----------------------------------------------------- prefix release
def test_consumer_released_at_prefix_before_seal(sess):
    """The tentpole: the consumer starts (and consumes) while the producer
    is still mid-stream — sealing happens strictly after the consumer has
    observed the first chunks."""
    gate = threading.Event()
    sealed_at_first_chunk = []

    _chunk_producer("stream-prod-overlap", 4, gates={1: gate})

    def consumer(cu_ctx):
        du_id = cu_ctx.cu.description.input_data[0]
        du = cu_ctx.ctx.lookup(du_id)
        total, order = 0, []
        for idx, chunk in cu_ctx.stream_input(du_id, window=2):
            if idx == 0:
                sealed_at_first_chunk.append(du.sealed)
                gate.set()  # producer may proceed past chunk 1
            order.append(idx)
            total += len(chunk)
        assert order == sorted(order) and len(order) == len(set(order))
        return total

    FUNCTIONS.register("stream-cons-overlap", consumer)
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=2)
    p.wait_active()
    out = sess.create_streaming_du(name="overlap", ready_chunks=2, chunk_size=CSIZE)
    prod = sess.submit_cu(executable="stream-prod-overlap", output_data=[out])
    cons = sess.submit_cu(executable="stream-cons-overlap", input_data=[out])
    assert cons.result(timeout=60) == 4 * CSIZE
    assert prod.result(timeout=10) == 4
    assert sealed_at_first_chunk == [False]  # genuine overlap, not seal-gated
    du = out.result(timeout=10)
    assert du.sealed and du.n_chunks == 4 and out.published == 4


def test_consumer_parks_until_ready_chunks_published(sess):
    """Readiness threshold: with ready_chunks=2 the consumer stays Waiting
    after the first publish and is released by the second."""
    g0, g1 = threading.Event(), threading.Event()
    _chunk_producer("stream-prod-gate", 3, gates={0: g0, 1: g1})
    def count_bytes(cu_ctx):
        du_id = cu_ctx.cu.description.input_data[0]
        return sum(len(c) for _i, c in cu_ctx.stream_input(du_id))

    FUNCTIONS.register("stream-cons-count", count_bytes)
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=2)
    p.wait_active()
    out = sess.create_streaming_du(name="gated", ready_chunks=2, chunk_size=CSIZE)
    sess.submit_cu(executable="stream-prod-gate", output_data=[out])
    deadline = time.monotonic() + 10
    while out.published < 1 and time.monotonic() < deadline:
        time.sleep(0.005)
    assert out.published == 1  # producer parked on g0 after one publish
    cons = sess.submit_cu(executable="stream-cons-count", input_data=[out])
    deadline = time.monotonic() + 5
    while cons.state != CUState.WAITING and time.monotonic() < deadline:
        time.sleep(0.005)
    assert cons.state == CUState.WAITING  # 1 < ready_chunks=2: still parked
    g0.set()  # second chunk publishes -> threshold met -> release
    deadline = time.monotonic() + 10
    while cons.state == CUState.WAITING and time.monotonic() < deadline:
        time.sleep(0.005)
    assert cons.state != CUState.WAITING
    g1.set()
    assert cons.result(timeout=60) == 3 * CSIZE


def test_wait_prefix_and_progress_callbacks(sess):
    gate = threading.Event()
    _chunk_producer("stream-prod-prefix", 3, gates={1: gate})
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=1)
    p.wait_active()
    out = sess.create_streaming_du(name="prefix", ready_chunks=1, chunk_size=CSIZE)
    progress = []
    out.add_prefix_callback(lambda fut, n: progress.append(n))
    cu = sess.submit_cu(executable="stream-prod-prefix", output_data=[out])
    assert out.wait_prefix(2, timeout=30) >= 2
    assert not cu.done()  # producer still parked mid-stream
    gate.set()
    assert cu.result(timeout=30) == 3
    assert out.wait_prefix(3, timeout=10) == 3  # satisfied post-seal too
    deadline = time.monotonic() + 5
    while (not progress or progress[-1] < 3) and time.monotonic() < deadline:
        time.sleep(0.01)
    assert progress == sorted(progress) and progress[-1] == 3


def test_ready_fraction_resolves_against_size_hint(sess):
    out = sess.create_streaming_du(
        name="frac",
        ready_fraction=0.5,
        size_hint=4 * CSIZE,
        chunk_size=CSIZE,
    )
    assert out.du.stream_threshold == 2
    with pytest.raises(ValueError, match="streaming"):
        sess.create_streaming_du(name="bad", streaming=False)


# ------------------------------------------------------- exactly-once
def test_failed_attempt_publishes_zero_chunks(sess):
    """A producer attempt that crashes mid-stream is rolled back: the
    retry streams from zero and the final DU holds ONLY the winning
    attempt's bytes."""
    attempts = []

    def flaky(cu_ctx):
        attempts.append(1)
        if len(attempts) == 1:
            cu_ctx.write_output("bad0", b"B" * CSIZE)
            cu_ctx.write_output("bad1", b"B" * CSIZE)
            assert cu_ctx.flush_output(0)  # two chunks published, then...
            raise IOError("mid-stream crash")
        for i in range(3):
            cu_ctx.write_output(f"good{i}", b"G" * CSIZE)
            assert cu_ctx.flush_output(0)
        return len(attempts)

    FUNCTIONS.register("stream-flaky", flaky)
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=1)
    p.wait_active()
    out = sess.create_streaming_du(name="once", ready_chunks=1, chunk_size=CSIZE)
    cu = sess.submit_cu(executable="stream-flaky", max_retries=2, output_data=[out])
    assert cu.result(timeout=60) == 2
    du = out.result(timeout=10)
    assert du.sealed and du.n_chunks == 3
    assert set(du.manifest) == {"good0", "good1", "good2"}
    assert du.read("good0") == b"G" * CSIZE  # no 'B' bytes survived
    # end-of-stream hygiene: the writer claim is released after the seal
    assert sess.store.hget(f"du:{du.id}", "stream_writer") is None


def test_duplicate_loses_stream_to_live_writer(sess):
    """A racing duplicate whose output stream is owned by a LIVE foreign
    attempt must publish nothing and decline the win."""
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=1)
    p.wait_active()
    out = sess.create_streaming_du(name="contested", ready_chunks=1, chunk_size=CSIZE)
    foreign = f"cu-foreign@{p.id}#999"  # live pilot: claim is NOT stealable
    sess.store.hset(f"du:{out.id}", "stream_writer", foreign)

    def dup(cu_ctx):
        cu_ctx.write_output("mine", b"Z" * CSIZE)
        assert not cu_ctx.flush_output(0)
        assert cu_ctx.lost_stream()
        raise RuntimeError("lost stream to live writer")

    FUNCTIONS.register("stream-dup", dup)
    cu = sess.submit_cu(executable="stream-dup", max_retries=0, output_data=[out])
    with pytest.raises(ComputeFailedError, match="lost stream"):
        cu.result(timeout=30)
    assert out.du.manifest == {}  # losing attempt published zero chunks
    assert int(sess.store.hget(f"du:{out.id}", "published") or 0) == 0
    # the foreign claim was left untouched (abort only rolls back OUR claim)
    assert sess.store.hget(f"du:{out.id}", "stream_writer") == foreign


def test_dead_writer_claim_stolen_and_stream_reset(sess):
    """A writer token whose pilot died is stolen after rolling the
    half-written stream back — the retry's content fully replaces it."""
    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=1)
    p.wait_active()
    out = sess.create_streaming_du(name="stolen", ready_chunks=1, chunk_size=CSIZE)
    du = out.du
    # simulate a crashed producer: dead pilot's claim + half-written stream
    sess.store.hset("pilot:ghost", "state", PilotState.FAILED)
    sess.store.hset(f"du:{du.id}", "stream_writer", "cu-ghost@ghost#0")
    du.add_file("old0", b"O" * CSIZE)
    du.publish_prefix(1)
    assert du.published == 1
    _chunk_producer("stream-prod-steal", 2)
    cu = sess.submit_cu(executable="stream-prod-steal", output_data=[out])
    assert cu.result(timeout=30) == 2
    final = out.result(timeout=10)
    assert final.sealed and final.n_chunks == 2
    assert set(final.manifest) == {"f000", "f001"}  # 'old0' rolled back


# ------------------------------------------------ read-frontier eviction
def _make_ctx():
    ctx = RuntimeContext(store=CoordinationStore(), topology=_topo())
    TransferService(ctx)
    return ctx


def _make_pd(ctx, url, quota=1 << 40):
    pd = PilotData(
        PilotDataDescription(service_url=url, affinity=SITE_A, size_quota=quota),
        ctx,
    )
    return ctx.register(pd)


def test_streamed_chunks_evictable_only_below_read_frontier():
    ctx = _make_ctx()
    tm = TierManager(ctx, auto_promote=False)
    src = _make_pd(ctx, f"mem://{SITE_A}/src")
    dst = _make_pd(ctx, f"mem://{SITE_A}/dst")
    du = ctx.register(
        DataUnit(
            DataUnitDescription(name="live-stream", streaming=True, chunk_size=CSIZE),
            ctx.store,
        )
    )
    du.add_file("x", b"S" * (4 * CSIZE))
    src.put_chunks(du, [0, 1, 2, 3])
    du.publish_prefix(4)
    dst.put_chunks(du, [0, 1, 2, 3])  # consumer-side redundant copies
    ctx.store.hset("cu:reader", "state", CUState.RUNNING)
    tm.pins.pin(du.id, "reader")
    # nothing consumed yet: the pin fully protects the stream
    assert tm.evictable_victims(dst) == []
    assert tm.pins.read_frontier(du.id) == 0
    # consumer read 2 chunks: exactly the consumed prefix becomes evictable
    tm.pins.advance_frontier(du.id, "reader", 2)
    victims = tm.evictable_victims(dst)
    assert [(v.du_id, v.indices) for v in victims] == [(du.id, [0, 1])]
    # frontier is monotone: a late smaller report never narrows it
    assert tm.pins.advance_frontier(du.id, "reader", 1) == 2
    # a second, slower live consumer drags the frontier back down
    ctx.store.hset("cu:slow", "state", CUState.WAITING)
    tm.pins.pin(du.id, "slow")
    assert tm.pins.read_frontier(du.id) == 0
    assert tm.evictable_victims(dst) == []
    # slow consumer finishes: its pin stops binding, frontier recovers
    ctx.store.hset("cu:slow", "state", CUState.DONE)
    assert tm.pins.read_frontier(du.id) == 2
    # no live pinning consumer at all: unconstrained (-1)
    ctx.store.hset("cu:reader", "state", CUState.DONE)
    assert tm.pins.read_frontier(du.id) == -1
    tm.stop()


# --------------------------------------------------- windowed shuffle
def test_windowed_shuffle_end_to_end(sess):
    """Streaming wordcount: reducers decode records incrementally from the
    chunk stream and every key lands in exactly one partition."""
    texts = ["a b a c a b", "b c c d a", "d d a b c e"]

    def map_fn(rel, data):
        for tok in data.decode().split():
            yield tok, b"1"

    def reduce_fn(key, values):
        return str(sum(int(v) for v in values)).encode()

    p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=4)
    p.wait_active()
    parts = [
        sess.submit_du(name=f"text{i}", files={"t": t.encode()})
        for i, t in enumerate(texts)
    ]
    res = windowed_shuffle(
        sess,
        parts,
        map_fn,
        reduce_fn,
        n_reducers=2,
        window=1,
        flush_every=2,
        chunk_size=64,
    )
    counts = {}
    for blob in res.wait(timeout=90):
        for key, value in decode_records(blob):
            assert key not in counts  # disjoint partitions
            counts[key] = int(value)
    expected = {}
    for t in texts:
        for tok in t.split():
            expected[tok] = expected.get(tok, 0) + 1
    assert counts == expected
    # intermediates really streamed: per-reducer DUs, all sealed streaming
    for mf in res.mappers:
        assert len(mf.outputs) == 2
        for of in mf.outputs:
            assert of.du.streaming and of.result(timeout=10).sealed
