"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one decode step on CPU; asserts shapes and no NaNs."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SMOKE_SHAPE, get_config, list_archs
from repro.models import build_model, make_fake_batch

ARCHS = list_archs()


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _build(name):
    cfg = get_config(name + "-smoke")
    return cfg, build_model(cfg)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg, api = _build(arch)
    params = api.init(rng)
    batch = make_fake_batch(cfg, SMOKE_SHAPE)
    if cfg.family == "encdec":
        logits, aux = api.forward(params, batch["frames"], batch["tokens"])
    elif cfg.family == "vlm":
        logits, aux = api.forward(
            params, batch["tokens"], prefix_embeds=batch["prefix_embeds"]
        )
    else:
        logits, aux = api.forward(params, batch["tokens"])
    b, s = SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len
    assert logits.shape == (b, s, cfg.vocab_size)
    assert jnp.isfinite(jnp.asarray(logits, jnp.float32)).all()
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads_finite(arch, rng):
    cfg, api = _build(arch)
    params = api.init(rng)
    batch = make_fake_batch(cfg, SMOKE_SHAPE)

    def loss(p):
        l, _ = api.loss_fn(p, batch)
        return l

    l, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert jnp.isfinite(l), f"{arch}: loss not finite"
    # a random model over V=256 tokens should start near ln(V)
    assert 2.0 < float(l) < 12.0, f"{arch}: loss {l} implausible"
    flat, _ = jax.tree.flatten(grads)
    assert all(jnp.isfinite(g).all() for g in flat), f"{arch}: NaN grads"
    assert any(jnp.abs(g).max() > 0 for g in flat), f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, rng):
    cfg, api = _build(arch)
    params = api.init(rng)
    b, max_len = 2, 32
    cache = api.init_cache(b, max_len)
    tokens = jnp.zeros((b, 1), dtype=jnp.int32)
    step = jax.jit(api.decode_step)
    logits, cache = step(params, cache, tokens, jnp.int32(0))
    logits2, cache = step(params, cache, tokens + 1, jnp.int32(1))
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert jnp.isfinite(jnp.asarray(logits2, jnp.float32)).all()


@pytest.mark.parametrize("arch", ["mamba2-370m", "zamba2-1.2b", "h2o-danube-1.8b", "gemma3-1b"])
def test_decode_matches_forward(arch, rng):
    """Prefill-vs-decode consistency: feeding tokens one-by-one through the
    cache must reproduce the teacher-forced logits."""
    cfg, api = _build(arch)
    params = api.init(rng)
    b, s = 1, 8
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab_size, dtype=jnp.int32)
    full_logits, _ = api.forward(params, tokens)
    cache = api.init_cache(b, s)
    step = jax.jit(api.decode_step)
    outs = []
    for i in range(s):
        lg, cache = step(params, cache, tokens[:, i : i + 1], jnp.int32(i))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    assert jnp.allclose(
        jnp.asarray(full_logits, jnp.float32),
        jnp.asarray(dec_logits, jnp.float32),
        atol=2e-2,
        rtol=2e-2,
    ), f"{arch}: max err {jnp.abs(full_logits - dec_logits).max()}"
