"""The runnable examples are part of the public API surface — run them."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(script, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", script)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    assert proc.returncode == 0, f"{script}:\n{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}"
    return proc.stdout


def test_quickstart():
    out = _run("quickstart.py")
    assert "quickstart OK" in out


def test_distributed_ensemble():
    out = _run("distributed_ensemble.py")
    assert "distributed_ensemble OK" in out


@pytest.mark.slow
def test_pilot_serve():
    out = _run("pilot_serve.py", timeout=900)
    assert "consistent ✓" in out
    assert "mem-tier promotions: " in out
    # the fleet really promoted the checkpoint into a site cache
    promos = int(out.rsplit("mem-tier promotions: ", 1)[1].split(")")[0])
    assert promos >= 1
