"""Storage adaptors: uniform semantics across heterogeneous backends."""

import pytest

from repro.backends import (
    KeyNotFound,
    MemoryBackend,
    ObjectStoreBackend,
    StorageError,
    available_schemes,
    make_backend,
)


@pytest.fixture(params=["mem", "file", "sharedfs", "object"])
def backend(request, tmp_path, monkeypatch):
    import repro.backends.local_fs as lfs

    monkeypatch.setattr(lfs, "_SANDBOX", str(tmp_path))
    url = {
        "mem": "mem://hostA/c1",
        "file": "file://hostA/c1",
        "sharedfs": "sharedfs://siteA/scratch",
        "object": "object://region1/bucket1",
    }[request.param]
    # unique container per test to avoid cross-test shared-store state
    return make_backend(url + f"-{request.node.name}")


def test_put_get_roundtrip(backend):
    assert backend.put("k1", b"hello") == 5
    assert backend.get("k1") == b"hello"
    assert backend.exists("k1")
    assert backend.size("k1") == 5


def test_hierarchical_keys(backend):
    backend.put("a/b/c.bin", b"x" * 10)
    assert backend.get("a/b/c.bin") == b"x" * 10
    assert backend.list() == (
        ["a%2Fb%2Fc.bin"] if backend.flat_namespace else ["a/b/c.bin"]
    )


def test_delete_and_missing(backend):
    backend.put("k", b"1")
    backend.delete("k")
    assert not backend.exists("k")
    with pytest.raises(KeyNotFound):
        backend.get("k")
    backend.delete("k")  # idempotent


def test_list_prefix(backend):
    if backend.flat_namespace:
        pytest.skip("flat namespace encodes separators")
    backend.put("x/1", b"a")
    backend.put("x/2", b"b")
    backend.put("y/1", b"c")
    assert backend.list("x/") == ["x/1", "x/2"]


def test_key_validation(backend):
    for bad in ("", "/abs", "a/../b"):
        with pytest.raises(ValueError):
            backend.put(bad, b"x")


def test_object_store_write_once():
    b = ObjectStoreBackend("object://region1/wonce")
    b.put("k", b"v1")
    with pytest.raises(StorageError):
        b.put("k", b"v2")
    bv = ObjectStoreBackend("object://region1/wonce-v", versioning=True)
    bv.put("k", b"v1")
    bv.put("k", b"v2")
    assert bv.get("k") == b"v2"


def test_mem_backend_shared_by_url():
    a = MemoryBackend("mem://h/shared1")
    b = MemoryBackend("mem://h/shared1")
    a.put("k", b"v")
    assert b.get("k") == b"v"  # same container → same data (shared FS model)
    c = MemoryBackend("mem://h/other")
    assert not c.exists("k")


def test_registry_and_profiles():
    assert set(available_schemes()) >= {"mem", "file", "sharedfs", "object"}
    with pytest.raises(ValueError):
        make_backend("bogus://x/y")
    fast = make_backend("mem://h/p1")
    slow = make_backend("object://r/b1")
    assert fast.profile.bandwidth > slow.profile.bandwidth
    assert fast.simulated_put_time(1 << 30) < slow.simulated_put_time(1 << 30)


def test_scheme_mismatch_raises():
    with pytest.raises(ValueError):
        MemoryBackend("file://h/c")
