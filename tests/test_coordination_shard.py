"""Sharded coordination plane: lock striping, out-of-lock dispatch with the
flush_events barrier, targeted pop_any wakeups, bisect prefix scans, and the
group-commit WAL — the PR-7 machinery, exercised directly."""

import threading
import time

from repro.core.coordination import (
    CoordinationStore,
    StoreEvent,
)


def make_store(**kw):
    return CoordinationStore(**kw)


# --------------------------------------------------------------- striping
def test_keys_and_hashes_span_shards_transparently():
    store = make_store(shards=8)
    for i in range(200):
        store.set(f"cu:k{i}", i)
        store.hset(f"du:h{i}", "state", i)
    assert store.get("cu:k123") == 123
    assert store.hget("du:h7", "state") == 7
    # the per-shard sorted indexes merge back into one sorted keyspace
    assert store.keys("cu:") == sorted(f"cu:k{i}" for i in range(200))
    assert store.hkeys("du:") == sorted(f"du:h{i}" for i in range(200))
    # shard placement is stable: more than one stripe actually populated
    used = {
        i
        for i, sh in enumerate(store._shards)
        if sh.kv or sh.hashes
    }
    assert len(used) > 1


def test_prefix_scan_is_range_not_full_keyspace():
    store = make_store(shards=4)
    for i in range(50):
        store.set(f"cu:{i:04d}", i)
        store.set(f"zz:{i:04d}", i)
    assert store.keys("cu:") == [f"cu:{i:04d}" for i in range(50)]
    assert store.keys("cu:0001") == ["cu:0001"]
    assert store.keys("") == sorted(
        [f"cu:{i:04d}" for i in range(50)] + [f"zz:{i:04d}" for i in range(50)]
    )
    store.delete("cu:0001")
    assert store.keys("cu:0001") == []


def test_hkeys_index_tracks_hdel_like_legacy():
    store = make_store()
    store.hset("pd:a", "f", 1)
    store.hdel("pd:a", "f")
    # legacy behaviour: the hash record survives field deletion
    assert store.hkeys("pd:") == ["pd:a"]


# ------------------------------------------------- out-of-lock dispatch
def test_flush_events_is_a_delivery_barrier():
    store = make_store()
    seen = []
    store.subscribe(seen.append, prefix="cu:")
    for i in range(500):
        store.hset(f"cu:{i % 17}", "state", i)
    assert store.flush_events()
    assert [ev.value for ev in seen] == list(range(500))
    seqs = [ev.seq for ev in seen]
    assert seqs == sorted(seqs)


def test_events_sequence_in_per_key_mutation_order():
    store = make_store(shards=16)
    seen = []
    store.subscribe(seen.append, prefix="")
    store.hset("cu:a", "state", "Pending")
    store.hset("cu:a", "state", "Running")
    store.hset("cu:a", "state", "Done")
    store.flush_events()
    assert [ev.value for ev in seen] == ["Pending", "Running", "Done"]


def test_unsubscribe_drops_queued_events():
    store = make_store()
    seen = []
    token = store.subscribe(seen.append, prefix="cu:")
    store.hset("cu:x", "state", 1)
    store.flush_events()
    store.unsubscribe(token)
    store.hset("cu:x", "state", 2)
    store.flush_events()
    assert [ev.value for ev in seen] == [1]


def test_callbacks_may_reenter_the_store():
    store = make_store()
    done = threading.Event()

    def chain(ev: StoreEvent):
        # re-entering from the dispatcher thread must not deadlock
        if ev.key == "cu:first":
            store.hset("du:second", "state", "chained")
        elif ev.key == "du:second":
            done.set()

    store.subscribe(chain, prefix="")
    store.hset("cu:first", "state", "go")
    assert done.wait(timeout=5.0)


def test_inline_dispatch_delivers_before_mutator_returns():
    store = make_store(dispatch="inline")
    seen = []
    store.subscribe(seen.append, prefix="cu:")
    store.hset("cu:a", "state", "Pending")
    # no flush: inline mode is synchronous by construction
    assert [ev.value for ev in seen] == ["Pending"]
    assert store.flush_events()  # and the barrier is a cheap no-op


def test_prefix_index_matches_only_registered_prefixes():
    store = make_store()
    cu_seen, du_seen, all_seen = [], [], []
    store.subscribe(cu_seen.append, prefix="cu:")
    store.subscribe(du_seen.append, prefix="du:")
    store.subscribe(all_seen.append, prefix="")
    store.hset("cu:1", "state", "a")
    store.hset("du:1", "state", "b")
    store.hset("pilot:1", "state", "c")
    store.flush_events()
    assert [ev.key for ev in cu_seen] == ["cu:1"]
    assert [ev.key for ev in du_seen] == ["du:1"]
    assert [ev.key for ev in all_seen] == ["cu:1", "du:1", "pilot:1"]


# ------------------------------------------------------ targeted wakeups
def test_pop_any_wakes_on_exact_queue_push():
    store = make_store()
    got = []

    def consumer():
        got.append(store.pop_any(["q:mine", "q:global"], timeout=5.0))

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.1)  # let it park
    store.push("q:mine", {"cu": 1})
    t.join(timeout=2.0)
    assert not t.is_alive()
    assert got == [{"cu": 1}]


def test_parked_waiter_is_not_woken_by_other_queues_and_stays_quiet():
    store = make_store()
    result = []

    def consumer():
        result.append(store.pop_any(["q:mine"], timeout=1.2))

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.15)  # parked now
    before = store.ops_total
    for i in range(50):
        store.push("q:other", i)  # traffic the waiter must ignore
    time.sleep(0.3)
    # the parked waiter burned no per-50ms poll ops while other queues
    # churned (the legacy loop would have logged ~6 wakeup passes here);
    # at most the 0.5s failure-poll pass may have fired
    assert store.ops_total - before <= 50 + 1
    store.push("q:mine", "x")
    t.join(timeout=2.0)
    assert result == ["x"]


def test_pop_any_priority_and_fifo_survive_sharding():
    store = make_store(shards=8)
    store.push("q:b", 1)
    store.push("q:b", 2)
    store.push("q:a", 3)
    assert store.pop_any(["q:a", "q:b"]) == 3
    assert store.pop_any(["q:a", "q:b"]) == 1
    assert store.pop_any(["q:a", "q:b"]) == 2
    assert store.pop_any(["q:a", "q:b"]) is None


def test_restore_wakes_parked_waiters():
    store = make_store()
    store.push("q:x", "preserved")
    snap = store.snapshot()
    assert store.pop("q:x") == "preserved"
    got = []

    def consumer():
        got.append(store.pop("q:x", timeout=5.0))

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.1)
    store.restore(snap)  # queue refilled: the parked waiter must re-check
    t.join(timeout=2.0)
    assert got == ["preserved"]


# ------------------------------------------------------- group-commit WAL
def test_wal_batches_are_buffered_until_flush(tmp_path):
    path = str(tmp_path / "wal.log")
    store = make_store(wal_path=path, wal_batch=10_000)
    for i in range(20):
        store.set(f"cu:{i}", i)
    # under the batch threshold: nothing on disk yet (the group commit)
    with open(path) as fh:
        assert fh.read() == ""
    store.flush_wal()
    with open(path) as fh:
        assert len(fh.read().splitlines()) == 20
    store.close()


def test_wal_batch_threshold_triggers_flush(tmp_path):
    path = str(tmp_path / "wal.log")
    store = make_store(wal_path=path, wal_batch=8)
    for i in range(8):
        store.set(f"cu:{i}", i)
    with open(path) as fh:
        assert len(fh.read().splitlines()) == 8
    store.close()


def test_wal_batch_1_is_legacy_per_op_durability(tmp_path):
    path = str(tmp_path / "wal.log")
    store = make_store(wal_path=path, wal_batch=1)
    store.set("cu:0", "v")
    with open(path) as fh:
        assert len(fh.read().splitlines()) == 1
    store.close()


def test_legacy_single_lock_mode_full_roundtrip(tmp_path):
    """shards=1 + inline dispatch + per-op WAL ≈ the pre-shard store."""
    path = str(tmp_path / "wal.log")
    store = make_store(wal_path=path, shards=1, dispatch="inline", wal_batch=1)
    seen = []
    store.subscribe(seen.append, prefix="cu:")
    store.hset("cu:a", "state", "Running")
    assert [ev.value for ev in seen] == ["Running"]
    store.push("q", 1)
    assert store.pop("q") == 1
    store.close()
    replayed = CoordinationStore(wal_path=path, replay=True)
    assert replayed.hget("cu:a", "state") == "Running"
    assert replayed.qlen("q") == 0
    replayed.close()


def test_replay_stops_at_torn_tail_record(tmp_path):
    """A crash mid-group-commit can leave one partial JSON line; replay
    must recover the valid prefix instead of raising."""
    path = str(tmp_path / "wal.log")
    store = make_store(wal_path=path, wal_batch=1)
    store.set("cu:a", 1)
    store.set("cu:b", 2)
    store.close()
    with open(path, "a") as fh:
        fh.write('["set", "cu:c"')  # torn mid-write
    replayed = CoordinationStore(wal_path=path, replay=True)
    assert replayed.get("cu:a") == 1
    assert replayed.get("cu:b") == 2
    assert replayed.get("cu:c") is None
    replayed.close()


def test_close_drains_buffered_wal_and_replays(tmp_path):
    path = str(tmp_path / "wal.log")
    store = make_store(wal_path=path, wal_batch=10_000)
    for i in range(37):
        store.hset(f"cu:{i}", "state", i)
    store.push("q:a", "item")
    store.close()
    replayed = CoordinationStore(wal_path=path, replay=True)
    for i in range(37):
        assert replayed.hget(f"cu:{i}", "state") == i
    assert replayed.qpeek("q:a") == ["item"]
    replayed.close()


# ------------------------------------------------------------ accounting
def test_ops_total_counts_each_public_op_once():
    store = make_store(shards=8)
    before = store.ops_total
    store.set("cu:a", 1)
    store.get("cu:a")
    store.hset("du:b", "f", 1)
    store.hget("du:b", "f")
    store.hgetall("du:b")
    store.hcas("du:b", "f", 1, 2)
    store.push("q", 1)
    store.pop("q")
    store.keys("cu:")
    store.hkeys("du:")
    store.qlen("q")
    assert store.ops_total - before == 11


def test_flush_events_does_not_count_as_store_op():
    store = make_store()
    store.subscribe(lambda ev: None, prefix="cu:")
    store.hset("cu:a", "state", 1)
    before = store.ops_total
    store.flush_events()
    store.flush_events()
    assert store.ops_total == before
