"""Per-kernel validation: hypothesis sweeps over shapes/dtypes, allclose
against the pure-jnp ref oracles (kernels run in interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref, rmsnorm_residual_ref
from repro.kernels.ssd_scan import ssd
from repro.kernels.ssd_scan.ref import ssd_chunked

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[jnp.bfloat16] if dtype == jnp.bfloat16 else TOL[jnp.float32]


def _maxerr(a, b):
    """Max error normalized by the ref magnitude (bf16 outputs quantize
    proportionally to value scale, so absolute error alone misleads)."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    scale = max(1.0, float(np.abs(b).max()))
    return float(np.abs(a - b).max()) / scale


# ------------------------------------------------------------ flash attn
@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 2),
    sq=st.sampled_from([17, 64, 130, 256]),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([32, 64, 80]),
    causal=st.booleans(),
    window=st.sampled_from([None, 16, 100]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_flash_attention_matches_ref(b, sq, hkv, g, d, causal, window, dtype):
    hq = hkv * g
    rng = jax.random.PRNGKey(b * 1000 + sq)
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, sq, hkv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, sq, hkv, d), jnp.float32).astype(dtype)
    out = flash_attention(
        q, k, v, causal=causal, window=window, block_q=64, block_k=64
    )
    ref = attention_ref(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal,
        window=window,
    ).transpose(0, 2, 1, 3)
    assert _maxerr(out, ref) < _tol(dtype)


def test_flash_attention_long_noncausal_cross_length():
    rng = jax.random.PRNGKey(7)
    q = jax.random.normal(rng, (1, 64, 4, 64))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (1, 320, 2, 64))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (1, 320, 2, 64))
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=128)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=False,
    ).transpose(0, 2, 1, 3)
    assert _maxerr(out, ref) < 2e-5


# ----------------------------------------------------------- decode attn
@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    sk=st.sampled_from([64, 257, 512]),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 4]),
    d=st.sampled_from([64, 80]),
    window=st.sampled_from([None, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_decode_attention_matches_ref(b, sk, hkv, g, d, window, dtype):
    hq = hkv * g
    rng = jax.random.PRNGKey(b * 31 + sk)
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (b, 1, hq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, sk, hkv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, sk, hkv, d), jnp.float32).astype(dtype)
    pos = jax.random.randint(ks[3], (b,), 0, sk, dtype=jnp.int32)
    out = decode_attention(q, k, v, pos, window=window, block_k=128)
    ref = decode_attention_ref(
        q[:, 0],
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        pos,
        window=window,
    )
    assert _maxerr(out[:, 0], ref) < _tol(dtype)


def test_decode_matches_flash_at_last_position():
    """Cross-kernel consistency: decode at position S-1 == last row of a
    causal prefill."""
    rng = jax.random.PRNGKey(3)
    b, s, hq, hkv, d = 2, 128, 4, 2, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    pre = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    pos = jnp.full((b,), s - 1, jnp.int32)
    dec = decode_attention(q[:, -1:], k, v, pos, block_k=128)
    assert _maxerr(pre[:, -1:], dec) < 2e-5


# -------------------------------------------------------------- ssd scan
@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 2),
    nc=st.integers(1, 4),
    h=st.sampled_from([1, 4]),
    p=st.sampled_from([32, 64]),
    n=st.sampled_from([16, 64]),
    chunk=st.sampled_from([16, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_ssd_matches_ref(b, nc, h, p, n, chunk, dtype):
    s = nc * chunk
    rng = jax.random.PRNGKey(s + h)
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32).astype(dtype)
    da = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32))
    B_ = (jax.random.normal(ks[2], (b, s, h, n), jnp.float32) * 0.5).astype(dtype)
    C_ = (jax.random.normal(ks[3], (b, s, h, n), jnp.float32) * 0.5).astype(dtype)
    y_k, st_k = ssd(x, da, B_, C_, chunk=chunk)
    y_r, st_r = ssd_chunked(
        x.astype(jnp.float32),
        da,
        B_.astype(jnp.float32),
        C_.astype(jnp.float32),
        chunk,
    )
    tol = 0.05 if dtype == jnp.bfloat16 else 1e-4
    assert _maxerr(y_k, y_r) < tol
    assert _maxerr(st_k, st_r) < tol


def test_ssd_state_continuity():
    """Splitting a sequence in half and passing the state must equal the
    full-sequence run (the invariant decode relies on)."""
    rng = jax.random.PRNGKey(9)
    b, s, h, p, n, chunk = 1, 128, 2, 32, 32, 32
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    da = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    B_ = jax.random.normal(ks[2], (b, s, h, n)) * 0.5
    C_ = jax.random.normal(ks[3], (b, s, h, n)) * 0.5
    y_full, st_full = ssd_chunked(x, da, B_, C_, chunk)
    half = s // 2
    y1, st1 = ssd_chunked(x[:, :half], da[:, :half], B_[:, :half], C_[:, :half], chunk)
    y2, st2 = ssd_chunked(
        x[:, half:], da[:, half:], B_[:, half:], C_[:, half:], chunk,
        initial_state=st1,
    )
    assert _maxerr(jnp.concatenate([y1, y2], axis=1), y_full) < 1e-4
    assert _maxerr(st2, st_full) < 1e-4


# --------------------------------------------------------------- rmsnorm
@settings(max_examples=10, deadline=None)
@given(
    rows=st.sampled_from([1, 7, 64, 300]),
    d=st.sampled_from([128, 256, 1024]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    with_residual=st.booleans(),
)
def test_rmsnorm_matches_ref(rows, d, dtype, with_residual):
    rng = jax.random.PRNGKey(rows * 7 + d)
    x = jax.random.normal(rng, (rows, d), jnp.float32).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(rng, 1), (d,), jnp.float32) * 0.1
    if with_residual:
        r = jax.random.normal(jax.random.fold_in(rng, 2), (rows, d)).astype(dtype)
        out, res = rmsnorm(x, w, residual=r)
        ref, rres = rmsnorm_residual_ref(x, r, w)
        assert _maxerr(res, rres) < _tol(dtype)
    else:
        out = rmsnorm(x, w)
        ref = rmsnorm_ref(x, w)
    assert _maxerr(out, ref) < _tol(dtype)
