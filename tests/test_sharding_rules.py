"""Sharding rules: spec derivation on a fake multi-device mesh.

Runs in a subprocess (XLA device count must be set before jax imports, and
the rest of the suite needs the real single device)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.distributed.context import DistContext
    from repro.distributed.sharding_rules import (
        batch_specs, cache_specs, opt_specs, param_specs,
    )
    from repro.models import build_model
    from repro.optim import init_adamw

    from repro.distributed.compat import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    ctx = DistContext(mesh=mesh, batch_axes=("data",))
    out = {}

    # dense arch: Megatron column/row rules
    cfg = get_config("h2o-danube-1.8b")
    api = build_model(cfg)
    shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    specs = param_specs(shapes, cfg, ctx)
    out["attn_q"] = str(specs["groups"]["pos0"]["attn"]["q"]["w"])
    out["attn_o"] = str(specs["groups"]["pos0"]["attn"]["o"]["w"])
    out["mlp_gate"] = str(specs["groups"]["pos0"]["mlp"]["gate"]["w"])
    out["mlp_down"] = str(specs["groups"]["pos0"]["mlp"]["down"]["w"])
    out["embed"] = str(specs["embed"]["table"])
    out["norm"] = str(specs["final_norm"]["scale"])

    # ZeRO: opt state gains a data axis on an unsharded dim
    opt_shapes = jax.eval_shape(init_adamw, shapes)
    ospecs = opt_specs(opt_shapes, specs, cfg, ctx)
    out["opt_m_q"] = str(ospecs["m"]["groups"]["pos0"]["attn"]["q"]["w"])
    out["opt_step"] = str(ospecs["step"])

    # MoE: experts over model axis
    cfgm = get_config("qwen3-moe-30b-a3b")
    apim = build_model(cfgm, ep=4)
    shapesm = jax.eval_shape(apim.init, jax.random.PRNGKey(0))
    specsm = param_specs(shapesm, cfgm, ctx)
    out["moe_gate"] = str(specsm["groups"]["pos0"]["moe"]["gate"])
    out["moe_router"] = str(specsm["groups"]["pos0"]["moe"]["router"]["w"])

    # mamba: head-parallel projections
    cfgs = get_config("mamba2-370m")
    apis = build_model(cfgs)
    shapess = jax.eval_shape(apis.init, jax.random.PRNGKey(0))
    specss = param_specs(shapess, cfgs, ctx)
    out["mamba_x"] = str(specss["groups"]["pos0"]["mamba"]["x_proj"]["w"])
    out["mamba_bc"] = str(specss["groups"]["pos0"]["mamba"]["bc_proj"]["w"])
    out["mamba_out"] = str(specss["groups"]["pos0"]["mamba"]["out_proj"]["w"])

    # whisper: 20 heads % 4 == 0 on this mesh → sharded
    cfgw = get_config("whisper-large-v3")
    apiw = build_model(cfgw)
    shapesw = jax.eval_shape(apiw.init, jax.random.PRNGKey(0))
    specsw = param_specs(shapesw, cfgw, ctx)
    out["whisper_q"] = str(specsw["decoder"]["self_attn"]["q"]["w"])

    # batch + cache specs
    from repro.configs import get_shape
    bs = batch_specs(api.batch_spec(get_shape("train_4k")), ctx)
    out["tokens"] = str(bs["tokens"])
    cache_shapes = jax.eval_shape(lambda: api.init_cache(128, 4096))
    cs = cache_specs(cache_shapes, cfg, ctx)
    out["cache_k"] = str(cs["groups"]["pos0"]["k"])
    print(json.dumps(out))
    """
)


@pytest.fixture(scope="module")
def specs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_megatron_column_row(specs):
    # leading None = the stacked per-group dim of scanned layers
    assert specs["attn_q"] == "PartitionSpec(None, None, 'model')"
    assert specs["attn_o"] == "PartitionSpec(None, 'model', None)"
    assert specs["mlp_gate"] == "PartitionSpec(None, None, 'model')"
    assert specs["mlp_down"] == "PartitionSpec(None, 'model', None)"


def test_vocab_sharded_embedding_and_replicated_norm(specs):
    assert specs["embed"] == "PartitionSpec('model', None)"
    assert "'model'" not in specs["norm"] and "'data'" not in specs["norm"]


def test_zero_adds_data_axis(specs):
    # ZeRO picks the first unsharded divisible dim (the group-stack dim)
    assert specs["opt_m_q"] == "PartitionSpec('data', None, 'model')"
    assert specs["opt_step"] == "PartitionSpec()"


def test_moe_expert_parallel(specs):
    assert specs["moe_gate"] == "PartitionSpec(None, 'model', None, None)"
    assert "'model'" not in specs["moe_router"]


def test_mamba_head_parallel(specs):
    assert specs["mamba_x"] == "PartitionSpec(None, None, 'model')"
    assert "'model'" not in specs["mamba_bc"]  # tiny: replicated
    assert specs["mamba_out"] == "PartitionSpec(None, 'model', None)"


def test_whisper_heads_shard_when_divisible(specs):
    # 20 heads on a 4-way model axis → divisible → sharded
    assert specs["whisper_q"] == "PartitionSpec(None, None, 'model')"


def test_batch_and_cache_specs(specs):
    assert specs["tokens"] == "PartitionSpec('data', None)"
    assert "model" in specs["cache_k"]  # seq dim sharded over model
