"""Affinity topology + the §6.1 cost calculus, incl. property tests."""

import math

import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import cheapest_replica, choose_replication_degree, decide_placement, estimate_td, estimate_tr_group, estimate_tr_sequential, estimate_tx, make_tpu_fleet_topology, match_affinity, straggler_threshold

GB = 1e9


@pytest.fixture()
def topo():
    t, _ = make_tpu_fleet_topology(pods=2, hosts_per_pod=4)
    return t


def test_distance_and_affinity(topo):
    a = "cluster:pod0:host0"
    assert topo.distance(a, a) == 0
    assert topo.affinity(a, a) == 1.0
    # same pod: up to pod0, down to host1 = 2 edges
    assert topo.distance(a, "cluster:pod0:host1") == 2
    # cross-pod: host->pod->cluster->pod->host = 4 edges
    assert topo.distance(a, "cluster:pod1:host0") == 4
    assert topo.affinity(a, "cluster:pod0:host1") > topo.affinity(
        a, "cluster:pod1:host0"
    )


def test_bandwidth_bottleneck(topo):
    # Cross-pod path is bottlenecked by the DCN uplink (25 GB/s default).
    assert topo.bandwidth("cluster:pod0:host0", "cluster:pod1:host0") == 25 * GB
    # Intra-pod is ICI-class.
    assert topo.bandwidth("cluster:pod0:host0", "cluster:pod0:host1") == 50 * GB
    assert topo.bandwidth("cluster:pod0:host0", "cluster:pod0:host0") == math.inf


def test_dynamic_edge_reweighting(topo):
    before = estimate_tx(10 * GB, "cluster:pod0:host0", "cluster:pod1:host0", topo)
    topo.set_edge_weight("cluster:pod1", bandwidth=1 * GB)  # congested DCN
    after = estimate_tx(10 * GB, "cluster:pod0:host0", "cluster:pod1:host0", topo)
    assert after > before


def test_match_affinity():
    assert match_affinity(None, "anything")
    assert match_affinity("cluster:pod0", "cluster:pod0")
    assert match_affinity("cluster:pod0", "cluster:pod0:host3")
    assert not match_affinity("cluster:pod0", "cluster:pod1:host0")
    assert not match_affinity("cluster:pod0", "cluster:pod00")  # no prefix-string trap


def test_tx_zero_when_colocated(topo):
    assert estimate_tx(1 << 30, "cluster:pod0:host0", "cluster:pod0:host0", topo) == 0.0


def test_group_beats_sequential(topo):
    targets = [f"cluster:pod1:host{h}" for h in range(4)]
    seq = estimate_tr_sequential(4 * GB, "cluster:pod0", targets, topo)
    grp = estimate_tr_group(4 * GB, "cluster:pod0", targets, topo)
    assert grp < seq  # Fig. 8's headline result


def test_estimate_td_modes(topo):
    targets = [f"cluster:pod1:host{h}" for h in range(3)]
    td_g = estimate_td(1 * GB, "cluster:pod0", targets, topo, mode="group")
    td_s = estimate_td(1 * GB, "cluster:pod0", targets, topo, mode="sequential")
    assert td_g <= td_s
    with pytest.raises(ValueError):
        estimate_td(1, "cluster:pod0", targets, topo, mode="bogus")


def test_decide_placement_prefers_colocated(topo):
    # DU of 8 GB at pod0; pilot A at pod0 (busy: T_Q=5s), pilot B at pod1 (idle).
    choices = decide_placement(
        {"cluster:pod0:host0": 8 * int(GB)},
        [("A", "cluster:pod0:host0", 5.0), ("B", "cluster:pod1:host0", 0.0)],
        topo,
    )
    # Staging 8 GB cross-pod ~ 0.32s < 5s queue → B wins (data-to-compute).
    assert choices[0].pilot_id == "B"
    assert choices[0].strategy == "compute-to-data"  # t_q(0) < t_stage
    # Crank B's queue to 50s: now co-located A wins despite its queue.
    choices = decide_placement(
        {"cluster:pod0:host0": 8 * int(GB)},
        [("A", "cluster:pod0:host0", 5.0), ("B", "cluster:pod1:host0", 50.0)],
        topo,
    )
    assert choices[0].pilot_id == "A"


def test_decide_placement_affinity_constraint(topo):
    choices = decide_placement(
        {},
        [("A", "cluster:pod0:host0", 0.0), ("B", "cluster:pod1:host0", 0.0)],
        topo,
        affinity_constraint="cluster:pod1",
    )
    assert [c.pilot_id for c in choices] == ["B"]


def test_cheapest_replica(topo):
    label, t = cheapest_replica(
        1 * GB,
        ["cluster:pod0:host0", "cluster:pod1:host0"],
        "cluster:pod1:host3",
        topo,
    )
    assert label == "cluster:pod1:host0"
    assert t < estimate_tx(1 * GB, "cluster:pod0:host0", "cluster:pod1:host3", topo)


def test_choose_replication_degree_grows_until_marginal(topo):
    # Many small tasks, expensive compute: replicating to the 2nd site pays.
    sites = [("cluster:pod0", 8), ("cluster:pod1", 8)]
    chosen = choose_replication_degree(
        nbytes=1 * int(GB),
        src="cluster:pod0",
        candidate_sites=sites,
        tasks=64,
        task_compute_s=10.0,
        topo=topo,
    )
    assert chosen == ["cluster:pod0", "cluster:pod1"]
    # Tiny workload: one (co-located, free) replica suffices.
    chosen = choose_replication_degree(
        nbytes=100 * int(GB),
        src="cluster:pod0",
        candidate_sites=sites,
        tasks=2,
        task_compute_s=0.1,
        topo=topo,
    )
    assert chosen == ["cluster:pod0"]


def test_straggler_threshold():
    assert straggler_threshold([]) == math.inf
    assert straggler_threshold([1.0, 2.0, 3.0], factor=2.0) == 4.0
    assert straggler_threshold([1.0, 3.0], factor=2.0) == 4.0


# --------------------------------------------------------------- properties
@settings(max_examples=50, deadline=None)
@given(
    nbytes=st.integers(min_value=1, max_value=1 << 40),
    n_targets=st.integers(min_value=0, max_value=8),
)
def test_prop_group_never_slower_than_sequential(nbytes, n_targets):
    topo, hosts = make_tpu_fleet_topology(pods=2, hosts_per_pod=4)
    targets = hosts[:n_targets]
    seq = estimate_tr_sequential(nbytes, "cluster:pod0", targets, topo)
    grp = estimate_tr_group(nbytes, "cluster:pod0", targets, topo)
    assert grp <= seq + 1e-9


@settings(max_examples=50, deadline=None)
@given(
    pa=st.integers(0, 1),
    ha=st.integers(0, 3),
    pb=st.integers(0, 1),
    hb=st.integers(0, 3),
)
def test_prop_affinity_symmetric_and_bounded(pa, ha, pb, hb):
    topo, _ = make_tpu_fleet_topology(pods=2, hosts_per_pod=4)
    a, b = f"cluster:pod{pa}:host{ha}", f"cluster:pod{pb}:host{hb}"
    assert topo.affinity(a, b) == topo.affinity(b, a)
    assert 0 < topo.affinity(a, b) <= 1
    assert (topo.affinity(a, b) == 1) == (a == b)


@settings(max_examples=50, deadline=None)
@given(nbytes=st.integers(min_value=0, max_value=1 << 42))
def test_prop_tx_monotone_in_bytes(nbytes):
    topo, _ = make_tpu_fleet_topology()
    a, b = "cluster:pod0:host0", "cluster:pod1:host0"
    assert estimate_tx(nbytes, a, b, topo) <= estimate_tx(nbytes + 1024, a, b, topo)
