"""Data-Unit / Compute-Unit semantics: immutability, namespaces,
partition/merge, lifecycle."""

import pytest

from repro.core import (
    CoordinationStore,
    CUState,
    ComputeUnit,
    ComputeUnitDescription,
    DataUnit,
    DataUnitDescription,
    DUState,
    merge_dus,
    partition_du,
)


@pytest.fixture()
def store():
    return CoordinationStore()


def test_du_logical_url_and_manifest(store):
    du = DataUnit(DataUnitDescription(name="d", files={"a": b"123"}), store)
    assert du.url == f"du://{du.id}"
    du.add_file("dir/b", b"4567")
    assert du.manifest == {"a": 3, "dir/b": 4}
    assert du.size == 7
    assert du.state == DUState.NEW
    assert du.locations == []


def test_du_immutable_after_seal(store):
    du = DataUnit(DataUnitDescription(files={"a": b"1"}), store)
    du.seal()
    with pytest.raises(RuntimeError, match="immutable"):
        du.add_file("b", b"2")


def test_du_path_validation(store):
    du = DataUnit(DataUnitDescription(), store)
    with pytest.raises(ValueError):
        du.add_file("/abs", b"")
    with pytest.raises(ValueError):
        du.add_file("a/../b", b"")


def test_du_drop_buffer_requires_replica(store):
    du = DataUnit(DataUnitDescription(files={"a": b"1"}), store)
    with pytest.raises(RuntimeError):
        du.drop_local_buffer()


def test_partition_round_robin(store):
    files = {f"f{i:02d}": bytes([i]) * (i + 1) for i in range(7)}
    du = DataUnit(DataUnitDescription(name="big", files=files), store)
    parts = partition_du(du, 3, store)
    assert len(parts) == 3
    got = {}
    for p in parts:
        for rel, data in p.iter_files():
            got[rel] = data
    assert got == files  # exact cover, no loss, no dup
    sizes = [len(p.manifest) for p in parts]
    assert max(sizes) - min(sizes) <= 1  # balanced


def test_merge_gathers_with_namespacing(store):
    d1 = DataUnit(DataUnitDescription(files={"r": b"1"}), store)
    d2 = DataUnit(DataUnitDescription(files={"r": b"2"}), store)
    merged = merge_dus([d1, d2], store)
    assert len(merged.manifest) == 2  # no collision: namespaced by DU id


def test_partition_validation(store):
    du = DataUnit(DataUnitDescription(files={"a": b"1"}), store)
    with pytest.raises(ValueError):
        partition_du(du, 0, store)


def test_cu_description_json_and_lifecycle(store):
    desc = ComputeUnitDescription(
        executable="fn", args=(1, 2), input_data=["du-1"], affinity="cluster:pod0"
    )
    d = desc.to_json()
    assert d["executable"] == "fn" and d["args"] == [1, 2]
    cu = ComputeUnit(desc, store)
    assert cu.state == CUState.NEW
    assert cu.url.startswith("cu://")
    cu._set_state(CUState.PENDING)
    cu.cancel()
    assert cu.state == CUState.CANCELED


def test_cu_cancel_only_before_running(store):
    cu = ComputeUnit(ComputeUnitDescription(executable="fn"), store)
    cu._set_state(CUState.RUNNING)
    cu.cancel()  # no-op once running
    assert cu.state == CUState.RUNNING
