"""Tiered storage hierarchy: tier classification, quota-driven eviction
(with its safety invariants), pin/lease interlocks, eviction-race
re-planning, mem-tier promotion, and tier-aware placement."""

import pytest

from repro.core import (
    ComputeUnit,
    ComputeUnitDescription,
    CoordinationStore,
    DataUnit,
    DataUnitDescription,
    DUState,
    FUNCTIONS,
    PilotData,
    PilotDataDescription,
    QuotaExceeded,
    RuntimeContext,
    Session,
    TierManager,
    Topology,
    TransferService,
    Victim,
    classify_tier,
    list_eviction_policies,
    make_eviction_policy,
    tier_rank,
)
from repro.core.tiering import TIER_DRAM, TIER_NODE, TIER_SITE, TIER_ARCHIVE

CHUNK = 64
DU_BYTES = 4 * CHUNK  # 4 chunks per DU


def _topo(*labels, bw=30e6, lat=0.01) -> Topology:
    t = Topology()
    for lbl in labels:
        t.register(lbl, bandwidth=bw, latency=lat)
    return t


def make_ctx(*labels):
    ctx = RuntimeContext(store=CoordinationStore(), topology=_topo(*labels))
    TransferService(ctx)
    return ctx


def make_pd(ctx, url, affinity, quota=1 << 40, tier=""):
    pd = PilotData(
        PilotDataDescription(
            service_url=url, affinity=affinity, size_quota=quota, tier=tier
        ),
        ctx,
    )
    return ctx.register(pd)


def make_du(ctx, name, fill, nbytes=DU_BYTES):
    du = DataUnit(
        DataUnitDescription(name=name, files={"x": fill * nbytes}, chunk_size=CHUNK),
        ctx.store,
    )
    return ctx.register(du)


# ---------------------------------------------------------- classification
def test_classify_tier_by_scheme():
    ctx = make_ctx("t:s0")
    cases = {
        "mem://t:s0/a": TIER_DRAM,
        "file://t:s0/b": TIER_NODE,
        "sharedfs://t:s0/c": TIER_SITE,
        "object://t:s0/d": TIER_ARCHIVE,
    }
    for url, expected in cases.items():
        assert classify_tier(make_pd(ctx, url, "t:s0")) == expected


def test_classify_tier_explicit_override_and_rank():
    ctx = make_ctx("t:s0")
    pd = make_pd(ctx, "mem://t:s0/x", "t:s0", tier=TIER_ARCHIVE)
    assert classify_tier(pd) == TIER_ARCHIVE
    with pytest.raises(ValueError):
        classify_tier(make_pd(ctx, "mem://t:s0/y", "t:s0", tier="warp-core"))
    assert tier_rank(TIER_DRAM) < tier_rank(TIER_NODE) < tier_rank(TIER_SITE)
    assert tier_rank(TIER_SITE) < tier_rank(TIER_ARCHIVE)


# --------------------------------------------------------------- policies
def test_eviction_policy_registry():
    assert {"lru", "lfu", "largest-first"} <= set(list_eviction_policies())
    with pytest.raises(KeyError):
        make_eviction_policy("optimal-clairvoyant")


def test_eviction_policy_orderings():
    victims = [
        Victim("du-a", [0], 100, last_access=3, access_count=9),
        Victim("du-b", [0], 300, last_access=1, access_count=5),
        Victim("du-c", [0], 200, last_access=2, access_count=1),
    ]
    order = {
        "lru": ["du-b", "du-c", "du-a"],
        "lfu": ["du-c", "du-b", "du-a"],
        "largest-first": ["du-b", "du-c", "du-a"],
    }
    for name, expected in order.items():
        ranked = make_eviction_policy(name).rank(None, victims)
        assert [v.du_id for v in ranked] == expected


# ------------------------------------------------------- quota eviction
def test_quota_eviction_reclaims_redundant_replica():
    ctx = make_ctx("t:s0", "t:s1")
    tm = TierManager(ctx, auto_promote=False)
    base = make_pd(ctx, "sharedfs://t:s0/base", "t:s0")
    small = make_pd(ctx, "mem://t:s1/small", "t:s1", quota=DU_BYTES + CHUNK)
    a = make_du(ctx, "a", b"A")
    b = make_du(ctx, "b", b"B")
    base.put_du(a), base.put_du(b)
    small.copy_du_from(a, base)
    assert small.has_du(a.id) and small.id in a.locations
    # staging B would exceed the quota: just enough of the redundant copy
    # of A is evicted (minimal eviction — A stays a partial holder)
    small.copy_du_from(b, base)
    assert small.has_du(b.id)
    assert not small.has_du(a.id)
    assert small.used_bytes <= small.description.size_quota
    assert tm.evictions and tm.evictions[0]["du"] == a.id
    # bookkeeping is exact: A demoted out of locations, its remaining
    # chunks still registered as a (valid) partial holding
    assert a.locations == [base.id]
    remaining = a.chunk_holders().get(small.id, [])
    assert set(remaining) == set(small.chunks_held(a.id))
    assert len(remaining) < a.n_chunks
    assert base.verify_du(a) and a.state == DUState.READY
    tm.stop()


def test_last_copy_of_sealed_du_never_evicted():
    ctx = make_ctx("t:s0")
    tm = TierManager(ctx, auto_promote=False)
    only = make_pd(ctx, "mem://t:s0/only", "t:s0", quota=DU_BYTES + CHUNK)
    a = make_du(ctx, "a", b"A")
    b = make_du(ctx, "b", b"B")
    only.put_du(a)
    assert a.sealed
    with pytest.raises(QuotaExceeded):
        only.put_du(b)
    # the sole replica of A survived intact
    assert only.verify_du(a)
    assert not tm.evictions
    tm.stop()


def test_eviction_never_drops_below_replication_factor():
    ctx = make_ctx("t:s0", "t:s1")
    tm = TierManager(ctx, auto_promote=False)
    pd0 = make_pd(ctx, "mem://t:s0/p0", "t:s0", quota=DU_BYTES + CHUNK)
    pd1 = make_pd(ctx, "mem://t:s1/p1", "t:s1")
    a = ctx.register(
        DataUnit(
            DataUnitDescription(
                name="a",
                files={"x": b"A" * DU_BYTES},
                chunk_size=CHUNK,
                replication_factor=2,
            ),
            ctx.store,
        )
    )
    b = make_du(ctx, "b", b"B")
    pd0.put_du(a), pd1.put_du(a), pd1.put_du(b)
    # both copies of A are load-bearing (factor=2): eviction must refuse
    with pytest.raises(QuotaExceeded):
        pd0.copy_du_from(b, pd1)
    assert sorted(a.locations) == sorted([pd0.id, pd1.id])
    tm.stop()


def test_pinned_inputs_never_evicted():
    ctx = make_ctx("t:s0", "t:s1")
    tm = TierManager(ctx, auto_promote=False)
    base = make_pd(ctx, "sharedfs://t:s0/base", "t:s0")
    small = make_pd(ctx, "mem://t:s1/small", "t:s1", quota=DU_BYTES + CHUNK)
    a = make_du(ctx, "a", b"A")
    b = make_du(ctx, "b", b"B")
    base.put_du(a), base.put_du(b)
    small.copy_du_from(a, base)
    ctx.store.hset("cu:consumer", "state", "Running")
    tm.pins.pin(a.id, "consumer")
    with pytest.raises(QuotaExceeded):
        small.copy_du_from(b, base)  # A is pinned: nothing to reclaim
    assert small.has_du(a.id)
    # consumer finishes: the pin self-heals and eviction proceeds
    ctx.store.hset("cu:consumer", "state", "Done")
    small.copy_du_from(b, base)
    assert small.has_du(b.id) and not small.has_du(a.id)
    tm.stop()


def test_unpin_owner_releases_pin():
    ctx = make_ctx("t:s0")
    tm = TierManager(ctx, auto_promote=False)
    ctx.store.hset("cu:c1", "state", "Running")
    tm.pins.pin("du-x", "c1")
    assert tm.pins.pinned("du-x")
    tm.pins.unpin_owner("c1")
    assert not tm.pins.pinned("du-x")
    tm.stop()


def test_source_lease_blocks_eviction():
    ctx = make_ctx("t:s0", "t:s1")
    tm = TierManager(ctx, auto_promote=False)
    ts = ctx.transfer_service
    base = make_pd(ctx, "sharedfs://t:s0/base", "t:s0")
    small = make_pd(ctx, "mem://t:s1/small", "t:s1", quota=DU_BYTES + CHUNK)
    a = make_du(ctx, "a", b"A")
    b = make_du(ctx, "b", b"B")
    base.put_du(a), base.put_du(b)
    small.copy_du_from(a, base)
    # simulate an in-flight fetch reading A from `small`
    ts._src_leases[(small.id, a.id)] = 1
    assert ts.source_leased(small.id, a.id)
    with pytest.raises(QuotaExceeded):
        small.copy_du_from(b, base)
    assert small.has_du(a.id)
    ts._src_leases.pop((small.id, a.id))
    small.copy_du_from(b, base)
    assert small.has_du(b.id)
    tm.stop()


def test_partial_eviction_demotes_to_partial_holder():
    ctx = make_ctx("t:s0", "t:s1")
    tm = TierManager(ctx, auto_promote=False)
    base = make_pd(ctx, "sharedfs://t:s0/base", "t:s0")
    pd = make_pd(ctx, "mem://t:s1/pd", "t:s1")
    a = make_du(ctx, "a", b"A")
    base.put_du(a)
    pd.copy_du_from(a, base)
    ver = a.locations_version
    freed = pd.evict_chunks(a, [0, 2])
    assert freed == 2 * CHUNK
    assert pd.chunks_held(a.id) == [1, 3]
    assert a.chunk_holders()[pd.id] == [1, 3]
    assert pd.id not in a.locations  # demoted: no longer a full replica
    assert a.locations_version > ver  # transfer caches invalidate
    # healing re-stages only the missing chunks
    ctx.transfer_service.heal_replica(a, pd)
    assert pd.has_du(a.id) and pd.id in a.locations
    tm.stop()


def test_eviction_race_replans_from_surviving_holder():
    ctx = make_ctx("t:s0", "t:s1", "t:s2")
    tm = TierManager(ctx, auto_promote=False)
    ts = ctx.transfer_service
    src1 = make_pd(ctx, "sharedfs://t:s0/s1", "t:s0")
    src2 = make_pd(ctx, "sharedfs://t:s1/s2", "t:s1")
    dst = make_pd(ctx, "mem://t:s2/dst", "t:s2")
    a = make_du(ctx, "a", b"A")
    src1.put_du(a)
    src2.copy_du_from(a, src1)
    groups = ts.plan_chunk_fetch(a, dst, "t:s2")
    planned_srcs = {g.src.id for g in groups if g.src is not None}
    assert planned_srcs  # at least one physical source planned
    # an eviction lands between planning and fetching: src1 loses its copy
    src1.evict_chunks(a, list(range(a.n_chunks)))
    sim = ts._fetch_groups(a, dst, groups, location="t:s2")
    assert dst.has_du(a.id)  # re-planned onto src2 instead of failing
    assert sim > 0.0
    assert dst.verify_du(a)
    tm.stop()


# ----------------------------------------------------------- promotion
def test_hot_du_promoted_to_mem_tier_cache():
    FUNCTIONS.register(
        "tier-read",
        lambda c: len(c.read_input(c.cu.description.input_data[0], "x")),
    )
    topo = _topo("t:s0", "t:s1", bw=10e6)
    with Session(
        topology=topo,
        tier_cache_bytes=4 * DU_BYTES,
        tier_auto_promote=False,
    ) as s:
        cold = s.start_pilot_data(service_url="sharedfs://t:s1/cold", affinity="t:s1")
        pilot = s.start_pilot(
            resource_url="sim://t:s0", slots=1, sandbox_quota=DU_BYTES
        )
        pilot.wait_active()
        dus = [
            s.submit_du(
                name=f"d{i}",
                files={"x": bytes([i]) * DU_BYTES},
                chunk_size=CHUNK,
                target=cold,
            ).result()
            for i in range(2)
        ]
        tm = s.tier_manager
        # two read epochs cross the promote_after=2 threshold
        for _ in range(2):
            for du in dus:
                cu = s.submit_cu(executable="tier-read", input_data=[du], pilot=pilot)
                assert cu.result(timeout=20) == DU_BYTES
        assert tm.drain_promotions() == 2
        cache = tm.cache_pds["t:s0"]
        assert classify_tier(cache) == TIER_DRAM
        for du in dus:
            assert cache.has_du(du.id)
            # cache-tier replica is linkable from the pilot: staging free
            cost = s.transfer.estimate_stage_cost(du, pilot.affinity, pilot.sandbox)
            assert cost == 0.0
        assert tm.promotions and len(tm.promotions) == 2


def test_access_stats_ride_store_events():
    ctx = make_ctx("t:s0")
    tm = TierManager(ctx, auto_promote=False)
    ts = ctx.transfer_service
    base = make_pd(ctx, "sharedfs://t:s0/base", "t:s0")
    a = make_du(ctx, "a", b"A")
    base.put_du(a)
    assert tm.access_stats(a.id) == (0, 0)
    sandbox = make_pd(ctx, "mem://t:s0/sb", "t:s0")
    ts.stage_in(a, sandbox, "t:s0")
    ts.stage_in(a, sandbox, "t:s0")  # pilot-level cache hit still counts
    count, last = tm.access_stats(a.id)
    assert count == 2 and last > 0
    tm.stop()


# ------------------------------------------------------ tier-aware placement
def test_data_local_strategy_prefers_faster_tier():
    FUNCTIONS.register("tier-noop", lambda c: 0)
    topo = _topo("t:s0", "t:s1")
    with Session(topology=topo, placement_strategy="data-local") as s:
        fast = s.start_pilot_data(service_url="mem://t:s0/fast", affinity="t:s0")
        slow = s.start_pilot_data(service_url="sharedfs://t:s1/slow", affinity="t:s1")
        p_fast = s.start_pilot(resource_url="sim://t:s0", slots=1)
        p_slow = s.start_pilot(resource_url="sim://t:s1", slots=1)
        p_fast.wait_active(), p_slow.wait_active()
        du = s.submit_du(
            name="d", files={"x": b"D" * DU_BYTES}, chunk_size=CHUNK,
            target=slow,
        ).result()
        fast.copy_du_from(du, slow)
        cu = ComputeUnit(
            ComputeUnitDescription(executable="tier-noop", input_data=[du.id]),
            s.store,
        )
        s.ctx.register(cu)
        engine = s.cds.engine
        # the session's data-local strategy declares uses_tier_bw, which
        # is what the CDS passes through on the live placement path
        assert s.cds.strategy.uses_tier_bw
        cands = engine.candidates(cu, [p_fast, p_slow], tier_bw=True)
        by_pilot = {c.pilot.id: c for c in cands}
        # both are fully local (linkable replica at each site)...
        assert by_pilot[p_fast.id].locality == 1.0
        assert by_pilot[p_slow.id].locality == 1.0
        # ...but the DRAM-tier replica serves faster than the shared FS
        assert by_pilot[p_fast.id].tier_bw > by_pilot[p_slow.id].tier_bw
        ranked = s.cds.strategy.rank(cu, cands)
        assert ranked[0].pilot.id == p_fast.id


def test_concurrent_admission_cannot_overshoot_quota():
    # check-and-reserve admission: racing stagers must not jointly exceed
    # the quota (each alone fits, together they would overshoot 3x)
    import threading

    ctx = make_ctx("t:s0", "t:s1")
    tm = TierManager(ctx, auto_promote=False)
    base = make_pd(ctx, "sharedfs://t:s0/base", "t:s0")
    small = make_pd(ctx, "mem://t:s1/small", "t:s1", quota=DU_BYTES + CHUNK)
    dus = [make_du(ctx, f"c{i}", bytes([i + 1])) for i in range(3)]
    for du in dus:
        base.put_du(du)
        ctx.store.hset(f"cu:keep-{du.id}", "state", "Running")
        tm.pins.pin(du.id, f"keep-{du.id}")  # nothing evictable: pure race
    results = []

    def copy(du):
        try:
            small.copy_du_from(du, base)
            results.append("ok")
        except QuotaExceeded:
            results.append("quota")

    threads = [threading.Thread(target=copy, args=(du,)) for du in dus]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert small.used_bytes <= small.description.size_quota
    assert results.count("ok") == 1 and results.count("quota") == 2
    tm.stop()


def test_quota_backpressure_requeues_instead_of_failing():
    # two CUs share one pilot whose sandbox fits only one CU's input:
    # the loser must wait for the winner (pin released on completion)
    # instead of burning retries into a failure
    FUNCTIONS.register(
        "bp-read",
        lambda c: len(c.read_input(c.cu.description.input_data[0], "x")),
    )
    topo = _topo("t:s0", "t:s1", bw=10e6)
    with Session(topology=topo, eviction_policy="lru") as s:
        cold = s.start_pilot_data(service_url="sharedfs://t:s1/cold", affinity="t:s1")
        pilot = s.start_pilot(
            resource_url="sim://t:s0", slots=2, sandbox_quota=DU_BYTES + CHUNK
        )
        pilot.wait_active()
        dus = [
            s.submit_du(
                name=f"bp{i}",
                files={"x": bytes([i]) * DU_BYTES},
                chunk_size=CHUNK,
                target=cold,
            ).result()
            for i in range(3)
        ]
        futs = [
            s.submit_cu(executable="bp-read", input_data=[du], pilot=pilot)
            for du in dus
        ]
        for f in futs:
            assert f.result(timeout=30) == DU_BYTES
        assert pilot.sandbox.used_bytes <= DU_BYTES + CHUNK


# --------------------------------------------------- end-to-end churn
def test_working_set_larger_than_sandbox_completes():
    FUNCTIONS.register(
        "tier-sum",
        lambda c: sum(len(c.read_input(d.id, "x")) for d in c.input_dus()),
    )
    topo = _topo("t:s0", "t:s1", bw=10e6)
    with Session(topology=topo, eviction_policy="lru") as s:
        cold = s.start_pilot_data(service_url="sharedfs://t:s1/cold", affinity="t:s1")
        pilot = s.start_pilot(
            resource_url="sim://t:s0", slots=1, sandbox_quota=2 * DU_BYTES
        )
        pilot.wait_active()
        dus = [
            s.submit_du(
                name=f"w{i}",
                files={"x": bytes([i]) * DU_BYTES},
                chunk_size=CHUNK,
                target=cold,
            ).result()
            for i in range(5)
        ]
        for _epoch in range(2):
            for du in dus:
                cu = s.submit_cu(executable="tier-sum", input_data=[du], pilot=pilot)
                assert cu.result(timeout=20) == DU_BYTES
        tm = s.tier_manager
        assert tm.evictions  # the working set cannot fit: churn happened
        assert pilot.sandbox.used_bytes <= 2 * DU_BYTES
        for du in dus:
            assert du.state == DUState.READY
            assert du.has_full_coverage()
            assert cold.verify_du(du)


# ------------------------------------------- access-stats snapshot (pdlint)
def test_victim_stats_fold_in_fresh_access_records():
    """evictable_victims() barriers once up front and snapshots the stats
    tables (instead of flush_events() per DU under _evict_lock, the
    PD-L002 finding): access records published immediately before the
    call must still be reflected in the ranked victims."""
    ctx = make_ctx("t:s0", "t:s1")
    tm = TierManager(ctx, auto_promote=False)
    base = make_pd(ctx, "sharedfs://t:s0/base", "t:s0")
    small = make_pd(ctx, "mem://t:s1/small", "t:s1")
    a = make_du(ctx, "a", b"A")
    b = make_du(ctx, "b", b"B")
    base.put_du(a), base.put_du(b)
    small.copy_du_from(a, base)
    small.copy_du_from(b, base)
    # publish access records the way the transfer service does; the
    # snapshot path must see them without any explicit flush by the test
    for _ in range(3):
        ctx.store.hset("du:access", a.id, {"location": "mem://t:s1/small"})
    ctx.store.hset("du:access", b.id, {"location": "mem://t:s1/small"})
    victims = {v.du_id: v for v in tm.evictable_victims(small)}
    assert victims[a.id].access_count == 3
    assert victims[b.id].access_count == 1
    assert victims[b.id].last_access > victims[a.id].last_access
    # and the ranking that make_room() uses honors them (lfu: b first)
    ranked = make_eviction_policy("lfu").rank(small, list(victims.values()))
    assert ranked[0].du_id == b.id
    tm.stop()
