"""Chunk-granular data path: manifest invariants, partial replicas,
multi-source striped staging, seal persistence, merge/partition round-trips,
and fractional chunk-locality placement."""

import threading
import zlib

import pytest

from repro.core import CoordinationStore, DataUnit, DataUnitDescription, DUState, PilotManager, Topology, merge_dus, partition_du


@pytest.fixture()
def store():
    return CoordinationStore()


def _topo(*labels, bw=30e6, lat=0.05) -> Topology:
    t = Topology()
    for lbl in labels:
        t.register(lbl, bandwidth=bw, latency=lat)
    return t


# ------------------------------------------------------- manifest invariants
def test_chunk_manifest_covers_stream_exactly(store):
    du = DataUnit(
        DataUnitDescription(
            name="c",
            files={"a": b"x" * 1000, "b": b"y" * 2500},
            chunk_size=1024,
        ),
        store,
    )
    assert du.n_chunks == 4  # ceil(3500 / 1024)
    assert sum(c.size for c in du.chunks) == du.size
    # every chunk except the last is full-size
    for c in du.chunks[:-1]:
        assert c.size == 1024
    # per-chunk checksums match the data
    for c in du.chunks:
        assert zlib.crc32(du.chunk_data(c.index)) == c.checksum


def test_split_reassemble_identity(store):
    files = {"a/b": b"0123456789" * 33, "z": b"Q" * 7, "m": b""}
    du = DataUnit(
        DataUnitDescription(name="r", files=files, chunk_size=64), store
    )
    stream = b"".join(du.chunk_data(i) for i in range(du.n_chunks))
    expect = b"".join(files[k] for k in sorted(files))
    assert stream == expect
    # file_range slices reproduce each file from the stream
    for rel, data in files.items():
        lo, hi = du.file_range(rel)
        assert stream[lo:hi] == data


def test_chunks_for_file_ranges(store):
    du = DataUnit(
        DataUnitDescription(
            files={"a": b"1" * 100, "b": b"2" * 100}, chunk_size=64
        ),
        store,
    )
    # stream: a=[0,100), b=[100,200); chunks of 64 → a: 0,1  b: 1,2,3
    assert du.chunks_for_file("a") == [0, 1]
    assert du.chunks_for_file("b") == [1, 2, 3]


def test_chunk_manifest_mirrored_to_store(store):
    du = DataUnit(
        DataUnitDescription(files={"a": b"k" * 150}, chunk_size=100), store
    )
    raw = store.hget(f"du:{du.id}", "chunks")
    assert [s for s, _ in raw] == [100, 50]
    assert store.hget(f"du:{du.id}", "chunk_size") == 100


def test_add_file_rechunks(store):
    du = DataUnit(DataUnitDescription(chunk_size=10), store)
    du.add_file("b", b"B" * 15)
    assert du.n_chunks == 2
    du.add_file("a", b"A" * 5)  # sorts before "b": stream shifts
    assert du.n_chunks == 2
    assert du.chunk_data(0) == b"A" * 5 + b"B" * 5


def test_chunk_size_validation(store):
    with pytest.raises(ValueError):
        DataUnit(DataUnitDescription(chunk_size=0), store)


# -------------------------------------------------------- seal persistence
def test_seal_persisted_to_store(store):
    du = DataUnit(DataUnitDescription(files={"a": b"1"}), store)
    assert store.hget(f"du:{du.id}", "sealed") is False
    du.seal()
    assert store.hget(f"du:{du.id}", "sealed") is True
    with pytest.raises(RuntimeError, match="immutable"):
        du.add_file("b", b"2")


def test_remote_client_observes_seal(store):
    """A second handle on the same store sees the seal — immutability is a
    property of the coordination store, not of one process's flag."""
    du = DataUnit(DataUnitDescription(files={"a": b"1"}), store)
    # simulate a remote client: flip the sealed field store-side only
    store.hset(f"du:{du.id}", "sealed", True)
    assert du.sealed
    with pytest.raises(RuntimeError, match="immutable"):
        du.add_file("b", b"2")


def test_first_replica_seals_via_store():
    topo = _topo("site:a")
    with PilotManager(topology=topo) as mgr:
        pd = mgr.start_pilot_data(service_url="mem://site:a/pd", affinity="site:a")
        du = mgr.session.submit_du(name="s", files={"a": b"z" * 256}, target=pd).du
        assert du.wait() == DUState.READY
        assert mgr.store.hget(f"du:{du.id}", "sealed") is True
        with pytest.raises(RuntimeError, match="immutable"):
            du.add_file("late", b"no")


def test_reattach_preserves_seal_and_manifest():
    """A second handle on an existing DU id adopts the store's state
    instead of wiping it — the persisted seal survives reconnect."""
    topo = _topo("site:a")
    with PilotManager(topology=topo) as mgr:
        pd = mgr.start_pilot_data(service_url="mem://site:a/pd", affinity="site:a")
        du = mgr.session.submit_du(name="orig", files={"a": b"q" * 300}, chunk_size=128, target=pd).du
        assert du.wait() == DUState.READY
        clone = DataUnit(DataUnitDescription(), mgr.store, du_id=du.id)
        assert clone.sealed
        assert clone.manifest == du.manifest
        assert [(c.size, c.checksum) for c in clone.chunks] == [
            (c.size, c.checksum) for c in du.chunks
        ]
        assert clone.locations == du.locations
        with pytest.raises(RuntimeError, match="immutable"):
            clone.add_file("b", b"2")
        # re-creating a sealed DU with new content is refused outright
        with pytest.raises(RuntimeError, match="sealed"):
            DataUnit(
                DataUnitDescription(files={"evil": b"x"}), mgr.store, du_id=du.id
            )


def test_fetch_du_file_for_unregistered_du():
    """PDs can serve files of DUs staged directly into them (partition/
    merge outputs) even when the DU was never registered in ctx.objects."""
    topo = _topo("site:a")
    with PilotManager(topology=topo) as mgr:
        pd = mgr.start_pilot_data(service_url="mem://site:a/pd", affinity="site:a")
        du = DataUnit(
            DataUnitDescription(name="side", files={"f": b"side-channel"}),
            mgr.store,
        )
        assert du.id not in mgr.ctx.objects
        pd.put_du(du)
        assert pd.fetch_du_file(du.id, "f") == b"side-channel"


# ------------------------------------------------------------ partial replicas
def test_partial_replicas_first_class():
    topo = _topo("site:a", "site:b", "site:c")
    with PilotManager(topology=topo) as mgr:
        src = mgr.start_pilot_data(service_url="mem://site:a/src", affinity="site:a")
        part = mgr.start_pilot_data(service_url="mem://site:b/p", affinity="site:b")
        du = mgr.session.submit_du(
            name="p", files={"blob": b"d" * 4096}, chunk_size=1024, target=src
        ).du
        du.wait()
        assert du.n_chunks == 4
        mgr.transfer.replicate_chunks(du, src, part, [0, 1])
        # partial holder: visible in chunk_holders, absent from locations
        holders = du.chunk_holders()
        assert holders[part.id] == [0, 1]
        assert part.id not in du.locations
        assert not part.has_du(du.id)
        assert part.chunks_held(du.id) == [0, 1]
        assert part.missing_chunks(du) == [2, 3]
        # healing to a full replica promotes it into locations
        mgr.transfer.replicate_chunks(du, src, part, [2, 3])
        assert part.has_du(du.id)
        assert part.id in du.locations
        assert part.verify_du(du)


def test_multi_source_striped_stage_in():
    """A cold sandbox stripes its chunks from several partial holders in
    parallel waves: T = max over per-source groups, not the sum."""
    topo = _topo("site:a", "site:b", "site:dst")
    with PilotManager(topology=topo) as mgr:
        pa = mgr.start_pilot_data(service_url="mem://site:a/pd", affinity="site:a")
        pb = mgr.start_pilot_data(service_url="mem://site:b/pd", affinity="site:b")
        dst = mgr.start_pilot_data(
            service_url="mem://site:dst/sb", affinity="site:dst"
        )
        du = mgr.session.submit_du(
            name="m", files={"blob": b"e" * 8192}, chunk_size=1024, target=pa
        ).du
        du.wait()
        # pb holds the odd half
        mgr.transfer.replicate_chunks(du, pa, pb, [1, 3, 5, 7])
        mgr.transfer.reset_records()
        sim = mgr.transfer.stage_in(du, dst, "site:dst")
        recs = [r for r in mgr.transfer.records() if r.dst_pd == dst.id]
        srcs = {r.src_pd for r in recs}
        assert srcs == {pa.id, pb.id}  # both holders served chunks
        assert all(r.striped for r in recs)
        assert sum(r.chunks for r in recs) == 8
        # parallel waves: total is the max of the groups, not their sum
        assert sim == pytest.approx(max(r.sim_seconds for r in recs))
        assert sim < sum(r.sim_seconds for r in recs)
        assert dst.has_du(du.id) and dst.verify_du(du)


def test_striped_beats_single_source():
    """Two half-holders stage a DU faster than one full holder at the same
    topology distance (the tentpole claim, unit-sized)."""
    topo = _topo("site:a", "site:b", "site:full", "site:d1", "site:d2")
    with PilotManager(topology=topo) as mgr:
        full = mgr.start_pilot_data(
            service_url="mem://site:full/pd", affinity="site:full"
        )
        du = mgr.session.submit_du(
            name="v", files={"blob": b"w" * 16384}, chunk_size=1024, target=full
        ).du
        du.wait()
        d1 = mgr.start_pilot_data(service_url="mem://site:d1/sb", affinity="site:d1")
        t_mono = mgr.transfer.stage_in(du, d1, "site:d1", use_cache=False)
        pa = mgr.start_pilot_data(service_url="mem://site:a/pd", affinity="site:a")
        pb = mgr.start_pilot_data(service_url="mem://site:b/pd", affinity="site:b")
        mgr.transfer.replicate_chunks(du, full, pa, list(range(0, 16, 2)))
        mgr.transfer.replicate_chunks(du, full, pb, list(range(1, 16, 2)))
        d2 = mgr.start_pilot_data(service_url="mem://site:d2/sb", affinity="site:d2")
        t_striped = mgr.transfer.stage_in(du, d2, "site:d2")
        assert t_striped < t_mono


def test_concurrent_stagers_split_chunks():
    """Chunk-granular in-flight dedup: racing stagers never move the same
    chunk twice into one sandbox."""
    topo = _topo("site:a", "site:dst")
    with PilotManager(topology=topo) as mgr:
        src = mgr.start_pilot_data(service_url="mem://site:a/pd", affinity="site:a")
        dst = mgr.start_pilot_data(
            service_url="mem://site:dst/sb", affinity="site:dst"
        )
        du = mgr.session.submit_du(
            name="race", files={"blob": b"r" * 8192}, chunk_size=512, target=src
        ).du
        du.wait()
        mgr.transfer.reset_records()
        threads = [
            threading.Thread(
                target=mgr.transfer.stage_in, args=(du, dst, "site:dst")
            )
            for _ in range(4)
        ]
        [t.start() for t in threads]
        [t.join(timeout=30) for t in threads]
        assert dst.has_du(du.id)
        moved = sum(
            r.chunks for r in mgr.transfer.records() if r.dst_pd == dst.id
        )
        assert moved == du.n_chunks  # each chunk moved exactly once
        assert dst.verify_du(du)


# ------------------------------------------------- partition/merge round-trips
def test_partition_merge_roundtrip(store):
    files = {f"f{i}": bytes([65 + i]) * (10 * i + 1) for i in range(9)}
    du = DataUnit(DataUnitDescription(name="big", files=files), store)
    parts = partition_du(du, 4, store)
    merged = merge_dus(parts, store, name="back")
    got = {
        rel.split("/", 1)[1]: data for rel, data in merged.iter_files()
    }
    assert got == files
    assert merged.size == du.size


def test_partition_preserves_chunk_size_and_affinity(store):
    du = DataUnit(
        DataUnitDescription(
            name="g",
            files={"a": b"1" * 100},
            affinity="cluster:pod0",
            chunk_size=7,
        ),
        store,
    )
    parts = partition_du(du, 2, store)
    for p in parts:
        assert p.description.chunk_size == 7
        assert p.affinity == "cluster:pod0"


def test_merge_propagates_agreeing_affinity(store):
    dus = [
        DataUnit(
            DataUnitDescription(files={"x": b"1"}, affinity="cluster:pod1"),
            store,
        )
        for _ in range(3)
    ]
    merged = merge_dus(dus, store)
    assert merged.affinity == "cluster:pod1"


def test_merge_drops_disagreeing_affinity(store):
    d1 = DataUnit(
        DataUnitDescription(files={"x": b"1"}, affinity="cluster:pod0"), store
    )
    d2 = DataUnit(
        DataUnitDescription(files={"y": b"2"}, affinity="cluster:pod1"), store
    )
    assert merge_dus([d1, d2], store).affinity is None


def test_merge_verifies_checksums(store):
    du = DataUnit(DataUnitDescription(files={"x": b"good"}), store)
    du._files["x"] = b"evil"  # corrupt the staging buffer behind the API
    with pytest.raises(RuntimeError, match="checksum mismatch"):
        merge_dus([du], store)


def test_merge_sealed_sources_ok(store):
    d1 = DataUnit(DataUnitDescription(files={"x": b"1"}), store)
    d1.seal()
    merged = merge_dus([d1], store)
    assert merged.manifest == {f"{d1.id}/x": 1}
    assert not merged.sealed  # the gather output is a fresh, open DU


def test_merge_dropped_buffer_raises():
    topo = _topo("site:a")
    with PilotManager(topology=topo) as mgr:
        pd = mgr.start_pilot_data(service_url="mem://site:a/pd", affinity="site:a")
        du = mgr.session.submit_du(name="d", files={"x": b"1" * 64}, target=pd).du
        du.wait()
        du.drop_local_buffer()
        with pytest.raises(RuntimeError, match="buffer dropped"):
            merge_dus([du], mgr.store)


def test_partition_dropped_buffer_raises():
    topo = _topo("site:a")
    with PilotManager(topology=topo) as mgr:
        pd = mgr.start_pilot_data(service_url="mem://site:a/pd", affinity="site:a")
        du = mgr.session.submit_du(name="d", files={"x": b"1" * 64}, target=pd).du
        du.wait()
        du.drop_local_buffer()
        with pytest.raises(RuntimeError, match="no local buffer"):
            partition_du(du, 2, mgr.store)


def test_partition_sealed_du_allowed(store):
    """Sealing freezes the DU itself; deriving new DUs from it is fine."""
    du = DataUnit(DataUnitDescription(files={"a": b"1", "b": b"2"}), store)
    du.seal()
    parts = partition_du(du, 2, store)
    assert sum(len(p.manifest) for p in parts) == 2


# ------------------------------------------------------- event-driven waits
def test_du_wait_event_driven(store):
    du = DataUnit(DataUnitDescription(files={"a": b"1"}), store)

    def promote():
        store.hset(f"du:{du.id}", "state", DUState.READY)

    t = threading.Timer(0.05, promote)
    t.start()
    assert du.wait(timeout=5.0) == DUState.READY
    t.join()


def test_wait_field_timeout_returns_last_value(store):
    store.hset("k", "state", "Pending")
    v = store.wait_field("k", "state", lambda s: s == "Done", timeout=0.1)
    assert v == "Pending"


def test_pilot_wait_active_event_driven():
    topo = _topo("site:a")
    with PilotManager(topology=topo) as mgr:
        p = mgr.start_pilot(resource_url="sim://site:a")
        assert p.wait_active(timeout=10.0) == "Active"


# -------------------------------------------------- fractional chunk locality
def test_fractional_chunk_locality_scoring():
    topo = _topo("site:a", "site:b", "site:c")
    with PilotManager(topology=topo) as mgr:
        pa = mgr.start_pilot_data(service_url="mem://site:a/pd", affinity="site:a")
        pb = mgr.start_pilot_data(service_url="mem://site:b/pd", affinity="site:b")
        du = mgr.session.submit_du(
            name="loc", files={"blob": b"l" * 4096}, chunk_size=1024, target=pa
        ).du
        du.wait()
        mgr.transfer.replicate_chunks(du, pa, pb, [0])  # 1/4 of the bytes
        pilots = {
            s: mgr.start_pilot(resource_url=f"sim://{s}", slots=0)
            for s in ("site:a", "site:b", "site:c")
        }
        [p.wait_active() for p in pilots.values()]
        cu = mgr.session.submit_cu(executable="noop-loc", input_data=[du]).cu
        engine = mgr.cds.engine
        loc = {
            s: engine.chunk_locality(cu, p) for s, p in pilots.items()
        }
        assert loc["site:a"] == 1.0  # full replica linkable
        assert loc["site:b"] == pytest.approx(0.25)  # one of four chunks
        assert loc["site:c"] == 0.0
