"""Async event-driven scheduler: placement plugins, pipelined staging,
event-ordering determinism, replica-aware transfer cache."""

import time

import pytest

from repro.core import (
    AsyncScheduler,
    ComputeDataService,
    ComputeUnitDescription,
    CoordinationStore,
    CUState,
    FUNCTIONS,
    PilotComputeDescription,
    PilotComputeService,
    PilotManager,
    Session,
    PlacementStrategy,
    RuntimeContext,
    Topology,
    TransferService,
    list_strategies,
    make_strategy,
    register_strategy,
)

SITE_A, SITE_B = "grid:sitea", "grid:siteb"


def _topo() -> Topology:
    topo = Topology()
    topo.register(SITE_A, bandwidth=20e6, latency=0.05)
    topo.register(SITE_B, bandwidth=20e6, latency=0.05)
    return topo


def _register_noop():
    FUNCTIONS.register("sched-noop", lambda cu_ctx: "ok")


# ------------------------------------------------------------------ registry
def test_strategy_registry_roundtrip():
    names = list_strategies()
    for expected in ("cost", "data-local", "queue-depth", "round-robin", "random"):
        assert expected in names
    for name in names:
        s = make_strategy(name)
        assert isinstance(s, PlacementStrategy)
        assert s.name == name

    @register_strategy("test-custom")
    class Custom(PlacementStrategy):
        def rank(self, cu, candidates):
            return list(candidates)

    assert "test-custom" in list_strategies()
    assert isinstance(make_strategy("test-custom"), Custom)
    with pytest.raises(KeyError):
        make_strategy("no-such-strategy")


def test_unknown_scheduler_mode_rejected():
    with pytest.raises(ValueError):
        PilotManager(scheduler_mode="warp")


# ------------------------------------------------------- async end-to-end
def test_async_mode_completes_workload():
    _register_noop()
    with Session(topology=_topo(), scheduler_mode="async") as m:
        pd = m.start_pilot_data(
            service_url=f"mem://{SITE_B}/pd", affinity=SITE_B
        )
        p = m.start_pilot(resource_url=f"sim://{SITE_A}", slots=2)
        p.wait_active()
        du = m.submit_du(name="in", files={"a": b"z" * 4096}, target=pd)
        du.wait()
        cus = [
            m.submit_cu(executable="sched-noop", input_data=[du])
            for _ in range(4)
        ]
        assert m.wait(timeout=30)
        assert all(cu.state == CUState.DONE for cu in cus)
        # every placement came through the shared CDS path with a policy tag
        ds = m.cds.decisions()
        assert len(ds) == 4
        assert all(d["policy"] == "cost" for d in ds)
        # staging was prefetched by the pipeline, not paid by the agents
        assert any(r.pipelined for r in m.transfer.records())


def test_pipelining_overlap_staging_during_execution():
    """Staging of CU B's inputs must START before CU A completes (the
    definition of transfer pipelining on a 1-slot pilot)."""
    _register_noop()
    with Session(
        topology=_topo(), scheduler_mode="async", time_scale=0.05
    ) as m:
        pd = m.start_pilot_data(
            service_url=f"mem://{SITE_B}/pd", affinity=SITE_B
        )
        p = m.start_pilot(resource_url=f"sim://{SITE_A}", slots=1)
        p.wait_active()
        du_a = m.submit_du(name="ina", files={"a": b"a" * 8192}, target=pd)
        du_b = m.submit_du(name="inb", files={"b": b"b" * 8192}, target=pd)
        du_a.wait(), du_b.wait()
        # sim_compute 2.0 × time_scale 0.05 → ~100 ms wall per CU
        cu_a = m.submit_cu(
            executable="sched-noop", input_data=[du_a], sim_compute_s=2.0
        )
        cu_b = m.submit_cu(
            executable="sched-noop", input_data=[du_b], sim_compute_s=2.0
        )
        assert m.wait(timeout=60)
        assert cu_a.state == CUState.DONE and cu_b.state == CUState.DONE
        first, second = (
            (cu_a, cu_b)
            if cu_a.timings.run_end <= cu_b.timings.run_end
            else (cu_b, cu_a)
        )
        second_du = second.description.input_data[0]
        recs = [
            r
            for r in m.transfer.records()
            if r.du_id == second_du and r.pipelined and not r.linked
        ]
        assert recs, "second CU's input was not prefetched"
        # the pipelined transfer began while the first CU was still running
        assert recs[0].wall_start < first.timings.run_end
        # and the agent charged no critical-path staging for it
        assert second.timings.sim_stage_s == 0.0
        assert second.timings.sim_prefetch_s > 0.0


def test_bulk_batches_multi_du_same_source():
    """Multi-DU inputs from one source PD coalesce into one costed bulk
    transfer: a single setup latency instead of one per DU."""
    _register_noop()
    with Session(topology=_topo(), scheduler_mode="async") as m:
        pd = m.start_pilot_data(
            service_url=f"mem://{SITE_B}/pd", affinity=SITE_B
        )
        p = m.start_pilot(resource_url=f"sim://{SITE_A}", slots=1)
        p.wait_active()
        dus = [
            m.submit_du(
                name=f"part{i}", files={f"p{i}": b"x" * 4096}, target=pd
            )
            for i in range(3)
        ]
        [du.wait() for du in dus]
        cu = m.submit_cu(
            executable="sched-noop", input_data=list(dus)
        )
        assert m.wait(timeout=30)
        assert cu.state == CUState.DONE
        recs = [
            r
            for r in m.transfer.records()
            if r.du_id in {du.id for du in dus} and r.pipelined
        ]
        assert len(recs) == 3
        assert len({r.batch_id for r in recs}) == 1  # one bulk transfer
        bulk_sim = sum(r.sim_seconds for r in recs)
        per_du_sim = sum(
            m.transfer.simulated_transfer_time(du.size, pd, p.sandbox)
            for du in dus
        )
        # batched: one latency+registration for the batch vs three
        assert bulk_sim < per_du_sim - 0.05


def test_replica_cache_short_circuits_and_invalidates():
    _register_noop()
    with Session(topology=_topo()) as m:
        pd_b = m.start_pilot_data(
            service_url=f"mem://{SITE_B}/pd", affinity=SITE_B
        )
        du = m.submit_du(name="hot", files={"a": b"h" * 2048}, target=pd_b).result()
        ts = m.transfer
        pd1, linked1 = ts.resolve_access(du, SITE_A)
        assert pd1 is pd_b and not linked1
        h0 = ts.cache_hits
        pd2, linked2 = ts.resolve_access(du, SITE_A)
        assert (pd2, linked2) == (pd1, linked1)
        assert ts.cache_hits > h0  # repeated lookup short-circuited
        # new replica at SITE_A bumps the DU's location version → the stale
        # entry self-invalidates and the lookup now resolves to a link
        pd_a = m.start_pilot_data(
            service_url=f"mem://{SITE_A}/pd", affinity=SITE_A
        )
        ts.replicate(du, pd_b, pd_a)
        pd3, linked3 = ts.resolve_access(du, SITE_A)
        assert pd3 is pd_a and linked3


# ------------------------------------------------------------- determinism
def _scripted_run(seed: int):
    """One manually-stepped async scheduler over a scripted submission
    sequence; returns (normalized event kinds, decision pilot indices)."""
    _register_noop()
    store = CoordinationStore()
    topo = _topo()
    ctx = RuntimeContext(store=store, topology=topo)
    TransferService(ctx)
    cds = ComputeDataService(
        ctx, strategy=make_strategy("random", seed=seed), start_loop=False
    )
    pcs = PilotComputeService(ctx)
    pilots = [
        pcs.create_pilot(
            PilotComputeDescription(resource_url=f"sim://{s}", slots=0)
        )
        for s in (SITE_A, SITE_B)
    ]
    for p in pilots:
        p.wait_active()
        cds.add_pilot_compute(p)
    # subscribe only after the pilots settle: the event log then contains
    # exclusively the scripted submission sequence
    sched = AsyncScheduler(cds, stage_workers=0, autostart=False)
    try:
        for i in range(8):
            cds.submit_compute_unit(
                ComputeUnitDescription(executable="sched-noop")
            )
        sched.drain()
        pilot_index = {p.id: i for i, p in enumerate(pilots)}
        kinds = [ev.kind for ev in sched.event_log]
        decisions = [pilot_index[d["pilot"]] for d in cds.decisions()]
        return kinds, decisions
    finally:
        sched.stop()
        cds.cancel()
        pcs.cancel()
        store.close()


def test_event_ordering_determinism_under_seeded_strategy():
    run1 = _scripted_run(seed=42)
    run2 = _scripted_run(seed=42)
    assert run1 == run2
    assert run1[0], "event log must not be empty"
    assert len(run1[1]) == 8
    # a different seed must be able to produce a different placement
    # sequence (otherwise the seeding is dead code)
    other = [_scripted_run(seed=s)[1] for s in (1, 2, 3)]
    assert any(o != run1[1] for o in other)


def test_sync_and_async_modes_make_identical_decisions():
    """Same store state + same strategy ⇒ same placements, both modes."""
    _register_noop()

    def run(mode: str):
        with Session(topology=_topo(), scheduler_mode=mode) as m:
            pd = m.start_pilot_data(
                service_url=f"mem://{SITE_B}/pd", affinity=SITE_B
            )
            # slots=0: pilots accept no work, so queue state stays frozen
            # and the decision sequence depends only on the submissions
            pa = m.start_pilot(resource_url=f"sim://{SITE_A}", slots=0)
            pb = m.start_pilot(resource_url=f"sim://{SITE_B}", slots=0)
            pa.wait_active(), pb.wait_active()
            idx = {pa.id: "A", pb.id: "B"}
            du = m.submit_du(name="d", files={"a": b"d" * 65536}, target=pd)
            du.wait()
            for i in range(6):
                m.submit_cu(
                    executable="sched-noop",
                    input_data=[du] if i % 2 == 0 else [],
                )
            deadline = time.monotonic() + 10
            while len(m.cds.decisions()) < 6 and time.monotonic() < deadline:
                time.sleep(0.01)
            ds = m.cds.decisions()
            assert len(ds) == 6, f"{mode}: only {len(ds)} decisions"
            return [(idx[d["pilot"]], d["strategy"]) for d in ds]

    assert run("sync") == run("async")
