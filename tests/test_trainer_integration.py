"""End-to-end integration: training driven THROUGH the Pilot-API v2 —
one-shot DAG submission, data-affinity placement, checkpoint-DU chains,
fault recovery, elasticity."""

import threading

import pytest

from repro.configs import get_config
from repro.core import Session, make_tpu_fleet_topology
from repro.training.trainer import PilotTrainer

TINY = dict(
    total_steps=9,
    chunk_steps=3,
    batch=4,
    seq=32,
    peak_lr=3e-3,
    n_shards=2,
    tokens_per_shard=4_000,
)


def tiny_cfg():
    from repro.configs.base import reduced

    return reduced(
        get_config("h2o-danube-1.8b"),
        n_layers=2,
        d_model=32,
        n_heads=2,
        n_kv_heads=1,
        d_ff=64,
        vocab_size=128,
        head_dim=16,
    )


@pytest.fixture()
def sess():
    topo, _ = make_tpu_fleet_topology(pods=2, hosts_per_pod=1)
    with Session(
        topology=topo, enable_heartbeat_monitor=True, heartbeat_timeout_s=0.5
    ) as s:
        yield s


def test_end_to_end_training_improves_loss(sess):
    sess.start_pilot_data(
        service_url="sharedfs://cluster:pod0/scratch", affinity="cluster:pod0"
    )
    p = sess.start_pilot(resource_url="sim://cluster:pod0:host0", slots=1)
    p.wait_active()
    tr = PilotTrainer(tiny_cfg(), sess, run_name="t-e2e", **TINY)
    tr.stage_data(affinities=["cluster:pod0"])
    summary = tr.run()
    assert summary["steps"] == TINY["total_steps"]
    assert summary["improved"], summary
    # the checkpoint chain is a DU chain
    assert len(tr.ckpt_dus) == summary["chunks"] + 1
    params = tr.restore_params()
    assert "embed" in params


def test_training_distributes_by_affinity(sess):
    """Shards placed at two sites → chunks run on the co-located pilots."""
    sess.start_pilot_data(
        service_url="sharedfs://cluster:pod0/s0", affinity="cluster:pod0"
    )
    sess.start_pilot_data(
        service_url="sharedfs://cluster:pod1/s1", affinity="cluster:pod1"
    )
    p0 = sess.start_pilot(resource_url="sim://cluster:pod0:host0", slots=1)
    p1 = sess.start_pilot(resource_url="sim://cluster:pod1:host0", slots=1)
    p0.wait_active(), p1.wait_active()
    tr = PilotTrainer(tiny_cfg(), sess, run_name="t-aff", **TINY)
    tr.stage_data(affinities=["cluster:pod0", "cluster:pod1"])
    summary = tr.run()
    assert summary["improved"]
    # chunks alternate shards; both pods' pilots should have participated
    assert len(summary["pilots_used"]) == 2, summary["pilots_used"]


def test_training_survives_pilot_failure(sess):
    """Kill the only active pilot mid-run: the heartbeat monitor requeues
    the chunk; a standby pilot resumes from the checkpoint DU."""
    sess.start_pilot_data(
        service_url="sharedfs://cluster:pod0/s", affinity="cluster:pod0"
    )
    p0 = sess.start_pilot(resource_url="sim://cluster:pod0:host0", slots=1)
    p1 = sess.start_pilot(resource_url="sim://cluster:pod1:host0", slots=1)
    p0.wait_active(), p1.wait_active()
    tr = PilotTrainer(tiny_cfg(), sess, run_name="t-ft", **TINY)
    tr.stage_data(affinities=["cluster:pod0"])

    killer = threading.Timer(1.0, p0.fail)
    killer.start()
    try:
        summary = tr.run(timeout_per_chunk=120.0)
    finally:
        killer.cancel()
    assert summary["steps"] == TINY["total_steps"]
    # at least one chunk must have run on the surviving pilot
    assert p1.id in summary["pilots_used"]


def test_elastic_scale_up_mid_run(sess):
    """A pilot added mid-run picks up later chunks (elastic scaling) —
    even though the WHOLE DAG was submitted before the pilot existed."""
    sess.start_pilot_data(
        service_url="sharedfs://cluster:pod0/s", affinity="cluster:pod0"
    )
    p0 = sess.start_pilot(resource_url="sim://cluster:pod0:host0", slots=1)
    p0.wait_active()
    tr = PilotTrainer(
        tiny_cfg(),
        sess,
        run_name="t-elastic",
        total_steps=8,
        chunk_steps=2,
        batch=2,
        seq=32,
        n_shards=1,
        tokens_per_shard=4_000,
    )
    tr.stage_data(affinities=None)

    added = {}

    def add_pilot():
        p_new = sess.start_pilot(resource_url="sim://cluster:pod0:host0", slots=1)
        added["pilot"] = p_new
        # freeze the original so the new pilot must take over
        p0.cancel()

    threading.Timer(1.0, add_pilot).start()
    summary = tr.run(timeout_per_chunk=120.0)
    assert summary["steps"] == 8
    assert added["pilot"].id in summary["pilots_used"]
