"""MoE routing/dispatch invariants + blocked attention + chunked CE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.configs.base import MoEConfig, reduced
from repro.models.attention import gqa_attention
from repro.models.blocked_attention import blocked_attention
from repro.models.layers import chunked_cross_entropy, softmax_cross_entropy, unembed
from repro.models.moe import _capacity, init_moe, moe_mlp_local


def moe_cfg(n_experts=8, top_k=2, cap=4.0):
    base = reduced(get_config("granite-moe-3b-a800m"))
    import dataclasses

    return dataclasses.replace(
        base,
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, d_ff_expert=32,
                      capacity_factor=cap),
    )


def test_moe_output_shape_and_aux():
    cfg = moe_cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = moe_mlp_local(params, x, cfg)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all()
    # switch aux loss ≥ 1 (equality at perfect balance)
    assert float(aux) >= 0.99


def test_moe_grads_flow_to_router_and_experts():
    cfg = moe_cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))

    def loss(p):
        y, aux = moe_mlp_local(p, x, cfg)
        return (y**2).mean() + 0.01 * aux

    grads = jax.grad(loss)(params)
    assert float(jnp.abs(grads["router"]["w"]).max()) > 0
    assert float(jnp.abs(grads["gate"]).max()) > 0


def test_moe_capacity_drops_tokens():
    """With capacity_factor → tiny, most tokens are dropped: output ~ 0 for
    dropped tokens but finite everywhere."""
    cfg_full = moe_cfg(cap=64.0)
    cfg_tight = moe_cfg(cap=0.01)
    params = init_moe(jax.random.PRNGKey(0), cfg_full)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg_full.d_model))
    y_full, _ = moe_mlp_local(params, x, cfg_full)
    y_tight, _ = moe_mlp_local(params, x, cfg_tight)
    assert float(jnp.abs(y_full).mean()) > float(jnp.abs(y_tight).mean())


def test_moe_expert_padding_never_routed():
    cfg = moe_cfg(n_experts=5, top_k=2)
    params = init_moe(jax.random.PRNGKey(0), cfg, ep=4)  # pads 5 → 8
    assert params["gate"].shape[0] == 8
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))
    y, _ = moe_mlp_local(params, x, cfg)
    assert jnp.isfinite(y).all()


def test_capacity_rounding():
    cfg = moe_cfg(n_experts=8, top_k=2, cap=1.25)
    c = _capacity(1024, cfg)
    assert c % 8 == 0 and c >= 1024 * 2 * 1.25 / 8


# ------------------------------------------------------- blocked attention
@settings(max_examples=10, deadline=None)
@given(
    sq=st.sampled_from([48, 96, 130]),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 3]),
    causal=st.booleans(),
    window=st.sampled_from([None, 24]),
    bk=st.sampled_from([32, 64]),
)
def test_prop_blocked_attention_matches_ref(sq, hkv, g, causal, window, bk):
    rng = jax.random.PRNGKey(sq * 7 + bk)
    b, d = 2, 32
    hq = hkv * g
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d))
    k = jax.random.normal(ks[1], (b, sq, hkv, d))
    v = jax.random.normal(ks[2], (b, sq, hkv, d))
    pos = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
    out = blocked_attention(q, k, v, pos, pos, causal, window, bk, False)
    ref = gqa_attention(q, k, v, pos, pos, causal=causal, window=window)
    assert float(jnp.abs(out - ref).max()) < 1e-4


def test_blocked_attention_grad_matches_ref():
    rng = jax.random.PRNGKey(3)
    b, s, hq, hkv, d = 1, 64, 4, 2, 16
    ks = jax.random.split(rng, 3)
    q, k, v = (jax.random.normal(ks[i], (b, s, hq if i == 0 else hkv, d)) for i in range(3))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def f(fn):
        return lambda q, k, v: (fn(q, k, v) ** 2).sum()

    g_blk = jax.grad(
        f(lambda q, k, v: blocked_attention(q, k, v, pos, pos, True, None, 32, False)),
        argnums=(0, 1, 2),
    )(q, k, v)
    g_ref = jax.grad(
        f(lambda q, k, v: gqa_attention(q, k, v, pos, pos, causal=True)),
        argnums=(0, 1, 2),
    )(q, k, v)
    for a, r in zip(g_blk, g_ref):
        assert float(jnp.abs(a - r).max()) < 2e-3


# ----------------------------------------------------------- chunked CE
def test_chunked_ce_matches_full():
    cfg = reduced(get_config("h2o-danube-1.8b"))
    from repro.models.layers import init_embedding

    params = init_embedding(jax.random.PRNGKey(0), cfg)
    b, s = 2, 48
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.1
    labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size)
    full = softmax_cross_entropy(unembed(x, params, cfg), labels)
    for chunk in (16, 17, 48, 100):
        ck = chunked_cross_entropy(x, params, cfg, labels, chunk=chunk)
        assert float(jnp.abs(ck - full)) < 1e-5, chunk


def test_chunked_ce_grad_matches_full():
    cfg = reduced(get_config("h2o-danube-1.8b"))
    from repro.models.layers import init_embedding

    params = init_embedding(jax.random.PRNGKey(0), cfg)
    b, s = 1, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.1
    labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size)
    g_full = jax.grad(
        lambda x: softmax_cross_entropy(unembed(x, params, cfg), labels)
    )(x)
    g_chunk = jax.grad(
        lambda x: chunked_cross_entropy(x, params, cfg, labels, chunk=8)
    )(x)
    assert float(jnp.abs(g_full - g_chunk).max()) < 1e-5


# ------------------------------------------------- windowed ring KV cache
def test_ring_cache_wraps_and_matches_forward():
    """Decode with a window-sized ring cache must equal teacher forcing for
    an SWA model even after the ring wraps several times."""
    import dataclasses

    from repro.models import build_model

    cfg = reduced(get_config("h2o-danube-1.8b"), sliding_window=4, n_layers=2)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b, s = 1, 12  # 3× wrap of the 4-slot ring
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    full_logits, _ = api.forward(params, tokens)
    cache = api.init_cache(b, s)
    # ring allocation: swa cache length == window
    assert cache["groups"]["pos0"]["k"].shape[2] == 4
    step = jax.jit(api.decode_step)
    outs = []
    for i in range(s):
        lg, cache = step(params, cache, tokens[:, i : i + 1], jnp.int32(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full_logits, np.float32),
        np.asarray(dec, np.float32),
        atol=2e-2,
        rtol=2e-2,
    )


def test_int8_kv_cache_decode_matches_forward():
    """int8-quantized ring KV cache: decode ≈ teacher forcing (quantization
    noise bounded) — the §Perf decode-memory lever."""
    import dataclasses

    from repro.models import build_model

    cfg = dataclasses.replace(
        reduced(get_config("h2o-danube-1.8b"), sliding_window=4, n_layers=2),
        kv_cache_dtype="int8",
    )
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b, s = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    full, _ = api.forward(params, tokens)
    cache = api.init_cache(b, s)
    assert cache["groups"]["pos0"]["k"].dtype == jnp.int8
    assert cache["groups"]["pos0"]["k"].shape[2] == 4  # ring + int8 compose
    step = jax.jit(api.decode_step)
    outs = []
    for i in range(s):
        lg, cache = step(params, cache, tokens[:, i : i + 1], jnp.int32(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    scale = float(jnp.abs(jnp.asarray(full, jnp.float32)).max())
    err = float(jnp.abs(jnp.asarray(full, jnp.float32) - jnp.asarray(dec, jnp.float32)).max())
    assert err / max(scale, 1.0) < 0.05
