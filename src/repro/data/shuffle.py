"""Windowed shuffle: streaming map → reduce over per-reducer DU streams.

The classic Pilot-Data shuffle (bench_dataflow) is seal-gated: every
reducer parks ``Waiting`` until every mapper has sealed its intermediate
DU, so the reduce stage's stage-in + compute serializes behind the
slowest mapper.  This module keeps the same declarative DAG but makes the
intermediate DUs **streaming**: each mapper partitions its records into
``n_reducers`` per-reducer output DUs and flushes them incrementally
(``CUContext.flush_output`` → ordered chunk-availability events), and each
reducer is released the moment its inputs have published their first
*window* of chunks — map and reduce overlap on the critical path.

Records are length-prefixed ``(key, value)`` pairs so reducers can decode
them incrementally from the chunk stream (chunk boundaries are byte
offsets, not record boundaries): :class:`RecordAssembler` stitches chunks
back into records as they arrive.
"""

from __future__ import annotations

import dataclasses
import struct
import zlib
from typing import Callable, Dict, Iterable, List, Sequence, Tuple

from ..core import DataUnitDescription
from ..core.data_unit import DEFAULT_CHUNK_SIZE

#: ``map_fn(relpath, file_bytes) -> iterable of (key, value_bytes)``
MapFn = Callable[[str, bytes], Iterable[Tuple[str, bytes]]]
#: ``reduce_fn(key, [value_bytes, ...]) -> reduced_bytes``
ReduceFn = Callable[[str, List[bytes]], bytes]

_HEADER = struct.Struct(">II")  # key length, value length


def encode_record(key: str, value: bytes) -> bytes:
    kb = key.encode("utf-8")
    return _HEADER.pack(len(kb), len(value)) + kb + bytes(value)


def decode_records(data: bytes) -> List[Tuple[str, bytes]]:
    """Decode a complete buffer of length-prefixed records."""
    asm = RecordAssembler()
    records = asm.feed(data)
    if asm.pending:
        raise ValueError(f"trailing partial record ({asm.pending} bytes)")
    return records


def partition_of(key: str, n_reducers: int) -> int:
    """Deterministic key → reducer partition (stable across processes)."""
    return zlib.crc32(key.encode("utf-8")) % n_reducers


class RecordAssembler:
    """Incremental decoder: feed arbitrary byte fragments (stream chunks),
    get back every record completed so far.  Partial records carry over
    to the next ``feed`` — chunk boundaries never split a decoded record.
    """

    def __init__(self) -> None:
        self._buf = bytearray()

    @property
    def pending(self) -> int:
        """Bytes buffered that do not yet form a complete record."""
        return len(self._buf)

    def feed(self, data: bytes) -> List[Tuple[str, bytes]]:
        self._buf.extend(data)
        out: List[Tuple[str, bytes]] = []
        while True:
            if len(self._buf) < _HEADER.size:
                return out
            klen, vlen = _HEADER.unpack_from(self._buf)
            total = _HEADER.size + klen + vlen
            if len(self._buf) < total:
                return out
            key = bytes(self._buf[_HEADER.size : _HEADER.size + klen])
            value = bytes(self._buf[_HEADER.size + klen : total])
            del self._buf[:total]
            out.append((key.decode("utf-8"), value))


def make_mapper(map_fn: MapFn, n_reducers: int, flush_every: int = 8) -> Callable:
    """Executable factory: partition every mapped record by key into the
    CU's ``n_reducers`` streaming output DUs, flushing each partition's
    stream every ``flush_every`` records so reducers see chunk prefixes
    while the mapper is still running."""

    def mapper(cu_ctx) -> int:
        out_ids = cu_ctx.cu.description.output_data
        if len(out_ids) != n_reducers:
            raise RuntimeError(
                f"mapper expects {n_reducers} output DUs, got {len(out_ids)}"
            )
        emitted = 0
        part_seq = [0] * n_reducers
        part_pending = [0] * n_reducers
        for du_id in cu_ctx.cu.description.input_data:
            for rel in sorted(cu_ctx.input_manifest(du_id)):
                data = cu_ctx.read_input(du_id, rel)
                for key, value in map_fn(rel, data):
                    r = partition_of(key, n_reducers)
                    cu_ctx.write_output(
                        f"part-{part_seq[r]:06d}",
                        encode_record(key, value),
                        index=r,
                    )
                    part_seq[r] += 1
                    part_pending[r] += 1
                    emitted += 1
                    if part_pending[r] >= flush_every:
                        part_pending[r] = 0
                        if not cu_ctx.flush_output(r):
                            return emitted  # foreign attempt owns the stream
        for r in range(n_reducers):
            if part_pending[r] and not cu_ctx.flush_output(r):
                return emitted
        return emitted

    return mapper


def make_reducer(reduce_fn: ReduceFn, window: int = 4) -> Callable:
    """Executable factory: consume every streaming input DU chunk-by-chunk
    as the producers publish (``CUContext.stream_input`` — read frontier
    advances behind the reducer so consumed stream chunks are evictable),
    group values by key, and write one sorted record file of
    ``reduce_fn(key, values)`` results."""

    def reducer(cu_ctx) -> int:
        groups: Dict[str, List[bytes]] = {}
        for du_id in cu_ctx.cu.description.input_data:
            asm = RecordAssembler()
            for _idx, chunk in cu_ctx.stream_input(du_id, window=window):
                for key, value in asm.feed(chunk):
                    groups.setdefault(key, []).append(value)
            if asm.pending:
                raise RuntimeError(
                    f"du://{du_id}: stream ended mid-record "
                    f"({asm.pending} trailing bytes)"
                )
        blob = b"".join(
            encode_record(key, reduce_fn(key, groups[key]))
            for key in sorted(groups)
        )
        cu_ctx.write_output("reduced.bin", blob)
        return len(groups)

    return reducer


@dataclasses.dataclass
class ShuffleResult:
    """Futures for one windowed-shuffle DAG submission."""

    mappers: List  # CUFuture per mapper
    reducers: List  # CUFuture per reducer
    outputs: List  # DUFuture per reducer output (sealed record files)

    def wait(self, timeout: float = 120.0) -> List[bytes]:
        """Block for the reduce stage; returns each reducer's record blob."""
        for fut in self.reducers:
            fut.result(timeout=timeout)
        return [fut.du.read("reduced.bin") for fut in self.outputs]


def windowed_shuffle(
    session,
    inputs: Sequence,
    map_fn: MapFn,
    reduce_fn: ReduceFn,
    n_reducers: int,
    *,
    window: int = 2,
    flush_every: int = 8,
    chunk_size: int = DEFAULT_CHUNK_SIZE,
    size_hint: int = 0,
    name: str = "shuffle",
    sim_map_s: float = 0.0,
    sim_reduce_s: float = 0.0,
) -> ShuffleResult:
    """Submit a streaming map → shuffle → reduce DAG in one shot.

    Every mapper gets ``n_reducers`` *streaming* intermediate DUs
    (``ready_chunks=window``); reducer *r* consumes partition *r* of every
    mapper and is released on the first published window instead of the
    last mapper seal.  ``chunk_size`` tunes streaming granularity (smaller
    chunks → earlier release, more events), ``flush_every`` the mapper's
    flush cadence, and ``size_hint`` optionally switches the readiness
    threshold to a fraction-of-expected-chunks basis downstream."""
    if n_reducers < 1:
        raise ValueError("n_reducers must be >= 1")
    map_name = f"{name}.map"
    reduce_name = f"{name}.reduce"
    session.register_function(map_name, make_mapper(map_fn, n_reducers, flush_every))
    session.register_function(reduce_name, make_reducer(reduce_fn, window=window))
    map_futs = []
    for m, src in enumerate(inputs):
        outs = [
            DataUnitDescription(
                name=f"{name}.m{m}.r{r}",
                streaming=True,
                ready_chunks=window,
                chunk_size=chunk_size,
                size_hint=size_hint,
            )
            for r in range(n_reducers)
        ]
        map_futs.append(
            session.submit_cu(
                executable=map_name,
                input_data=[src],
                output_data=outs,
                sim_compute_s=sim_map_s,
            )
        )
    reduce_futs = []
    out_futs = []
    for r in range(n_reducers):
        fut = session.submit_cu(
            executable=reduce_name,
            input_data=[mf.outputs[r] for mf in map_futs],
            output_data=[DataUnitDescription(name=f"{name}.out.r{r}")],
            sim_compute_s=sim_reduce_s,
        )
        reduce_futs.append(fut)
        out_futs.append(fut.outputs[0])
    return ShuffleResult(mappers=map_futs, reducers=reduce_futs, outputs=out_futs)
