from .pipeline import (
    Prefetcher,
    ShardReader,
    decode_tokens,
    encode_tokens,
    make_token_shards,
    shard_dus,
)

__all__ = [
    "Prefetcher",
    "ShardReader",
    "decode_tokens",
    "encode_tokens",
    "make_token_shards",
    "shard_dus",
]
