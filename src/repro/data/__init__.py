from .pipeline import (
    Prefetcher,
    ShardReader,
    decode_tokens,
    encode_tokens,
    make_token_shards,
    shard_dus,
)
from .shuffle import (
    RecordAssembler,
    ShuffleResult,
    decode_records,
    encode_record,
    make_mapper,
    make_reducer,
    partition_of,
    windowed_shuffle,
)

__all__ = [
    "Prefetcher",
    "RecordAssembler",
    "ShardReader",
    "ShuffleResult",
    "decode_records",
    "decode_tokens",
    "encode_record",
    "encode_tokens",
    "make_mapper",
    "make_reducer",
    "make_token_shards",
    "partition_of",
    "shard_dus",
    "windowed_shuffle",
]
