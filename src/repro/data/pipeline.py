"""DU-based token data pipeline.

Training data is organized exactly the way the paper's BWA workload was
(§6.3): a large input partitioned into per-task Data-Units ("each task
consumes a unique part of the data") plus a *shared* DU every task needs
(the reference-genome analogue — here: tokenizer/eval artifacts).  Shards
are serialized token arrays; the pipeline reads whichever replica is
co-located with the executing pilot (via CUContext) and cuts fixed-shape
next-token-prediction batches with a background prefetcher.
"""

from __future__ import annotations

import io
import queue
import threading
from typing import Dict, Iterator, List, Optional

import numpy as np

from ..core import CoordinationStore, DataUnit, DataUnitDescription


def encode_tokens(tokens: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, tokens.astype(np.int32), allow_pickle=False)
    return buf.getvalue()


def decode_tokens(data: bytes) -> np.ndarray:
    return np.load(io.BytesIO(data), allow_pickle=False)


def make_token_shards(
    n_shards: int,
    tokens_per_shard: int,
    vocab_size: int,
    seed: int = 0,
    files_per_shard: int = 4,
) -> List[Dict[str, bytes]]:
    """Synthetic corpus: ``n_shards`` shard file-sets (each a DU's files).

    Tokens follow a Zipf-like unigram distribution (not uniform) so that a
    few optimizer steps measurably reduce the loss — the e2e training tests
    assert improvement, and uniform noise has nothing to learn."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = 1.0 / (ranks + 5.0)
    probs /= probs.sum()
    shards = []
    per_file = tokens_per_shard // files_per_shard
    for s in range(n_shards):
        files = {}
        for f in range(files_per_shard):
            toks = rng.choice(
                vocab_size, size=per_file, p=probs
            ).astype(np.int32)
            files[f"tokens_{f:03d}.npy"] = encode_tokens(toks)
        shards.append(files)
    return shards


def shard_dus(
    shards: List[Dict[str, bytes]],
    store: CoordinationStore,
    name: str = "corpus",
    affinities: Optional[List[Optional[str]]] = None,
) -> List[DataUnit]:
    """Wrap shard file-sets into Data-Units (partitioned-data pattern)."""
    dus = []
    for i, files in enumerate(shards):
        aff = affinities[i % len(affinities)] if affinities else None
        dus.append(
            DataUnit(
                DataUnitDescription(
                    name=f"{name}.shard{i:03d}", files=files, affinity=aff
                ),
                store,
            )
        )
    return dus


class ShardReader:
    """Cuts [batch, seq+1] windows from a shard's token stream (wrapping)."""

    def __init__(self, files: Dict[str, bytes], seed: int = 0):
        arrays = [decode_tokens(files[k]) for k in sorted(files)]
        self.tokens = np.concatenate(arrays) if arrays else np.zeros(0, np.int32)
        self.rng = np.random.default_rng(seed)

    @classmethod
    def from_cu_context(cls, cu_ctx, du_id: str, seed: int = 0) -> "ShardReader":
        manifest = cu_ctx.input_manifest(du_id)
        files = {rel: cu_ctx.read_input(du_id, rel) for rel in manifest}
        return cls(files, seed=seed)

    def batches(
        self, batch: int, seq: int, start_step: int = 0
    ) -> Iterator[Dict[str, np.ndarray]]:
        n = len(self.tokens)
        need = seq + 1
        assert n >= need, f"shard too small: {n} < {need}"
        step = start_step
        while True:
            starts = self.rng.integers(0, n - need, size=batch)
            window = np.stack([self.tokens[s : s + need] for s in starts])
            yield {
                "tokens": window[:, :-1].astype(np.int32),
                "labels": window[:, 1:].astype(np.int32),
            }
            step += 1


class Prefetcher:
    """Background-thread prefetch with bounded queue (overlaps host-side
    batch prep with device compute)."""

    _DONE = object()

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()

        def run():
            try:
                for item in it:
                    if self._stop.is_set():
                        return
                    self._q.put(item)
            except BaseException as e:  # noqa: BLE001
                self._err = e
            finally:
                self._q.put(self._DONE)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._DONE:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
