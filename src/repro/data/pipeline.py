"""DU-based token data pipeline.

Training data is organized exactly the way the paper's BWA workload was
(§6.3): a large input partitioned into per-task Data-Units ("each task
consumes a unique part of the data") plus a *shared* DU every task needs
(the reference-genome analogue — here: tokenizer/eval artifacts).  Shards
are serialized token arrays; the pipeline reads whichever replica is
co-located with the executing pilot (via CUContext) and cuts fixed-shape
next-token-prediction batches with a background prefetcher.

Two on-DU formats coexist:

  * ``.npy`` files (:func:`encode_tokens`) — self-describing, read whole
    via ``CUContext.read_input``;
  * raw little-endian int32 ``.bin`` files (:func:`encode_raw_tokens`) —
    the *chunk-streamable* format: the DU's canonical byte stream
    (files concatenated in sorted-relpath order) IS the token stream, so
    :class:`StreamingShardReader` can consume published chunk prefixes
    through ``CUContext.stream_input`` before the whole shard is staged.
"""

from __future__ import annotations

import io
import queue
import threading
from typing import Dict, Iterator, List, Optional

import numpy as np

from ..core import CoordinationStore, DataUnit, DataUnitDescription

#: default shard chunk size — small enough that a 200 kB demo shard still
#: splits into several chunks (so prefix streaming/prefetch is exercised)
SHARD_CHUNK_BYTES = 64 * 1024


def encode_tokens(tokens: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, tokens.astype(np.int32), allow_pickle=False)
    return buf.getvalue()


def decode_tokens(data: bytes) -> np.ndarray:
    return np.load(io.BytesIO(data), allow_pickle=False)


def encode_raw_tokens(tokens: np.ndarray) -> bytes:
    """Chunk-streamable codec: raw little-endian int32, no header — any
    byte prefix of length 4k decodes to the first k tokens."""
    return np.ascontiguousarray(tokens, dtype="<i4").tobytes()


def decode_raw_tokens(data: bytes) -> np.ndarray:
    usable = len(data) - (len(data) % 4)
    return np.frombuffer(data[:usable], dtype="<i4")


def _decode_shard_file(relpath: str, data: bytes) -> np.ndarray:
    return decode_raw_tokens(data) if relpath.endswith(".bin") else decode_tokens(data)


def make_token_shards(
    n_shards: int,
    tokens_per_shard: int,
    vocab_size: int,
    seed: int = 0,
    files_per_shard: int = 4,
    fmt: str = "npy",
) -> List[Dict[str, bytes]]:
    """Synthetic corpus: ``n_shards`` shard file-sets (each a DU's files).

    Tokens follow a Zipf-like unigram distribution (not uniform) so that a
    few optimizer steps measurably reduce the loss — the e2e training tests
    assert improvement, and uniform noise has nothing to learn.

    ``fmt="raw"`` emits headerless ``tokens_*.bin`` files whose sorted
    concatenation is the raw token stream (the streamable shard format);
    ``fmt="npy"`` keeps the self-describing per-file arrays."""
    if fmt not in ("npy", "raw"):
        raise ValueError(f"unknown shard format {fmt!r} (use 'npy' or 'raw')")
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = 1.0 / (ranks + 5.0)
    probs /= probs.sum()
    encode = encode_raw_tokens if fmt == "raw" else encode_tokens
    ext = "bin" if fmt == "raw" else "npy"
    shards = []
    per_file = tokens_per_shard // files_per_shard
    for s in range(n_shards):
        files = {}
        for f in range(files_per_shard):
            toks = rng.choice(vocab_size, size=per_file, p=probs).astype(np.int32)
            files[f"tokens_{f:03d}.{ext}"] = encode(toks)
        shards.append(files)
    return shards


def shard_dus(
    shards: List[Dict[str, bytes]],
    store: CoordinationStore,
    name: str = "corpus",
    affinities: Optional[List[Optional[str]]] = None,
    chunk_size: Optional[int] = None,
) -> List[DataUnit]:
    """Wrap shard file-sets into Data-Units (partitioned-data pattern)."""
    dus = []
    for i, files in enumerate(shards):
        aff = affinities[i % len(affinities)] if affinities else None
        dus.append(
            DataUnit(
                DataUnitDescription(
                    name=f"{name}.shard{i:03d}",
                    files=files,
                    affinity=aff,
                    **({"chunk_size": chunk_size} if chunk_size else {}),
                ),
                store,
            )
        )
    return dus


def stage_shard_dus(
    session,
    shards: List[Dict[str, bytes]],
    name: str = "corpus",
    affinities: Optional[List[Optional[str]]] = None,
    chunk_size: int = SHARD_CHUNK_BYTES,
) -> List:
    """Session-native shard staging: each shard file-set becomes a chunked
    DU placed by affinity (round-robin over ``affinities``); returns the
    :class:`~repro.core.futures.DUFuture` handles.  Chunked manifests are
    what lets consumers stream prefixes (``CUContext.stream_input``) and
    the async scheduler prefetch at chunk granularity."""
    futures = []
    for i, files in enumerate(shards):
        aff = affinities[i % len(affinities)] if affinities else None
        futures.append(
            session.submit_du(
                name=f"{name}.shard{i:03d}",
                files=files,
                affinity=aff,
                chunk_size=chunk_size,
            )
        )
    return futures


class ShardReader:
    """Cuts [batch, seq+1] windows from a shard's token stream (wrapping).

    Window positions are drawn from a **per-step** RNG stream
    (``default_rng([seed, step])``), so ``batches(start_step=k)`` resumes
    exactly where an uninterrupted run would be at step k — a training
    chunk replayed after a pilot failure sees the same data it would have
    seen the first time (resume ≡ continuation)."""

    def __init__(self, files: Dict[str, bytes], seed: int = 0):
        arrays = [_decode_shard_file(k, files[k]) for k in sorted(files)]
        self.tokens = np.concatenate(arrays) if arrays else np.zeros(0, np.int32)
        self.seed = seed

    @classmethod
    def from_cu_context(cls, cu_ctx, du_id: str, seed: int = 0) -> "ShardReader":
        manifest = cu_ctx.input_manifest(du_id)
        files = {rel: cu_ctx.read_input(du_id, rel) for rel in manifest}
        return cls(files, seed=seed)

    def batches(
        self, batch: int, seq: int, start_step: int = 0
    ) -> Iterator[Dict[str, np.ndarray]]:
        n = len(self.tokens)
        need = seq + 1
        assert n >= need, f"shard too small: {n} < {need}"
        step = start_step
        while True:
            rng = np.random.default_rng([self.seed, step])
            starts = rng.integers(0, n - need, size=batch)
            window = np.stack([self.tokens[s : s + need] for s in starts])
            yield {
                "tokens": window[:, :-1].astype(np.int32),
                "labels": window[:, 1:].astype(np.int32),
            }
            step += 1


class StreamingShardReader:
    """Chunk-prefix shard reader over ``CUContext.stream_input``.

    Consumes a raw-format (``.bin``) shard DU as its chunks land in the
    sandbox — published prefixes of a streaming producer, or the staged
    prefix of a sealed chunked DU — and cuts **deterministic sequential
    windows**: step k's batch covers tokens
    ``[k·batch·(seq+1), (k+1)·batch·(seq+1))`` of the canonical stream
    (wrapping modulo the final length once the stream is exhausted).
    Positions depend only on the step index, never on how much of the
    stream had arrived when the batch was cut, so a replayed chunk reads
    identical data (resume ≡ continuation) and sync/async execution modes
    see identical batches."""

    def __init__(self, cu_ctx, du_id: str, window: int = 4):
        self._chunks = cu_ctx.stream_input(du_id, window=window)
        self._buf = bytearray()
        self._exhausted = False
        #: chunks consumed so far (observability: prefetch-overlap tests)
        self.chunks_consumed = 0

    def _tokens(self) -> np.ndarray:
        return decode_raw_tokens(bytes(self._buf))

    def _fill(self, need_tokens: int) -> None:
        while not self._exhausted and len(self._buf) // 4 < need_tokens:
            try:
                _, data = next(self._chunks)
            except StopIteration:
                self._exhausted = True
                return
            self._buf.extend(data)
            self.chunks_consumed += 1

    def batches(
        self, batch: int, seq: int, start_step: int = 0
    ) -> Iterator[Dict[str, np.ndarray]]:
        need = seq + 1
        per_step = batch * need
        step = start_step
        while True:
            lo = step * per_step
            self._fill(lo + per_step)
            toks = self._tokens()
            n = len(toks)
            assert n >= need, f"shard too small: {n} < {need}"
            if n >= lo + per_step:
                window = toks[lo : lo + per_step]
            else:
                # stream exhausted: n is the final length, wrap modulo it —
                # the same positions an unwrapped infinite stream would map
                # to, computable identically on any replay
                window = toks[np.arange(lo, lo + per_step) % n]
            window = window.reshape(batch, need)
            yield {
                "tokens": window[:, :-1].astype(np.int32),
                "labels": window[:, 1:].astype(np.int32),
            }
            step += 1


class Prefetcher:
    """Background-thread prefetch with bounded queue (overlaps host-side
    batch prep with device compute).

    ``close()`` is leak-proof: the producer's puts are stop-aware (bounded
    timeout, re-checking the stop flag), and close drains the queue until
    the thread exits — a producer parked in ``put`` on a full queue can
    never outlive an abandoned iterator."""

    _DONE = object()

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, depth))
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._produce, args=(it,), daemon=True)
        self._thread.start()

    def _put(self, item) -> bool:
        """Stop-aware bounded put; False once the consumer closed us."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _produce(self, it: Iterator) -> None:
        try:
            for item in it:
                if not self._put(item):
                    return
        except BaseException as e:  # noqa: BLE001
            self._err = e
        finally:
            self._put(self._DONE)

    def __iter__(self):
        return self

    def __next__(self):
        while True:
            if self._stop.is_set():
                raise StopIteration
            try:
                item = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            if item is self._DONE:
                if self._err is not None:
                    raise self._err
                raise StopIteration
            return item

    def close(self):
        """Stop the producer and reclaim the thread (drain-and-join): free
        a slot so a blocked put observes the stop flag, repeat until the
        thread is gone."""
        self._stop.set()
        while self._thread.is_alive():
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=0.05)
