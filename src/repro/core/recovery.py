"""Self-healing replicated data layer: pilot-death recovery pipeline.

The paper's §4.2 fault-tolerance story ("all framework state lives in the
coordination store, so components can crash, reconnect and resume") covers
*compute* recovery — orphaned CUs are re-queued.  This module adds the
*data* half, the capability "A Comprehensive Perspective on Pilot-Job
Systems" (arXiv:1508.04180) calls the distinguishing production feature of
pilot systems — automated recovery — built on PR 2's chunk-granular
replicas and PR 3's producer/lineage metadata:

  * :class:`FaultManager` — the event-driven pipeline a pilot failure
    flows through: purge the dead sandbox's entries from every DU's
    ``locations``/``du:<id>:chunks`` holdings (bumping location versions
    so transfer resolve/estimate caches, in-flight claim dedup and
    placement locality all stop seeing the dead replica), then triage
    every affected DU — heal, re-ingest, or recompute — prioritizing DUs
    that lost their last full replica, then re-queue the pilot's orphaned
    CUs (consumers of still-recovering DUs re-park on the dependency gate
    instead of exploding in staging);
  * :class:`ReplicaManager` — enforces each DU's declared
    ``replication_factor``: it subscribes to the store's keyspace
    notifications and chunk-stripes a new replica (via the transfer
    service's multi-source ``heal_replica``) whenever a sealed DU's live
    full-replica count drops below its factor — failure-domain-aware
    (targets in sites that do not already hold a replica are preferred,
    so one site's churn cannot take out every copy);
  * **lineage recomputation** — when every replica of a sealed DU is gone
    and its local staging buffer was dropped, the DU is re-opened
    (``Recovering`` state, surfaced through DU futures), its recorded
    ``producer`` CU is reset and re-queued — transitively up the DAG when
    the producer's own inputs were lost too — and the re-run's re-seal
    releases the parked consumers.  Producers are assumed deterministic
    (the re-run rewrites the same logical content).
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, List, Set

from .agent import GLOBAL_QUEUE
from .compute_unit import CUState, ComputeUnit
from .coordination import StoreEvent, StoreEventPump
from .data_unit import DataUnit, DataUnitDescription, DUState
from .faults import fail_cu_terminal, requeue_orphans
from .pilot import PilotData, PilotState, RuntimeContext
from .replication import select_heal_targets

#: lineage re-runs per producer CU before the DU is declared lost (guards
#: against a producer whose re-runs keep landing on dying pilots)
MAX_RECOVERIES = 3


def recovering_dus(store) -> List[str]:
    """DU ids currently in ``Recovering`` (rebuilding after total replica
    loss) — the one store scan both FaultManager and Session surface."""
    return [
        key.split(":", 1)[1]
        for key in store.hkeys("du:")
        if key.count(":") == 1
        and store.hget(key, "state") == DUState.RECOVERING
    ]


class ReplicaManager:
    """Keeps every sealed DU at its declared ``replication_factor``.

    Subscribes to ``du:`` keyspace notifications (location/holding
    changes, delivered in store ``seq`` order via the out-of-lock
    dispatcher) and, on the pump thread, re-replicates any sealed DU whose
    live full-replica count fell below its factor — chunk-striped from all
    remaining holders (partial replicas included) via
    ``TransferService.heal_replica``.  Target selection is failure-domain
    aware: sites not already holding a replica win (see
    :func:`repro.core.replication.select_heal_targets`).
    """

    def __init__(self, ctx: RuntimeContext, cds=None):
        self.ctx = ctx
        self.cds = cds
        #: (du_id, target_pd_id) pairs healed, in order
        self.heals: List[tuple] = []
        #: serializes concurrent heal decisions (pump thread vs the
        #: FaultManager's explicit priority pass) so a race cannot create
        #: replicas beyond the factor; guards _healing only — the
        #: transfers themselves run outside it (PD-L002)
        self._ensure_lock = threading.Lock()
        #: du_id -> Event set when that DU's in-flight heal pass finishes
        self._healing: Dict[str, threading.Event] = {}
        self._pump = StoreEventPump(
            ctx.store,
            handler=self._process,
            prefix="du:",
            accept=lambda ev: ev.op == "hset"
            and (
                ev.field in ("locations", "sealed")
                or ev.key.endswith(":chunks")
            ),
            name="replica-manager",
        )

    def _process(self, ev: StoreEvent) -> None:
        du_id = ev.key.split(":", 2)[1]
        store = self.ctx.store
        # Only settled DUs are event-healed: a DU mid-first-ingest or
        # mid-striped-dispersal is still being written by its own transfer
        # plan, and healing it here would race that plan.  (Recovery paths
        # that legitimately operate on unsettled DUs call ensure/recover_du
        # directly.)
        if not store.hget(f"du:{du_id}", "sealed", False):
            return
        if store.hget(f"du:{du_id}", "state") != DUState.READY:
            return
        du = self.ctx.objects.get(du_id)
        if isinstance(du, DataUnit):
            self.ensure(du)

    # ------------------------------------------------------------- healing
    def _candidate_pds(self, du: DataUnit, holders: Set[str]) -> List[PilotData]:
        """Live PDs that could host a new replica: explicitly-created PDs
        plus active pilots' sandboxes, minus current holders and the dead."""
        store = self.ctx.store
        out: List[PilotData] = []
        pds: List[PilotData] = []
        if self.cds is not None:
            pds.extend(self.cds.pilot_data())
            pds.extend(
                p.sandbox
                for p in self.cds.pilots()
                if p.state == PilotState.ACTIVE
            )
        else:
            pds.extend(
                o for o in self.ctx.objects.values()
                if isinstance(o, PilotData)
            )
        seen: Set[str] = set()
        for pd in pds:
            if pd.id in holders or pd.id in seen:
                continue
            seen.add(pd.id)
            if store.hget(f"pd:{pd.id}", "state") in (
                PilotState.FAILED, PilotState.CANCELED,
            ):
                continue
            if pd.free_bytes < du.size:
                continue
            out.append(pd)
        return out

    def ensure(self, du: DataUnit) -> int:
        """Bring ``du`` back to its replication factor; returns the number
        of replicas created.  A DU whose chunks are no longer fully covered
        by holders *or* the local buffer cannot be healed here (lineage
        recomputation owns that case)."""
        # Per-DU gate instead of one big critical section: heal transfers
        # block for seconds, and holding _ensure_lock across them would
        # stall every other DU's heal decision (and trips PD-L002).  The
        # race _ensure_lock exists to prevent — two passes both seeing the
        # DU under-replicated and both healing it — is closed by parking
        # the second pass on the first one's completion event, after which
        # it re-reads the (now updated) locations.
        while True:
            with self._ensure_lock:
                gate = self._healing.get(du.id)
                if gate is None:
                    gate = threading.Event()
                    self._healing[du.id] = gate
                    break
            gate.wait(timeout=60.0)
        try:
            locs = set(du.locations)
            need = du.replication_factor - len(locs)
            if need <= 0:
                return 0
            if not du.has_full_coverage() and not du.iter_files():
                return 0  # data loss: FaultManager recovers by lineage
            targets = select_heal_targets(
                self.ctx, du, self._candidate_pds(du, locs), need,
                held=[
                    self.ctx.objects[pd_id].affinity
                    for pd_id in locs
                    if pd_id in self.ctx.objects
                ],
            )
            made = 0
            for target in targets:
                try:
                    self.ctx.transfer_service.heal_replica(du, target)
                except Exception:
                    continue  # quota/transfer error: try the next candidate
                self.heals.append((du.id, target.id))
                made += 1
            return made
        finally:
            with self._ensure_lock:
                self._healing.pop(du.id, None)
            gate.set()

    def stop(self) -> None:
        self._pump.stop()


class FaultManager:
    """Turns pilot death into an event-driven recovery pipeline.

    Wire :meth:`on_pilot_suspect`/:meth:`on_pilot_failed` into a
    :class:`~repro.core.faults.HeartbeatMonitor`; failures are processed on
    a dedicated worker thread (detection must not stall behind recovery
    transfers):

      1. mark the dead pilot's sandbox PD failed and **purge** it from
         every affected DU's ``locations`` and chunk holdings (location
         versions bump, so the transfer service's resolve/estimate caches
         and the placement engine's locality scores all invalidate; its
         in-flight staging claims are released so racing stagers re-plan);
      2. triage affected DUs worst-first (fewest remaining full replicas):
         re-enforce the replication factor via :class:`ReplicaManager`,
         re-ingest from an intact local buffer, or — when every chunk copy
         is gone — **recompute by lineage** (reset + re-queue the recorded
         producer CU, transitively);
      3. re-queue the pilot's orphaned CUs; consumers whose inputs are
         still ``Recovering`` re-park on the dependency gate.
    """

    def __init__(self, ctx: RuntimeContext, cds=None):
        self.ctx = ctx
        self.cds = cds
        self.replicas = ReplicaManager(ctx, cds=cds)
        #: per-failure audit records {"pilot", "pd", "actions", "requeued"}
        self.log: List[Dict] = []
        #: producer CU ids re-queued for lineage recomputation, in order
        self.recomputed: List[str] = []
        self.suspected: List[str] = []
        self._lock = threading.Lock()
        self._resubmitting: Set[str] = set()
        self._queue: "queue.Queue[Optional[str]]" = queue.Queue()
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._worker, name="fault-manager", daemon=True
        )
        self._thread.start()

    # ------------------------------------------------------- monitor hooks
    def on_pilot_suspect(self, pilot_id: str) -> None:
        self.suspected.append(pilot_id)

    def on_pilot_failed(self, pilot_id: str) -> None:
        self._queue.put(pilot_id)

    def _worker(self) -> None:
        while not self._stop.is_set():
            try:
                pilot_id = self._queue.get(timeout=0.1)
            except queue.Empty:
                continue
            if pilot_id is None:
                break
            try:
                self._handle_failure(pilot_id)
            except Exception:
                pass  # a broken recovery must not kill the pipeline

    # ---------------------------------------------------- failure pipeline
    def _handle_failure(self, pilot_id: str) -> None:
        store = self.ctx.store
        pd_id = store.hget(f"pilot:{pilot_id}", "sandbox_pd")
        affected: List[str] = []
        if pd_id:
            store.hset(f"pd:{pd_id}", "state", PilotState.FAILED)
            affected = list(store.hget(f"pd:{pd_id}", "dus", []))
            if self.ctx.transfer_service is not None:
                self.ctx.transfer_service.purge_pd(pd_id)
            for du_id in affected:
                self._purge_holding(du_id, pd_id)
        # worst-first: a DU that just lost its LAST full replica recovers
        # before one that merely dropped below factor
        order = sorted(
            affected,
            key=lambda d: (len(store.hget(f"du:{d}", "locations", [])), d),
        )
        actions = {du_id: self.recover_du(du_id) for du_id in order}
        requeued = requeue_orphans(
            self.ctx, pilot_id,
            deps=self.cds.deps if self.cds is not None else None,
        )
        self.log.append(
            {
                "pilot": pilot_id,
                "pd": pd_id,
                "actions": actions,
                "requeued": requeued,
            }
        )

    def _purge_holding(self, du_id: str, pd_id: str) -> None:
        """Remove one PD from a DU's replica bookkeeping (live handle when
        available — that bumps the location version the transfer caches and
        placement key on — store-side otherwise)."""
        du = self.ctx.objects.get(du_id)
        if isinstance(du, DataUnit):
            du._remove_location(pd_id)
            return
        store = self.ctx.store
        locs = [
            loc for loc in store.hget(f"du:{du_id}", "locations", [])
            if loc != pd_id
        ]
        store.hset(f"du:{du_id}", "locations", locs)
        store.hdel(f"du:{du_id}:chunks", pd_id)

    # ------------------------------------------------------- DU recovery
    def recover_du(self, du_id: str, depth: int = 0) -> str:
        """Triage one DU after replica loss.  Returns the action taken:
        ``"healed"`` (re-replicated from surviving holders/buffer),
        ``"lineage"`` (producer re-queued for recomputation), ``"lost"``
        (unrecoverable → FAILED, cascading to consumers), or ``"ok"``/
        ``"skipped"`` when nothing was needed/possible."""
        store = self.ctx.store
        rec = store.hgetall(f"du:{du_id}")
        if not rec or rec.get("state") in (DUState.FAILED, DUState.DELETED):
            return "skipped"
        du = self.ctx.objects.get(du_id)
        if not isinstance(du, DataUnit):
            # Store-only DU (a reconnected manager, §4.2): re-attach a
            # live handle — it adopts the persisted manifest/chunks/seal —
            # so healing and lineage recovery work without the original
            # process.  Registered so later transfers resolve it too.
            du = DataUnit(DataUnitDescription(), store, du_id=du_id)
            self.ctx.register(du)
        if du.has_full_coverage() or du.iter_files():
            # content survives (replicas/partials/buffer): enforce factor
            if rec.get("sealed"):
                self.replicas.ensure(du)
                if len(du.locations) < du.replication_factor:
                    # no candidate could host the replica (quota, no live
                    # PDs): surfaced in the audit log; any future holding
                    # event re-triggers the ReplicaManager
                    return "below-factor"
                return "healed"
            return "ok"
        if not rec.get("sealed") and not rec.get("producer"):
            return "ok"  # unsealed source DU: local buffer is authoritative
        producer = rec.get("producer")
        if producer:
            if store.hget(f"cu:{producer}", "state") != CUState.DONE:
                # the producer run is still queued/in flight (or being
                # re-queued by orphan recovery): it will write the outputs
                # itself — resetting it here would race that run
                return "pending-producer"
            recoveries = int(store.hget(f"cu:{producer}", "recoveries", 0))
            if recoveries >= MAX_RECOVERIES:
                self._fail_du(
                    du_id,
                    f"all replicas lost; producer cu://{producer} already "
                    f"recomputed {recoveries}x",
                )
                return "lost"
            du.begin_recovery()
            if self._resubmit_producer(producer, depth=depth):
                self.recomputed.append(producer)
                return "lineage"
            return "lost"
        self._fail_du(du_id, "all replicas lost and no producer recorded")
        return "lost"

    def _fail_du(self, du_id: str, reason: str) -> None:
        store = self.ctx.store
        store.hset(f"du:{du_id}", "error", reason)
        store.hset(f"du:{du_id}", "state", DUState.FAILED)

    # -------------------------------------------------- lineage recompute
    def _resubmit_producer(self, cu_id: str, depth: int = 0) -> bool:
        """Reset a DONE producer CU and re-queue it so its outputs are
        rewritten.  Recurses up the DAG when the producer's own inputs were
        lost too.  Returns False when the re-run is impossible (the CU and
        its outputs are then failed terminally)."""
        if depth > 8:
            fail_cu_terminal(
                self.ctx, cu_id, "lineage recovery recursion limit reached",
                respect_winner=False,
            )
            return False
        with self._lock:
            if cu_id in self._resubmitting:
                return True  # already being handled in this walk
            self._resubmitting.add(cu_id)
        try:
            store = self.ctx.store
            cu = self.ctx.objects.get(cu_id)
            if not isinstance(cu, ComputeUnit):
                # reconnected manager: re-attach the producer from its
                # persisted description, like recover_du does for DUs —
                # the lineage lives in the store, not in this process
                desc_json = store.hget(f"cu:{cu_id}", "desc")
                if not desc_json:
                    fail_cu_terminal(
                        self.ctx, cu_id,
                        "producer description lost; cannot recompute lineage",
                        respect_winner=False,
                    )
                    return False
                from .compute_unit import ComputeUnitDescription

                cu = ComputeUnit(
                    ComputeUnitDescription(**desc_json), store, cu_id=cu_id
                )
                self.ctx.register(cu)
            # un-seal every output for rewrite (the re-run regenerates all
            # of them; deterministic-producer assumption).  Siblings whose
            # replicas survive only need the seal lifted — wiping their
            # holdings would make healthy data unreadable mid-recovery.
            for out_id in cu.description.output_data:
                odu = self.ctx.objects.get(out_id)
                if not isinstance(odu, DataUnit):
                    continue
                if odu.has_full_coverage():
                    store.hset(f"du:{out_id}", "sealed", False)
                else:
                    odu.begin_recovery()
            # ensure inputs, walking the DAG upward for lost ones
            unmet: Set[str] = set()
            for in_id in cu.description.input_data:
                in_du = self.ctx.objects.get(in_id)
                if isinstance(in_du, DataUnit) and not (
                    in_du.has_full_coverage() or in_du.iter_files()
                ):
                    self.recover_du(in_id, depth=depth + 1)
                state = store.hget(f"du:{in_id}", "state")
                if state == DUState.FAILED:
                    fail_cu_terminal(
                        self.ctx, cu_id,
                        f"lineage input du://{in_id} is unrecoverable",
                        respect_winner=False,
                    )
                    return False
                if state == DUState.RECOVERING:
                    unmet.add(in_id)
            # reset execution bookkeeping for the re-run (exactly-once CAS
            # starts fresh; recovery re-runs don't burn the retry budget)
            store.hset(f"cu:{cu_id}", "winner", None)
            store.hset(f"cu:{cu_id}", "pilot", None)
            store.hset(
                f"cu:{cu_id}", "recoveries",
                int(store.hget(f"cu:{cu_id}", "recoveries", 0)) + 1,
            )
            if unmet and self.cds is not None:
                store.hset(f"cu:{cu_id}", "state", CUState.WAITING)
                self.cds.deps.add(cu, unmet)
            else:
                store.hset(f"cu:{cu_id}", "state", CUState.PENDING)
                # straight to the global queue: the original placement may
                # have pinned a pilot that is exactly the one that died
                store.push(GLOBAL_QUEUE, {"cu": cu_id, "dup": False})
            return True
        finally:
            with self._lock:
                self._resubmitting.discard(cu_id)

    # ------------------------------------------------------------- control
    def recovering_dus(self) -> List[str]:
        """DU ids currently in ``Recovering`` (rebuilding via lineage)."""
        return recovering_dus(self.ctx.store)

    def stop(self) -> None:
        self._stop.set()
        self._queue.put(None)
        self._thread.join(timeout=2.0)
        self.replicas.stop()
