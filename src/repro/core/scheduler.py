"""Event-driven async scheduler with pipelined data staging.

The sync :class:`~repro.core.services.ComputeDataService` loop polls its
incoming queue; this module replaces the polling with a **reactor**: the
coordination store publishes keyspace notifications for every CU/DU/pilot
state transition (P*'s pilot lifecycle as an event-driven state machine,
arXiv:1207.6644), and a single scheduler thread consumes them in sequence
order.  Placement itself is the *same* code path as sync mode
(``ComputeDataService.place`` → shared :class:`PlacementEngine` + the
selected :class:`PlacementStrategy` plugin), so the two modes make
identical decisions; what the async mode adds is **transfer pipelining**:

  * the moment a CU is bound to a pilot, its input DUs' *missing chunks*
    (and only those — a partially-cached sandbox pays just the remainder)
    are bulk-staged into the pilot's sandbox on a staging thread-pool —
    staging of CU B overlaps execution of already-ready CU A instead of
    serializing in the agent's slot;
  * multi-DU chunk groups from one source Pilot-Data coalesce into a
    single costed bulk transfer (one setup latency + one registration),
    while groups from distinct sources stripe in parallel;
  * the transfer service's chunk-granular in-flight dedup makes the
    agent's own ``stage_in`` wait on (not repeat) a prefetch already
    moving those chunks;
  * dataflow DAGs pipeline across edges: a CU parked ``Waiting`` on
    unsealed input DUs is released by the CDS DependencyTracker the moment
    its last producer seals — the release lands back on ``cds:incoming``,
    the reactor places it, and the pre-push prefetch stages stage *i+1*'s
    inputs while stage *i*'s remaining CUs are still executing.

Determinism: events carry the store's monotonic sequence number and the
scheduler processes them strictly in arrival order.  With ``autostart=
False`` and ``stage_workers=0`` the reactor runs only when :meth:`step` is
called and stages inline — two identically-scripted runs then produce
identical event logs and decisions (see tests/test_scheduler_async.py).
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Deque, Dict, List, Optional, Tuple

from .compute_unit import CUState
from .coordination import StoreEvent
from .services import ComputeDataService


@dataclasses.dataclass(frozen=True)
class SchedulerEvent:
    """One reactor-relevant occurrence, in store-sequence order."""

    seq: int
    #: "cu-submitted" | "cu-state" | "du-state" | "du-published" |
    #: "pilot-state"
    kind: str
    subject: str  # cu/du/pilot id
    value: Any  # new state (or queue item for submissions)


class AsyncScheduler:
    """Reactor over coordination-store events; owns async-mode placement.

    Subscribes to the store, filters the firehose down to scheduler-
    relevant transitions, and reacts:

      * CU submission  → place (shared CDS path) + prefetch pipeline;
      * CU terminal    → re-check delayed CUs (a slot freed up);
      * pilot Active   → re-check delayed CUs (capacity appeared).
    """

    def __init__(
        self,
        cds: ComputeDataService,
        stage_workers: int = 4,
        autostart: bool = True,
        tick_s: float = 0.02,
        event_log_size: int = 10_000,
    ):
        self.cds = cds
        self.ctx = cds.ctx
        self.tick_s = tick_s
        self._queue: "queue.Queue[SchedulerEvent]" = queue.Queue()
        self._stop = threading.Event()
        #: bounded trace of handled events (oldest evicted) — enough for
        #: determinism tests and debugging without growing with the workload
        self.event_log: Deque[SchedulerEvent] = collections.deque(
            maxlen=event_log_size
        )
        self._log_lock = threading.Lock()
        self._pool: Optional[ThreadPoolExecutor] = (
            ThreadPoolExecutor(
                max_workers=stage_workers, thread_name_prefix="stage"
            )
            if stage_workers > 0
            else None
        )
        #: du_id -> [(cu, pilot)] consumers whose streaming input is still
        #: being produced: every publish event re-claims + prefetches the
        #: newly available chunks toward the consumer's sandbox
        self._stream_watch: Dict[str, List[Tuple[Any, Any]]] = {}
        self._watch_lock = threading.Lock()
        self._token = self.ctx.store.subscribe(self._on_store_event)
        # Claim staging BEFORE the CU becomes visible on a pilot queue:
        # agents then dedup onto the prefetch instead of re-staging.
        cds.pre_push_hook = self._begin_prefetch
        # A CU parked Waiting gets no placement (and hence no pre-push
        # prefetch) until its producers seal — but its OTHER inputs may
        # already be ready.  Stage those toward the predicted winner now,
        # so a serial DAG (train chunk i+1 waiting on ckpt_i) still
        # overlaps shard stage-in with chunk i's compute.
        cds.waiting_prefetch_hook = self._prefetch_waiting
        self._thread: Optional[threading.Thread] = None
        if autostart:
            self._thread = threading.Thread(
                target=self._run, name="async-scheduler", daemon=True
            )
            self._thread.start()

    # ---------------------------------------------------------- event intake
    def _on_store_event(self, ev: StoreEvent) -> None:
        """Store callback (dispatcher thread): filter + enqueue, nothing
        else — events arrive in seq order, off the mutating thread."""
        if ev.op == "push" and ev.key == "cds:incoming":
            self._queue.put(
                SchedulerEvent(ev.seq, "cu-submitted", str(ev.value), ev.value)
            )
        elif ev.op == "hset" and ev.field == "state":
            for prefix, kind in (
                ("cu:", "cu-state"),
                ("du:", "du-state"),
                ("pilot:", "pilot-state"),
            ):
                if ev.key.startswith(prefix):
                    self._queue.put(
                        SchedulerEvent(
                            ev.seq, kind, ev.key.split(":", 1)[1], ev.value
                        )
                    )
                    break
        elif (
            ev.op == "hset"
            and ev.field == "published"
            and ev.key.startswith("du:")
            and ev.key.count(":") == 1
        ):
            # a producer published a chunk prefix: pipeline the new chunks
            # toward every watching consumer's sandbox
            self._queue.put(
                SchedulerEvent(
                    ev.seq, "du-published", ev.key.split(":", 1)[1], ev.value
                )
            )

    # -------------------------------------------------------------- reactor
    def _run(self) -> None:
        while not self._stop.is_set():
            self.step(timeout=self.tick_s)

    def step(self, timeout: float = 0.0) -> bool:
        """Process one pending event (or time out re-checking delayed CUs).
        Returns True if an event was handled — the manual-stepping hook the
        determinism tests drive.  With ``timeout=0`` an empty queue first
        drains the store's out-of-lock dispatcher (``flush_events``), so
        manual stepping observes every mutation already issued."""
        try:
            if timeout:
                ev = self._queue.get(timeout=timeout)
            else:
                if self._queue.empty():
                    self.ctx.store.flush_events()
                ev = self._queue.get_nowait()
        except queue.Empty:
            self.cds.recheck_delayed()
            return False
        with self._log_lock:
            self.event_log.append(ev)
        try:
            self._react(ev)
        except Exception:
            pass  # scheduler must survive misbehaving CUs/agents
        return True

    def drain(self, max_events: int = 10_000) -> int:
        """Synchronously process everything queued (manual-stepping mode)."""
        n = 0
        while n < max_events and self.step():
            n += 1
        return n

    def _react(self, ev: SchedulerEvent) -> None:
        if ev.kind == "cu-submitted":
            cu_id = self.ctx.store.pop("cds:incoming", timeout=0.0)
            if cu_id is None:
                return  # sync loop (or a prior event) already took it
            cu = self.ctx.lookup(cu_id)
            if cu.state != CUState.PENDING:
                return
            self.cds.place(cu)  # prefetch rides the pre-push hook
        elif ev.kind == "du-published":
            self._on_published(ev.subject)
        elif ev.kind == "cu-state" and ev.value in CUState.TERMINAL:
            self.cds.recheck_delayed()
            # a slot freed up tenant-side too: re-admit parked CUs on the
            # reactor thread (the admission pump also drains — poke is
            # idempotent — but reacting here keeps async-mode admission
            # latency event-driven instead of cross-thread)
            self.cds.admission.poke()
        elif ev.kind == "pilot-state" and ev.value in (
            "Active", "Suspect", "Failed"
        ):
            # Active: capacity appeared.  Suspect/Failed: capacity VANISHED
            # — delayed CUs parked for that pilot must re-place elsewhere
            # (suspect pilots are non-placeable while their in-flight work
            # drains), and the fault pipeline's re-queues need a pass.
            self.cds.recheck_delayed()

    def _begin_prefetch(self, cu, pilot) -> None:
        """Pre-push hook (pipeline entry): claim the missing input chunks
        NOW — before the CU is visible to agents — then move the bytes on
        the staging pool so they overlap whatever the pilot is executing.
        Chunks the sandbox already holds are never claimed or re-moved.

        Streaming inputs still mid-production are additionally *watched*:
        each subsequent publish event re-claims the newly available chunks
        and stages them too (chunk-granular prefetch re-planning)."""
        if not cu.description.input_data:
            return
        ts = self.ctx.transfer_service
        dus = ts.lookup_dus(cu)
        with self._watch_lock:
            for du in dus:
                if du.streaming and not du.sealed:
                    self._stream_watch.setdefault(du.id, []).append(
                        (cu, pilot)
                    )
        claimed = ts.claim_bulk(dus, pilot.sandbox)
        if not claimed:
            return
        if self._pool is not None:
            try:
                self._pool.submit(ts.prefetch_inputs, cu, pilot, claimed)
                return
            except RuntimeError:
                pass  # pool shut down mid-flight: fall back to inline
        ts.prefetch_inputs(cu, pilot, claimed=claimed)

    def _prefetch_waiting(self, cu, unmet) -> None:
        """Speculative pipeline for ``Waiting`` CUs: claim + stage the
        inputs that are already consumable (everything not in ``unmet``)
        toward the pilot the placement strategy currently favors.  Pure
        data movement — no decision is logged, no queue is touched; if the
        release later lands the CU elsewhere, the sandbox replica still
        helps via cheapest-replica resolution (same rationale as the
        delayed-scheduling prefetch in ``ComputeDataService.place``).

        Runs only when a staging pool exists: with ``stage_workers=0``
        (the determinism-test configuration) submission stays free of
        side effects beyond the dependency registration."""
        if self._pool is None:
            return
        ready_ids = [d for d in cu.description.input_data if d not in unmet]
        if not ready_ids:
            return
        pilot = self.cds.predict_pilot(cu)
        if pilot is None:
            return
        dus = []
        for du_id in ready_ids:
            try:
                dus.append(self.ctx.lookup(du_id))
            except KeyError:
                continue
        ts = self.ctx.transfer_service
        claimed = ts.claim_bulk(dus, pilot.sandbox)
        if not claimed:
            return
        try:
            self._pool.submit(ts.prefetch_inputs, cu, pilot, claimed)
        except RuntimeError:
            ts.release_claims(claimed)  # pool shut down mid-flight

    def _on_published(self, du_id: str) -> None:
        """A streaming producer advanced its published prefix: stage the
        new chunks toward every live watching consumer's sandbox.  The DU
        sealing (its final publish event carries the full chunk count)
        retires the watch."""
        try:
            du = self.ctx.lookup(du_id)
        except KeyError:
            with self._watch_lock:
                self._stream_watch.pop(du_id, None)
            return
        with self._watch_lock:
            pairs = self._stream_watch.get(du_id, [])
            keep = [
                (cu, p) for cu, p in pairs
                if cu.state not in CUState.TERMINAL
            ]
            if du.sealed or not keep:
                self._stream_watch.pop(du_id, None)
            else:
                self._stream_watch[du_id] = keep
        ts = self.ctx.transfer_service
        for cu, pilot in keep:
            claimed = ts.claim_bulk([du], pilot.sandbox)
            if not claimed:
                continue
            if self._pool is not None:
                try:
                    self._pool.submit(ts.prefetch_inputs, cu, pilot, claimed)
                    continue
                except RuntimeError:
                    pass
            ts.prefetch_inputs(cu, pilot, claimed=claimed)

    # -------------------------------------------------------------- control
    def decisions(self) -> List[dict]:
        return self.cds.decisions()

    def stop(self) -> None:
        self._stop.set()
        self.ctx.store.unsubscribe(self._token)
        if self.cds.pre_push_hook is self._begin_prefetch:
            self.cds.pre_push_hook = None
        if self.cds.waiting_prefetch_hook is self._prefetch_waiting:
            self.cds.waiting_prefetch_hook = None
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        if self._pool is not None:
            self._pool.shutdown(wait=False)
