"""Typed futures over Compute-Units and Data-Units (Pilot-API v2).

The paper's API is asynchronous ("the Pilot-API is asynchronous, i.e.
submission calls return immediately", §4.2) but the original handles force
callers back into polling and id-string plumbing.  This module gives the
asynchrony a shape: :class:`CUFuture` / :class:`DUFuture` are typed,
chainable handles with ``result()/done()/add_done_callback()`` semantics
(mirroring :mod:`concurrent.futures`) plus a :func:`gather` combinator, so
whole DAGs are wired by object instead of by raw id string.

Completion is event-driven end to end: blocking waits ride
``CoordinationStore.wait_field`` (keyspace notifications, no polling) and
callbacks are fired by a per-session :class:`FutureDispatcher` thread that
consumes the same store event stream — callbacks never run on the store's
dispatcher thread (or any store lock), so they may block or re-enter the
API freely.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Iterable, List, Optional, Sequence, Tuple

from .compute_unit import ComputeUnit, CUState
from .coordination import CoordinationStore, StoreEvent, StoreEventPump
from .data_unit import DataUnit, DUState


class FutureError(RuntimeError):
    """Base class for future resolution failures."""


class FutureTimeoutError(FutureError, TimeoutError):
    """``result()`` deadline elapsed before the subject settled."""


class ComputeFailedError(FutureError):
    """The underlying CU reached FAILED/CANCELED."""

    def __init__(self, cu_id: str, message: str):
        super().__init__(message)
        self.cu_id = cu_id


class DataUnitFailedError(FutureError):
    """The underlying DU reached FAILED (e.g. its producer CU failed)."""

    def __init__(self, du_id: str, message: str):
        super().__init__(message)
        self.du_id = du_id


class FutureDispatcher:
    """Runs ``add_done_callback`` callbacks off the store's event stream.

    A :class:`StoreEventPump` drains the subscription onto a dedicated
    thread, so user callbacks run off the store's dispatcher (which must
    stay fast for every other subscriber) and may block or re-enter the
    API freely.
    """

    def __init__(self, store: CoordinationStore):
        self._store = store
        self._lock = threading.Lock()
        #: "cu:<id>"/"du:<id>" -> [(future, callback)] not yet fired
        self._pending: dict = {}
        #: "du:<id>" -> [(future, callback)] fired on EVERY publish event
        #: (streaming chunk-prefix progress), dropped once the future is done
        self._progress: dict = {}
        self._pump = StoreEventPump(
            store,
            handler=self._handle,
            accept=lambda ev: (
                ev.op == "hset"
                and ev.field in ("state", "sealed", "published")
                and (ev.key.startswith("cu:") or ev.key.startswith("du:"))
            ),
            name="future-dispatcher",
        )

    def _handle(self, ev: StoreEvent) -> None:
        if ev.field == "published":
            self._fire_progress(ev.key, ev.value)
        self._fire(ev.key)

    def _fire(self, key: str) -> None:
        with self._lock:
            entries = self._pending.get(key)
            if not entries:
                return
            ready = [e for e in entries if e[0].done()]
            if not ready:
                return
            remaining = [e for e in entries if not e[0].done()]
            if remaining:
                self._pending[key] = remaining
            else:
                self._pending.pop(key, None)
        for future, callback in ready:
            try:
                callback(future)
            except Exception:
                pass  # a broken callback must not kill the dispatcher

    def _fire_progress(self, key: str, value: Any) -> None:
        with self._lock:
            entries = list(self._progress.get(key, ()))
            if entries:
                live = [e for e in entries if not e[0].done()]
                if live:
                    self._progress[key] = live
                else:
                    self._progress.pop(key, None)
        for future, callback in entries:
            try:
                callback(future, int(value or 0))
            except Exception:
                pass

    def register(self, key: str, future: Any, callback: Callable) -> None:
        if future.done():
            callback(future)
            return
        with self._lock:
            self._pending.setdefault(key, []).append((future, callback))
        # Completion may have landed between the check and the registration;
        # a synthetic event closes the race on the dispatcher thread.
        self._pump.inject(
            StoreEvent(seq=-1, op="hset", key=key, field="state", value=None)
        )

    def register_progress(
        self, key: str, future: Any, callback: Callable
    ) -> None:
        """Fire ``callback(future, published)`` on every subsequent chunk-
        prefix publish event for ``key`` until the future settles."""
        with self._lock:
            self._progress.setdefault(key, []).append((future, callback))

    def stop(self) -> None:
        self._pump.stop()


class DUFuture:
    """Typed handle on a Data-Unit that may not be materialized yet.

    Resolves when the DU is sealed/first-replicated (READY) — or raises
    :class:`DataUnitFailedError` when its producer CU failed.  Read-only
    properties proxy the underlying :class:`DataUnit` so a future can be
    used wherever a DU handle is inspected.
    """

    _SETTLED = (DUState.READY, DUState.FAILED, DUState.DELETED)

    def __init__(
        self,
        du: DataUnit,
        store: CoordinationStore,
        dispatcher: Optional[FutureDispatcher] = None,
    ):
        self.du = du
        self._store = store
        self._dispatcher = dispatcher

    # ------------------------------------------------------------- proxies
    @property
    def id(self) -> str:
        return self.du.id

    @property
    def url(self) -> str:
        return self.du.url

    @property
    def state(self) -> str:
        return self.du.state

    @property
    def sealed(self) -> bool:
        return self.du.sealed

    @property
    def locations(self) -> List[str]:
        return self.du.locations

    @property
    def manifest(self) -> dict:
        return self.du.manifest

    @property
    def size(self) -> int:
        return self.du.size

    @property
    def error(self) -> Optional[str]:
        return self._store.hget(f"du:{self.id}", "error")

    @property
    def recovering(self) -> bool:
        """True while the runtime rebuilds this DU after total replica
        loss (lineage recomputation / buffer re-ingest).  A recovering
        future is NOT done; ``result()`` keeps waiting and resolves when
        the re-run re-seals the DU — or raises if recovery fails."""
        return self.state == DUState.RECOVERING

    # ----------------------------------------------------------- streaming
    @property
    def streaming(self) -> bool:
        return self.du.streaming

    @property
    def published(self) -> int:
        """Published chunk-prefix length (0 for non-streaming DUs until
        they seal)."""
        return self.du.published if self.du.streaming else (
            self.du.n_chunks if self.du.sealed else 0
        )

    def available_chunks(self) -> int:
        return self.du.available_chunks()

    def wait_prefix(self, n: int, timeout: float = 30.0) -> int:
        """Block until at least ``n`` chunks of this streaming DU are
        published (or the DU settles); returns the published count.

        Raises :class:`DataUnitFailedError` if the DU fails first and
        :class:`FutureTimeoutError` on deadline."""
        self._store.wait_field(
            f"du:{self.id}",
            "published",
            lambda v: int(v or 0) >= n or self.done(),
            timeout=timeout,
            default=0,
        )
        if self.state in (DUState.FAILED, DUState.DELETED):
            raise DataUnitFailedError(
                self.id, f"{self.url} failed: {self.error or self.state}"
            )
        published = self.published
        if published < n and not self.done():
            raise FutureTimeoutError(
                f"{self.url}: prefix {n} not published within {timeout}s "
                f"(published={published})"
            )
        return published

    def add_prefix_callback(
        self, fn: Callable[["DUFuture", int], None]
    ) -> None:
        """Invoke ``fn(future, published)`` on every chunk-prefix publish
        event until the DU settles (streaming progress observation)."""
        if self._dispatcher is None:
            raise RuntimeError(
                "add_prefix_callback needs a dispatcher — create this "
                "future through a Session"
            )
        self._dispatcher.register_progress(f"du:{self.id}", self, fn)

    # ------------------------------------------------------------- futures
    def done(self) -> bool:
        state = self.state
        if state == DUState.RECOVERING:
            return False  # un-sealed for rewrite; the re-seal settles it
        return state in self._SETTLED or self.sealed

    def wait(self, timeout: float = 30.0) -> str:
        """Block until settled; returns the DU state (compat with
        ``DataUnit.wait``)."""
        return self.du.wait(timeout=timeout)

    def result(self, timeout: float = 60.0) -> DataUnit:
        """Block until the DU materializes; returns the sealed DataUnit.

        Raises :class:`DataUnitFailedError` if the DU failed (producer CU
        error propagates here) and :class:`FutureTimeoutError` on deadline.
        """
        self._store.wait_field(
            f"du:{self.id}",
            "state",
            lambda s: s in self._SETTLED,
            timeout=timeout,
            default=DUState.NEW,
        )
        state = self.state
        if state in (DUState.FAILED, DUState.DELETED):
            raise DataUnitFailedError(
                self.id, f"{self.url} failed: {self.error or state}"
            )
        if not self.done():
            raise FutureTimeoutError(
                f"{self.url} not materialized within {timeout}s "
                f"(state={state})"
            )
        return self.du

    def add_done_callback(self, fn: Callable[["DUFuture"], None]) -> None:
        if self._dispatcher is None:
            raise RuntimeError(
                "add_done_callback needs a dispatcher — create this future "
                "through a Session"
            )
        self._dispatcher.register(f"du:{self.id}", self, fn)

    def __repr__(self) -> str:  # pragma: no cover
        return f"<DUFuture {self.url} state={self.state} done={self.done()}>"


class CUFuture:
    """Typed handle on a submitted Compute-Unit.

    ``outputs`` exposes :class:`DUFuture` handles for the CU's output DUs,
    which is what lets whole DAGs be chained by object: pass
    ``cu_future.outputs[0]`` straight into the next CU's ``input_data``.
    """

    def __init__(
        self,
        cu: ComputeUnit,
        store: CoordinationStore,
        outputs: Sequence[DUFuture] = (),
        dispatcher: Optional[FutureDispatcher] = None,
    ):
        self.cu = cu
        self._store = store
        self.outputs: Tuple[DUFuture, ...] = tuple(outputs)
        self._dispatcher = dispatcher

    # ------------------------------------------------------------- proxies
    @property
    def id(self) -> str:
        return self.cu.id

    @property
    def url(self) -> str:
        return self.cu.url

    @property
    def state(self) -> str:
        return self.cu.state

    @property
    def description(self):
        return self.cu.description

    @property
    def timings(self):
        return self.cu.timings

    @property
    def pilot_id(self) -> Optional[str]:
        return self.cu.pilot_id

    @property
    def error(self) -> Optional[str]:
        return self.cu.error or self._store.hget(f"cu:{self.id}", "error")

    @property
    def output(self) -> DUFuture:
        """The sole output DU future (raises if the CU has 0 or >1)."""
        if len(self.outputs) != 1:
            raise ValueError(
                f"{self.url} has {len(self.outputs)} outputs; use .outputs"
            )
        return self.outputs[0]

    def cancel(self) -> None:
        self.cu.cancel()

    # ------------------------------------------------------------- futures
    def done(self) -> bool:
        return self.state in CUState.TERMINAL

    def wait(self, timeout: float = 60.0) -> str:
        """Block until terminal; returns the CU state (compat with
        ``ComputeUnit.wait``)."""
        return self.cu.wait(timeout=timeout)

    def result(self, timeout: float = 60.0) -> Any:
        """Block until the CU is terminal and return its executable's
        return value; raises :class:`ComputeFailedError` on FAILED/CANCELED
        and :class:`FutureTimeoutError` on deadline."""
        state = self.wait(timeout=timeout)
        if state == CUState.DONE:
            return self.cu.result
        if state in (CUState.FAILED, CUState.CANCELED):
            raise ComputeFailedError(
                self.id, f"{self.url} {state.lower()}: {self.error}"
            )
        raise FutureTimeoutError(
            f"{self.url} not terminal within {timeout}s (state={state})"
        )

    def add_done_callback(self, fn: Callable[["CUFuture"], None]) -> None:
        if self._dispatcher is None:
            raise RuntimeError(
                "add_done_callback needs a dispatcher — create this future "
                "through a Session"
            )
        self._dispatcher.register(f"cu:{self.id}", self, fn)

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"<CUFuture {self.url} exe={self.description.executable} "
            f"state={self.state} outputs={len(self.outputs)}>"
        )


def gather(
    futures: Iterable[Any], timeout: float = 120.0
) -> List[Any]:
    """Resolve a collection of futures under one shared deadline.

    Returns ``[f.result() for f in futures]``; the first failure raises
    (fail-fast, like ``asyncio.gather`` without ``return_exceptions``).
    """
    import time

    deadline = time.monotonic() + timeout
    out: List[Any] = []
    for f in futures:
        remaining = deadline - time.monotonic()
        if remaining <= 0 and not f.done():
            raise FutureTimeoutError(f"gather: deadline elapsed before {f!r}")
        out.append(f.result(timeout=max(0.001, remaining)))
    return out
