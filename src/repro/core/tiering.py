"""Tiered storage hierarchy: tier classification, quota-driven eviction,
and hot-chunk promotion into a memory-tier cache.

The paper's adaptor pattern (§4.2) gives every Pilot-Data a backend with a
distinct performance profile (Fig. 7 shows backend choice dominating
transfer time), but the runtime historically treated each PD as a flat,
infinite-durability peer: a full PD simply raised ``QuotaExceeded``.  This
module turns the backend spread into a first-class storage *hierarchy* —
the RAM/SSD/Lustre tiering of "Hadoop on HPC" (Luckow et al., 2016) and
the Spark-style in-memory tier of the 2015 pilot-abstraction paper:

  * :func:`classify_tier` maps every PD onto a tier — ``dram-cache`` /
    ``node-local`` / ``site-shared`` / ``archival`` — from an explicit
    ``tier=`` in its description or its backend's :class:`BackendProfile`;
  * :class:`TierManager` tracks per-DU access frequency/recency off the
    coordination store's existing event stream (the transfer service
    publishes one ``du:access`` record per stage-in — no polling);
  * **quota-driven eviction** replaces the hard ``QuotaExceeded``: when a
    put/stage-in would exceed a PD's ``size_quota``, a pluggable
    :class:`EvictionPolicy` (LRU / LFU / largest-first, registered like
    placement strategies) reclaims space by dropping chunk replicas that
    are *redundant* — never the last copy of a sealed DU's chunk, never a
    full replica that would take a DU below its ``replication_factor``,
    never chunks claimed by an in-flight transfer, never the pinned
    inputs of a Waiting/Running consumer (pins are wired through the
    agent and the DependencyTracker);
  * **hot-chunk promotion**: DUs re-read from the same site cross an
    access threshold and are asynchronously copied into a mem-tier cache
    PD at that site (off the critical path, like the async scheduler's
    prefetch); under pressure the same eviction machinery demotes them.

Eviction keeps the replica bookkeeping exact: evicted chunks leave
``du:<id>:chunks``, location versions bump (transfer resolve/estimate
caches invalidate), and a PD that no longer covers every chunk is demoted
from ``locations`` back to a partial holder.
"""

from __future__ import annotations

import abc
import collections
import dataclasses
import itertools
import queue
import threading
from typing import (
    Any,
    Callable,
    Deque,
    Dict,
    List,
    Optional,
    Sequence,
    Set,
    Tuple,
)

from .coordination import StoreEvent
from .data_unit import DataUnit
from .pilot import PilotData, PilotDataDescription, PilotState, RuntimeContext
from .replication import _site_of

# ------------------------------------------------------------------- tiers
#: fastest → slowest; ordinals rank tiers where a scalar is needed
TIER_DRAM = "dram-cache"
TIER_NODE = "node-local"
TIER_SITE = "site-shared"
TIER_ARCHIVE = "archival"
TIERS = (TIER_DRAM, TIER_NODE, TIER_SITE, TIER_ARCHIVE)

#: URL scheme → tier (the adaptor already encodes the hardware class)
_SCHEME_TIERS = {
    "mem": TIER_DRAM,
    "file": TIER_NODE,
    "sharedfs": TIER_SITE,
    "object": TIER_ARCHIVE,
}

#: profile-bandwidth thresholds (bytes/s) for schemes the map doesn't know
_BW_TIERS = ((5e9, TIER_DRAM), (1e9, TIER_NODE), (0.5e9, TIER_SITE))


def classify_tier(pd: PilotData) -> str:
    """Tier of a Pilot-Data: explicit ``tier=`` in its description wins,
    then the backend scheme, then the profile's sustained bandwidth."""
    explicit = getattr(pd.description, "tier", "")
    if explicit:
        if explicit not in TIERS:
            raise ValueError(f"unknown storage tier {explicit!r} (known: {TIERS})")
        return explicit
    tier = _SCHEME_TIERS.get(pd.backend.scheme)
    if tier is not None:
        return tier
    bw = pd.backend.profile.bandwidth
    for threshold, t in _BW_TIERS:
        if bw >= threshold:
            return t
    return TIER_ARCHIVE


def tier_rank(tier: str) -> int:
    """0 = fastest (DRAM); larger = colder."""
    return TIERS.index(tier) if tier in TIERS else len(TIERS)


# ------------------------------------------------------------------- pins
class PinRegistry:
    """DU ids pinned by live consumers — never evicted while pinned.

    Owners are CU ids: a CU pins its declared inputs from submission
    (Waiting CUs included — the DependencyTracker re-pins on re-park)
    until it reaches a terminal state.  Lookups are self-healing: a pin
    whose owner CU is already terminal is dropped lazily, so a crashed
    agent cannot leak a pin forever.
    """

    def __init__(self, ctx: RuntimeContext):
        self.ctx = ctx
        self._lock = threading.Lock()
        self._owners: Dict[str, Set[str]] = {}  # du_id -> owner cu_ids
        #: du_id -> owner cu_id -> consumed chunk prefix (streaming reads):
        #: chunks below EVERY live owner's frontier are consumed and may be
        #: evicted even while the DU stays pinned
        self._frontiers: Dict[str, Dict[str, int]] = {}

    def pin(self, du_id: str, owner: str) -> None:
        with self._lock:
            self._owners.setdefault(du_id, set()).add(owner)

    def pin_inputs(self, cu) -> None:
        for du_id in cu.description.input_data:
            self.pin(du_id, cu.id)

    def unpin(self, du_id: str, owner: str) -> None:
        with self._lock:
            owners = self._owners.get(du_id)
            if owners is not None:
                owners.discard(owner)
                if not owners:
                    del self._owners[du_id]
            fr = self._frontiers.get(du_id)
            if fr is not None:
                fr.pop(owner, None)
                if not fr:
                    del self._frontiers[du_id]

    def unpin_owner(self, owner: str) -> None:
        with self._lock:
            for du_id in list(self._owners):
                self._owners[du_id].discard(owner)
                if not self._owners[du_id]:
                    del self._owners[du_id]
            for du_id in list(self._frontiers):
                self._frontiers[du_id].pop(owner, None)
                if not self._frontiers[du_id]:
                    del self._frontiers[du_id]

    # ------------------------------------------------------ read frontiers
    def advance_frontier(self, du_id: str, owner: str, upto: int) -> int:
        """Record that ``owner`` has consumed the first ``upto`` chunks of
        streaming DU ``du_id``.  Monotone: a frontier never moves backward
        (max-merge), so eviction decisions based on an earlier reading
        stay valid.  Returns the owner's (possibly unchanged) frontier."""
        with self._lock:
            fr = self._frontiers.setdefault(du_id, {})
            cur = fr.get(owner, 0)
            if upto > cur:
                fr[owner] = upto
                return upto
            return cur

    def read_frontier(self, du_id: str) -> int:
        """The slowest *live* pinning consumer's consumed prefix: chunks
        below this index are consumed by everyone and evictable.  A live
        pinning owner with no recorded frontier holds it at 0 (nothing of
        the stream may be reclaimed for it yet); with no live pinning
        owners at all there is no frontier constraint (the plain
        redundancy/replication invariants still apply)."""
        with self._lock:
            owners = list(self._owners.get(du_id, ()))
            fr = dict(self._frontiers.get(du_id, {}))
        live = [o for o in owners if self._owner_live(o)]
        if not live:
            return -1  # unconstrained (no live consumer to starve)
        return min(fr.get(o, 0) for o in live)

    #: owner CU states whose pins bind: a parked consumer's inputs and a
    #: staging/running attempt's inputs must survive; a merely *queued*
    #: (Pending) CU re-stages whatever is missing when it runs, so its
    #: pin does not block eviction of the bytes someone else needs NOW
    _BINDING_STATES = ("Waiting", "Staging", "Running")

    def _owner_live(self, cu_id: str) -> bool:
        state = self.ctx.store.hget(f"cu:{cu_id}", "state")
        return state in self._BINDING_STATES

    def pinned(self, du_id: str) -> bool:
        """True iff a *live* (non-terminal) consumer pins ``du_id``; dead
        owners are garbage-collected on the way through."""
        with self._lock:
            owners = list(self._owners.get(du_id, ()))
        if not owners:
            return False
        dead = [o for o in owners if not self._owner_live(o)]
        if dead:
            with self._lock:
                live = self._owners.get(du_id)
                if live is not None:
                    live.difference_update(dead)
                    if not live:
                        del self._owners[du_id]
                        return False
        return len(owners) > len(dead)

    def pinned_dus(self) -> List[str]:
        with self._lock:
            return sorted(self._owners)


# -------------------------------------------------------- eviction policies
@dataclasses.dataclass
class Victim:
    """One evictable (DU, chunk subset) group inside a PD, with the access
    statistics eviction policies rank on."""

    du_id: str
    indices: List[int]  # evictable chunk indices, ascending
    nbytes: int
    last_access: int  # monotonic access counter (0 = never accessed)
    access_count: int
    #: owning tenant — tenant-aware make_room reclaims the requestor's
    #: own redundant chunks before touching anyone else's
    tenant: str = "default"


class EvictionPolicy(abc.ABC):
    """Orders eviction victims; space is reclaimed front-to-back.

    Implementations must be deterministic for a fixed victim list (the
    CI regression gate replays eviction-churn benchmarks)."""

    #: registry key; subclasses override
    name: str = "?"

    @abc.abstractmethod
    def rank(self, pd: PilotData, victims: Sequence[Victim]) -> List[Victim]:
        ...


_POLICIES: Dict[str, Callable[..., EvictionPolicy]] = {}
_policy_lock = threading.Lock()


def register_eviction_policy(name: str):
    """Class decorator: register an eviction policy factory under ``name``."""

    def deco(cls):
        cls.name = name
        with _policy_lock:
            _POLICIES[name] = cls
        return cls

    return deco


def make_eviction_policy(name: str, **kwargs) -> EvictionPolicy:
    with _policy_lock:
        if name not in _POLICIES:
            raise KeyError(
                f"unknown eviction policy {name!r} "
                f"(registered: {sorted(_POLICIES)})"
            )
        factory = _POLICIES[name]
    return factory(**kwargs)


def list_eviction_policies() -> List[str]:
    with _policy_lock:
        return sorted(_POLICIES)


@register_eviction_policy("lru")
class LRUPolicy(EvictionPolicy):
    """Least-recently-accessed DU first (du id breaks ties)."""

    def rank(self, pd, victims):
        return sorted(victims, key=lambda v: (v.last_access, v.du_id))


@register_eviction_policy("lfu")
class LFUPolicy(EvictionPolicy):
    """Least-frequently-accessed DU first; recency, then id break ties."""

    def rank(self, pd, victims):
        return sorted(
            victims,
            key=lambda v: (v.access_count, v.last_access, v.du_id),
        )


@register_eviction_policy("largest-first")
class LargestFirstPolicy(EvictionPolicy):
    """Most evictable bytes first — frees quota in the fewest evictions."""

    def rank(self, pd, victims):
        return sorted(victims, key=lambda v: (-v.nbytes, v.du_id))


# ------------------------------------------------------------ tier manager
class TierManager:
    """Storage-hierarchy coordinator: tier classification, access stats,
    quota-driven eviction, and mem-tier cache promotion.

    Attached to the :class:`RuntimeContext` (``ctx.tier_manager``) so
    Pilot-Data quota checks can call :meth:`make_room` without an import
    cycle.  Access statistics ride the coordination store's keyspace
    notifications: the transfer service publishes one ``du:access`` record
    per stage-in and this manager folds it into per-DU frequency/recency
    (and per-site demand, which drives promotion).
    """

    def __init__(
        self,
        ctx: RuntimeContext,
        cds: Optional[Any] = None,
        eviction_policy: str = "lru",
        cache_bytes: int = 0,
        promote_after: int = 2,
        auto_promote: bool = True,
    ):
        self.ctx = ctx
        self.cds = cds
        self.policy: EvictionPolicy = (
            eviction_policy
            if isinstance(eviction_policy, EvictionPolicy)
            else make_eviction_policy(eviction_policy)
        )
        self.pins = PinRegistry(ctx)
        self.cache_bytes = cache_bytes
        self.promote_after = promote_after
        #: bounded audit tail of evictions ({"pd", "du", "chunks",
        #: "nbytes", "policy"}) — a churn workload evicts indefinitely,
        #: so the full history cannot be kept; totals below never reset
        self.evictions: Deque[Dict[str, Any]] = collections.deque(maxlen=1000)
        self.evictions_total = 0
        self.evicted_bytes_total = 0
        #: evictions where the requesting tenant reclaimed ANOTHER
        #: tenant's (redundant, unpinned) chunks — only after its own
        #: were exhausted
        self.cross_tenant_evictions_total = 0
        #: cross-tenant evictions that touched a pinned DU: guarded to be
        #: impossible (victim discovery excludes them); bench-gated == 0
        self.cross_tenant_pinned_evictions = 0
        #: bounded audit tail of (du_id, cache_pd_id) promotions
        self.promotions: Deque[tuple] = collections.deque(maxlen=1000)
        self.promotions_total = 0
        #: site -> mem-tier cache PD (created lazily on first promotion)
        self.cache_pds: Dict[str, PilotData] = {}
        self._counter = itertools.count(1)
        self._lock = threading.Lock()
        self._evict_lock = threading.Lock()
        #: serializes cache-PD creation per process (NOT self._lock: a PD
        #: constructor writes to the store, whose callbacks re-enter
        #: _on_access and take self._lock on the same thread)
        self._cache_create_lock = threading.Lock()
        self._freq: Dict[str, int] = {}
        self._last: Dict[str, int] = {}
        self._site_freq: Dict[tuple, int] = {}
        self._promote_q: "queue.Queue[Optional[tuple]]" = queue.Queue()
        self._queued: Set[tuple] = set()
        self._stop = threading.Event()
        self._token = ctx.store.subscribe(self._on_access, prefix="du:access")
        ctx.tier_manager = self
        self._thread: Optional[threading.Thread] = None
        if auto_promote and cache_bytes > 0:
            self._thread = threading.Thread(
                target=self._promote_loop, name="tier-promoter", daemon=True
            )
            self._thread.start()

    # -------------------------------------------------------------- tiers
    def tier_of(self, pd: PilotData) -> str:
        return classify_tier(pd)

    def pds_by_tier(self) -> Dict[str, List[str]]:
        """Live PD ids grouped by tier (diagnostics/benchmarks)."""
        out: Dict[str, List[str]] = {t: [] for t in TIERS}
        for obj in list(self.ctx.objects.values()):
            if isinstance(obj, PilotData):
                out[self.tier_of(obj)].append(obj.id)
        return {t: sorted(ids) for t, ids in out.items()}

    # ------------------------------------------------------- access stats
    def _on_access(self, ev: StoreEvent) -> None:
        """Store callback (dispatcher thread): fold one access record into
        the frequency/recency tables; cheap and lock-scoped only."""
        if ev.op != "hset" or ev.key != "du:access" or ev.field is None:
            return
        du_id = ev.field
        location = ""
        if isinstance(ev.value, dict):
            location = ev.value.get("location", "")
        with self._lock:
            tick = next(self._counter)
            self._freq[du_id] = self._freq.get(du_id, 0) + 1
            self._last[du_id] = tick
            hot = False
            if location:
                site = _site_of(location)
                key = (du_id, site)
                self._site_freq[key] = self._site_freq.get(key, 0) + 1
                hot = (
                    self.cache_bytes > 0
                    and self._site_freq[key] >= self.promote_after
                    and key not in self._queued
                )
                if hot:
                    self._queued.add(key)
        if hot:
            self._promote_q.put((du_id, site))

    def access_stats(self, du_id: str) -> tuple:
        """(access_count, last_access_tick) for a DU; (0, 0) if never.
        Barriers on the store dispatcher first, so stats reflect every
        access record already published."""
        self.ctx.store.flush_events()
        with self._lock:
            return self._freq.get(du_id, 0), self._last.get(du_id, 0)

    def _stats_snapshot(self) -> Tuple[Dict[str, int], Dict[str, int]]:
        """One consistent (freq, last) copy — callers that rank many DUs
        take this once instead of barriering per DU."""
        with self._lock:
            return dict(self._freq), dict(self._last)

    # ---------------------------------------------------------- eviction
    def _live_holders(self, du: DataUnit) -> Dict[str, Set[int]]:
        """Registered chunk holders that are still usable sources: live
        objects, not FAILED/CANCELED, not purged by fault recovery."""
        store = self.ctx.store
        ts = self.ctx.transfer_service
        out: Dict[str, Set[int]] = {}
        for pd_id, idxs in du.chunk_holders().items():
            if pd_id not in self.ctx.objects:
                continue
            if store.hget(f"pd:{pd_id}", "state") in (
                PilotState.FAILED,
                PilotState.CANCELED,
            ):
                continue
            if ts is not None and ts.is_dead(pd_id):
                continue
            out[pd_id] = set(idxs)
        return out

    def _du_handle(self, pd: PilotData, du_id: str) -> Optional[DataUnit]:
        du = self.ctx.objects.get(du_id)
        if isinstance(du, DataUnit):
            return du
        return pd._du_objs.get(du_id)

    def evictable_victims(
        self,
        pd: PilotData,
        exclude_du: Optional[str] = None,
        tenant: Optional[str] = None,
    ) -> List[Victim]:
        """Chunk replicas in ``pd`` that are safe to drop.

        A chunk is redundant iff at least one OTHER live registered holder
        also holds it — so eviction can never lose the last copy of a
        sealed DU's chunk.  Whole DUs are skipped when they are pinned by
        a live consumer, leased as an in-flight transfer source, being
        staged into ``pd`` right now, or when dropping this (full) replica
        would take the DU below its ``replication_factor``.

        ``tenant`` names the requestor (the tenant whose write needs the
        space): the streaming-frontier carve-out below — the only path
        that may touch a *pinned* DU's chunks — is then restricted to the
        requestor's own DUs, so one tenant's pressure can never reclaim
        even the consumed prefix of ANOTHER tenant's pinned working set.
        """
        ts = self.ctx.transfer_service
        store = self.ctx.store
        # one barrier + one stats copy up front (PD-L002: per-DU
        # access_stats() calls would flush the dispatcher once per DU,
        # and make_room() calls us with _evict_lock held)
        self.ctx.store.flush_events()
        freq, last_seen = self._stats_snapshot()
        out: List[Victim] = []
        for du_id in pd.du_ids():
            if du_id == exclude_du:
                continue
            du = self._du_handle(pd, du_id)
            if du is None:
                continue
            du_tenant = store.hget(f"du:{du_id}", "tenant") or "default"
            frontier: Optional[int] = None
            if self.pins.pinned(du_id):
                if not du.streaming:
                    continue
                if tenant is not None and du_tenant != tenant:
                    # another tenant's pinned streaming working set is
                    # off-limits entirely, consumed prefix included
                    continue
                # streamed chunks are evictable only PAST the slowest live
                # consumer's read frontier: consumed prefix chunks may be
                # reclaimed (that is the backpressure valve), unconsumed
                # ones never (a released prefix-consumer must not observe
                # a chunk gap)
                frontier = self.pins.read_frontier(du_id)
                if frontier == 0:
                    continue  # nothing consumed yet: fully protected
            if ts is not None and ts.source_leased(pd.id, du_id):
                continue
            # local accounting, so transient (register=False) sandbox
            # copies are evictable too; redundancy is judged against the
            # *registered* holdings of every other live PD
            mine = set(pd.chunks_held(du_id))
            holders = self._live_holders(du)
            holders.pop(pd.id, None)
            if not mine:
                continue
            if pd.id in du.locations:
                live_full = [
                    loc
                    for loc in du.locations
                    if loc == pd.id or loc in holders
                ]
                if len(live_full) <= max(du.replication_factor, 1):
                    continue  # would drop the DU below its factor
            elsewhere: Set[int] = set()
            for idxs in holders.values():
                elsewhere |= idxs
            inflight = (
                ts.inflight_chunks(du_id, pd.id) if ts is not None else set()
            )
            indices = sorted(i for i in mine - inflight if i in elsewhere)
            if frontier is not None and frontier >= 0:
                indices = [i for i in indices if i < frontier]
            if not indices:
                continue
            chunks = du.chunks
            nbytes = sum(chunks[i].size for i in indices if i < len(chunks))
            count, last = freq.get(du_id, 0), last_seen.get(du_id, 0)
            out.append(
                Victim(
                    du_id=du_id,
                    indices=indices,
                    nbytes=nbytes,
                    last_access=last,
                    access_count=count,
                    tenant=du_tenant,
                )
            )
        return out

    def make_room(
        self,
        pd: PilotData,
        need: int,
        exclude_du: Optional[str] = None,
        tenant: Optional[str] = None,
    ) -> int:
        """Reclaim at least ``need`` bytes in ``pd`` by evicting redundant
        chunk replicas in policy order; returns bytes actually freed (may
        be less when the invariants forbid further eviction — the caller
        then raises ``QuotaExceeded`` exactly as before).

        ``tenant`` names the requestor: its OWN redundant chunks are
        reclaimed (in policy order) before any other tenant's are touched,
        so one tenant's cache pressure is absorbed by its own working set
        first.  Cross-tenant evictions — still invariant-guarded: never a
        pinned DU, never a last copy — are counted separately for audit.
        With ``tenant=None`` (or a single-tenant world, where every victim
        shares the requestor's tenant) the ordering is exactly the
        pre-tenancy policy ranking."""
        if need <= 0:
            return 0
        freed = 0
        # candidate discovery barriers on the store dispatcher, so it must
        # run before _evict_lock is taken (PD-L002: the dispatcher may be
        # delivering a callback that wants this same lock)
        candidates = self.evictable_victims(
            pd, exclude_du=exclude_du, tenant=tenant
        )
        with self._evict_lock:
            if tenant is not None:
                own = [v for v in candidates if v.tenant == tenant]
                others = [v for v in candidates if v.tenant != tenant]
                victims = self.policy.rank(pd, own) + self.policy.rank(
                    pd, others
                )
            else:
                victims = self.policy.rank(pd, candidates)
            for v in victims:
                if freed >= need:
                    break
                du = self._du_handle(pd, v.du_id)
                if du is None:
                    continue
                take: List[int] = []
                taken = 0
                for i in v.indices:
                    if freed + taken >= need:
                        break
                    take.append(i)
                    taken += du.chunks[i].size if i < du.n_chunks else 0
                if not take:
                    continue
                nbytes = pd.evict_chunks(du, take)
                freed += nbytes
                if nbytes:
                    self.evictions_total += 1
                    self.evicted_bytes_total += nbytes
                    cross = tenant is not None and v.tenant != tenant
                    if cross:
                        self.cross_tenant_evictions_total += 1
                        if self.pins.pinned(v.du_id):
                            # guarded against upstream — this counter
                            # staying 0 is the bench-gated isolation claim
                            self.cross_tenant_pinned_evictions += 1
                    self.evictions.append(
                        {
                            "pd": pd.id,
                            "du": v.du_id,
                            "chunks": len(take),
                            "nbytes": nbytes,
                            "policy": self.policy.name,
                            "tenant": v.tenant,
                            "requestor": tenant or "",
                        }
                    )
        return freed

    # --------------------------------------------------------- promotion
    def cache_pd(self, site: str) -> Optional[PilotData]:
        """The mem-tier cache PD for ``site`` (created lazily; racing
        creators serialize so exactly one PD is ever registered)."""
        if self.cache_bytes <= 0:
            return None
        with self._lock:
            pd = self.cache_pds.get(site)
        if pd is not None:
            return pd
        with self._cache_create_lock:
            with self._lock:
                pd = self.cache_pds.get(site)
            if pd is not None:
                return pd  # lost the race: the winner already registered
            desc = PilotDataDescription(
                service_url=f"mem://{site}/tier-cache",
                affinity=site,
                size_quota=self.cache_bytes,
                name=f"tier-cache-{site}",
                tier=TIER_DRAM,
            )
            pd = PilotData(desc, self.ctx)
            self.ctx.register(pd)
            if self.cds is not None:
                self.cds.add_pilot_data(pd)
            with self._lock:
                self.cache_pds[site] = pd
            return pd

    def _promote_one(self, du_id: str, site: str) -> bool:
        """Copy a hot DU into the site's mem-tier cache PD (off the
        consumer's critical path).  Quota pressure in the cache is handled
        by the same eviction machinery — promotion is what *creates* the
        pressure that demotes colder entries."""
        du = self.ctx.objects.get(du_id)
        if not isinstance(du, DataUnit) or not du.sealed:
            return False
        if du.size <= 0 or du.size > self.cache_bytes:
            return False
        cache = self.cache_pd(site)
        if cache is None or not cache.missing_chunks(du):
            return False
        ts = self.ctx.transfer_service
        if ts is None:
            return False
        try:
            ts.heal_replica(du, cache)
        except Exception:
            return False  # quota/invariants blocked: stay at the cold tier
        if cache.has_du(du_id):
            self.promotions_total += 1
            self.promotions.append((du_id, cache.id))
            return True
        return False

    def drain_promotions(self, max_n: int = 100) -> int:
        """Synchronously process queued promotions (deterministic mode for
        benchmarks/tests); returns the number of DUs promoted.  Barriers on
        the store dispatcher first so access records already published have
        fed the promotion queue."""
        self.ctx.store.flush_events()
        done = 0
        for _ in range(max_n):
            try:
                item = self._promote_q.get_nowait()
            except queue.Empty:
                break
            if item is None:
                break
            du_id, site = item
            if self._promote_one(du_id, site):
                done += 1
            with self._lock:
                self._queued.discard(item)
        return done

    def _promote_loop(self) -> None:
        while not self._stop.is_set():
            try:
                item = self._promote_q.get(timeout=0.1)
            except queue.Empty:
                continue
            if item is None:
                break
            du_id, site = item
            try:
                self._promote_one(du_id, site)
            except Exception:
                pass  # a broken promotion must not kill the worker
            finally:
                with self._lock:
                    self._queued.discard(item)

    # ------------------------------------------------------------ control
    def stop(self) -> None:
        self._stop.set()
        self.ctx.store.unsubscribe(self._token)
        self._promote_q.put(None)
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        if self.ctx.tier_manager is self:
            self.ctx.tier_manager = None
