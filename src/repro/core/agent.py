"""Pilot-Agent: the decentralized per-pilot execution loop (§4.2, Fig. 1).

"Each Pilot is represented by a decentral component referred to as the
Pilot-Agent, which manages the set of resources assigned to it. ... Each
Pilot-Agent generally pulls from two queues: its agent-specific queue and a
global queue."

The agent:
  * waits out the (simulated) batch-queue time, then reports ACTIVE and
    pushes local resource information to the coordination store (paper: the
    agent "collects various information about the local resource, which is
    pushed to the Redis server and used by the Pilot-Manager to conduct e.g.
    placement decisions");
  * pulls CU ids from [pilot queue, global queue], claims them with an
    atomic CAS (exactly-once against racing duplicates), stages input DUs
    (pull-mode data management), executes the registered executable, stages
    outputs into DUs, and heartbeats throughout;
  * honors its walltime: unfinished claimed CUs are re-queued (the paper's
    observed walltime-limit failures, §6.4, handled instead of lost);
  * supports hard-kill for fault-injection (heartbeat stops, in-flight work
    is discarded — the manager's monitor re-queues it).
"""

from __future__ import annotations

import itertools
import threading
import time
import traceback
from typing import Dict, Iterator, List, Optional, Tuple

from .affinity import match_affinity
from .compute_unit import CUState, ComputeUnit, FUNCTIONS
from .data_unit import DataUnit, DUState
from .pilot import HEARTBEATS_KEY, PilotState, QuotaExceeded, RuntimeContext

GLOBAL_QUEUE = "queue:global"

#: staging attempts a CU may abandon to quota backpressure (sandbox full
#: of OTHER live consumers' pinned inputs) before the hit counts as a
#: real failure; each wait re-queues without burning a retry attempt
MAX_QUOTA_WAITS = 100

#: quota-blocked waits a streaming producer's flush tolerates before the
#: QuotaExceeded surfaces as a CU failure — each wait is the backpressure
#: that paces a fast producer against a slow consumer's sandbox (eviction
#: can only reclaim streamed chunks the consumers' read frontiers passed)
MAX_STREAM_FLUSH_WAITS = 200

#: attempt-unique stream-writer tokens (``<cu>@<pilot>#<n>``): the pilot id
#: in the middle is what lets a retry prove the prior writer is dead
_stream_tokens = itertools.count()


class CUContext:
    """Execution context handed to CU executables (the sandbox view).

    Output writes are buffered per attempt and flushed into the real output
    DUs only after the exactly-once winner CAS: a CU that raises after
    partial ``write_output`` calls leaves its output DUs untouched (a retry
    starts from a clean buffer instead of appending onto half-written
    state), and a straggler duplicate that loses the race never writes at
    all."""

    def __init__(self, cu: ComputeUnit, pilot, ctx: RuntimeContext):
        self.cu = cu
        self.pilot = pilot
        self.ctx = ctx
        #: output index -> {relpath: bytes}, flushed by the agent on win
        self._out_buffers: Dict[int, Dict[str, bytes]] = {}
        #: this attempt's stream-writer identity (streaming outputs only)
        self._stream_token = f"{cu.id}@{pilot.id}#{next(_stream_tokens)}"
        #: set once this attempt loses a stream to a live foreign writer —
        #: the agent declines the winner CAS instead of double-publishing
        self._stream_lost = False

    # ------------------------------------------------------------- inputs
    def input_dus(self) -> List[DataUnit]:
        return [self.ctx.lookup(d) for d in self.cu.description.input_data]

    def read_input(self, du_id: str, relpath: str) -> bytes:
        """Read an input file — from the sandbox copy if staged, else via
        the logical link to a co-located PD."""
        sandbox = self.pilot.sandbox
        if sandbox.has_du(du_id):
            return sandbox.fetch_du_file(du_id, relpath)
        du = self.ctx.lookup(du_id)
        pd, linked = self.ctx.transfer_service.resolve_access(
            du, self.pilot.affinity
        )
        if pd is not None:
            return pd.fetch_du_file(du_id, relpath)
        return du.read(relpath)  # pre-replica local buffer

    def input_manifest(self, du_id: str) -> Dict[str, int]:
        return self.ctx.lookup(du_id).manifest

    # ------------------------------------------------------------ outputs
    def write_output(self, relpath: str, data: bytes, index: int = 0) -> None:
        """Stage a file for the index-th output DU (Fig. 5 data flow).

        Buffered: the bytes land in the DU only if this attempt wins the
        exactly-once completion race (see :meth:`flush_outputs`)."""
        out_ids = self.cu.description.output_data
        if not out_ids:
            raise RuntimeError(f"{self.cu.url} declares no output_data")
        if not 0 <= index < len(out_ids):
            raise IndexError(
                f"{self.cu.url} has {len(out_ids)} output DUs, no index {index}"
            )
        if relpath.startswith("/") or ".." in relpath.split("/"):
            raise ValueError(f"bad DU-relative path {relpath!r}")
        self._out_buffers.setdefault(index, {})[relpath] = bytes(data)

    def flush_outputs(self) -> None:
        """Move the attempt's buffered writes into the real output DUs —
        called by the agent strictly after the winner CAS, so failed
        attempts and losing duplicates never touch a DU.

        Streaming DUs flush in *insertion* order (their canonical stream is
        append-ordered — already-published chunk prefixes must not shift);
        sealed-at-once DUs keep the deterministic sorted order."""
        out_ids = self.cu.description.output_data
        for index in sorted(self._out_buffers):
            du: DataUnit = self.ctx.lookup(out_ids[index])
            items = self._out_buffers[index].items()
            for relpath, data in (
                items if du.streaming else sorted(items)
            ):
                du.add_file(relpath, data)
        self._out_buffers.clear()

    # -------------------------------------------------- streaming outputs
    def flush_output(self, index: int = 0) -> bool:
        """Flush the buffered writes of streaming output ``index`` NOW,
        publishing every newly-completed chunk to consumers (ordered
        chunk-availability events on the store stream) while this CU keeps
        running.

        Exactly-once is preserved by a **stream-writer CAS** on the DU: the
        first attempt to flush claims the stream; a racing duplicate loses
        the claim, drops its buffer, and returns ``False`` (the agent then
        declines the winner CAS for that attempt).  A writer token whose
        pilot has died is stolen — after rolling the half-written stream
        back to zero — so retries of a crashed producer start clean.

        Returns ``True`` if this attempt owns the stream and the flush
        published; ``False`` if the stream belongs to a live foreign
        attempt (the caller should stop producing)."""
        out_ids = self.cu.description.output_data
        if not out_ids:
            raise RuntimeError(f"{self.cu.url} declares no output_data")
        if not 0 <= index < len(out_ids):
            raise IndexError(
                f"{self.cu.url} has {len(out_ids)} output DUs, no index {index}"
            )
        du: DataUnit = self.ctx.lookup(out_ids[index])
        if not du.streaming:
            raise RuntimeError(
                f"{du.url} is not a streaming DU; buffered writes flush "
                f"after the winner CAS instead"
            )
        store = self.ctx.store
        if store.hget(f"cu:{self.cu.id}", "winner") is not None:
            # another attempt already completed the whole CU
            self._stream_lost = True
            self._out_buffers.pop(index, None)
            return False
        if not self._own_stream(du):
            self._out_buffers.pop(index, None)
            return False
        buf = self._out_buffers.pop(index, None)
        if buf:
            for relpath, data in buf.items():  # insertion order
                du.add_file(relpath, data)
        self._publish_prefix(du)
        return True

    def _own_stream(self, du: DataUnit) -> bool:
        """Acquire (or re-confirm) the stream-writer claim for ``du``."""
        store = self.ctx.store
        key = f"du:{du.id}"
        token = self._stream_token
        if store.hcas(key, "stream_writer", None, token):
            return True
        cur = store.hget(key, "stream_writer")
        if cur == token:
            return True
        writer_pilot = None
        if isinstance(cur, str) and "@" in cur and "#" in cur:
            writer_pilot = cur.split("@", 1)[1].rsplit("#", 1)[0]
        if writer_pilot is not None:
            pstate = store.hget(f"pilot:{writer_pilot}", "state")
            if pstate in (
                PilotState.FAILED, PilotState.CANCELED, PilotState.DONE
            ) and store.hcas(key, "stream_writer", cur, token):
                # the prior writer died mid-stream: roll its partial
                # publishes back so this attempt re-streams from zero
                du.reset_stream()
                return True
        self._stream_lost = True
        return False

    def _publish_prefix(self, du: DataUnit) -> None:
        """Materialize the newly-completed chunks into the producer's
        sandbox PD, cost-account the move, then advance the published
        prefix — strictly in that order, so a consumer released by the
        publish event always finds a registered holder for every chunk of
        the prefix (the no-gap invariant).

        The sandbox quota is the backpressure: when eviction cannot make
        room (consumers' read frontiers haven't passed the already-
        streamed chunks), the producer *waits* here instead of flooding."""
        ts = self.ctx.transfer_service
        sandbox = self.pilot.sandbox
        upto = du.publishable_chunks()
        already = du.published
        if upto <= already:
            return
        t0 = time.monotonic()
        waits = 0
        while True:
            try:
                nbytes = sandbox.put_chunks(du, list(range(already, upto)))
                break
            except QuotaExceeded:
                waits += 1
                if waits > MAX_STREAM_FLUSH_WAITS:
                    raise
                time.sleep(max(self.ctx.poll_s, 0.01))
        if nbytes > 0:
            from .transfer import TransferRecord

            sim = ts.simulated_ingest_time(nbytes, sandbox)
            self.ctx.sleep_sim(sim)
            ts.record(
                TransferRecord(
                    du_id=du.id,
                    src_pd=None,
                    dst_pd=sandbox.id,
                    nbytes=nbytes,
                    sim_seconds=sim,
                    wall_seconds=time.monotonic() - t0,
                    wall_start=t0,
                    chunks=upto - already,
                )
            )
        du.publish_prefix(upto)

    def abort_stream(self) -> None:
        """Roll back this attempt's partially-streamed outputs (the
        exception/retry path): every streaming output DU whose writer
        claim is ours is reset to zero published chunks and the claim
        released — a failed producer attempt publishes nothing durable."""
        store = self.ctx.store
        for du_id in self.cu.description.output_data:
            try:
                du: DataUnit = self.ctx.lookup(du_id)
            except KeyError:
                continue
            if not du.streaming or du.sealed:
                continue
            if store.hget(f"du:{du.id}", "stream_writer") == self._stream_token:
                du.reset_stream()
                store.hdel(f"du:{du.id}", "stream_writer")
        self._out_buffers.clear()

    def lost_stream(self) -> bool:
        """True if a live foreign attempt owns one of our output streams —
        the agent declines the winner CAS for this attempt."""
        return self._stream_lost

    # --------------------------------------------------- streaming inputs
    def stream_input(
        self, du_id: str, window: int = 4
    ) -> Iterator[Tuple[int, bytes]]:
        """Iterate ``(chunk_index, chunk_bytes)`` over a streaming input
        DU, staging chunks into the sandbox as the producer publishes them
        (chunk-granular stage-in, re-planned as more chunks appear) and
        blocking — event-driven on the ``published`` field — when the
        consumer catches up with the producer.

        ``window`` bounds read-ahead: at most that many chunks beyond the
        current read position are staged per call, and the consumer's read
        frontier advances after each yielded chunk so the TierManager may
        evict consumed stream chunks behind it (the backpressure valve)."""
        du: DataUnit = self.ctx.lookup(du_id)
        ts = self.ctx.transfer_service
        sandbox = self.pilot.sandbox
        tm = self.ctx.tier_manager
        store = self.ctx.store
        i = 0
        while True:
            if du.state == DUState.FAILED:
                raise RuntimeError(
                    f"{du.url} failed mid-stream: "
                    f"{store.hget(f'du:{du.id}', 'error') or 'producer failed'}"
                )
            avail = du.available_chunks()
            if i >= avail:
                if du.sealed and i >= du.n_chunks:
                    return
                # producer ahead of us not yet: wait on the next publish
                # event (short timeout so FAILED/reset are re-checked)
                store.wait_field(
                    f"du:{du.id}",
                    "published",
                    lambda v, _i=i: int(v or 0) > _i or du.sealed,
                    timeout=0.5,
                    default=0,
                )
                continue
            ts.stage_in(
                du, sandbox, self.pilot.affinity,
                prefix=min(avail, i + window),
            )
            data = self._read_chunk(du, sandbox, i)
            if data is None:
                # stream rolled back mid-fetch (or holder lost): re-check
                time.sleep(max(self.ctx.poll_s, 0.01))
                continue
            yield i, data
            if tm is not None:
                tm.pins.advance_frontier(du.id, self.cu.id, i + 1)
            i += 1

    def _read_chunk(self, du: DataUnit, sandbox, i: int) -> Optional[bytes]:
        """Chunk ``i``'s bytes from the sandbox — or, when ``stage_in``
        resolved to a *linked* access and physically moved nothing (a
        sealed DU on a same-site PD, e.g. a sharedfs shard), straight from
        a holder replica.  None if no live holder has the chunk (stream
        rolled back mid-fetch)."""
        if i in set(sandbox.chunks_held(du.id)):
            return sandbox.fetch_du_chunk(du.id, i)
        for loc in du.locations:
            try:
                pd = self.ctx.lookup(loc)
            except KeyError:
                continue
            if i in set(pd.chunks_held(du.id)):
                return pd.fetch_du_chunk(du.id, i)
        return None


class PilotAgent:
    def __init__(self, pilot, ctx: RuntimeContext):
        self.pilot = pilot
        self.ctx = ctx
        self._stop = threading.Event()
        self._dead = threading.Event()  # hard failure: discard everything
        self._threads: List[threading.Thread] = []
        self._slots = threading.Semaphore(pilot.description.slots)
        self._started_at: Optional[float] = None
        self._lock = threading.Lock()
        self._running: Dict[str, float] = {}  # cu_id -> start time
        # Own pilot/sandbox state tracked off keyspace notifications, so
        # the claim-loop SUSPECT/FAILED checks are memory reads instead of
        # per-iteration store ops (assignment is atomic; no lock needed).
        # Events land from the store's dispatcher thread a beat after the
        # mutation — the claim loop tolerates that: the monitor's CAS plus
        # the agent's own post-pop state re-check keep decisions correct.
        self._own_state_cache: Optional[str] = ctx.store.hget(
            f"pilot:{pilot.id}", "state"
        )
        self._sandbox_failed_flag = False
        self._sub_tokens = [
            ctx.store.subscribe(
                self._on_pilot_event, prefix=f"pilot:{pilot.id}"
            ),
            ctx.store.subscribe(
                self._on_sandbox_event, prefix=f"pd:{pilot.sandbox.id}"
            ),
        ]

    def _on_pilot_event(self, ev) -> None:
        if ev.op == "hset" and ev.field == "state":
            self._own_state_cache = ev.value

    def _on_sandbox_event(self, ev) -> None:
        if ev.op == "hset" and ev.field == "state":
            self._sandbox_failed_flag = ev.value == PilotState.FAILED

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        t = threading.Thread(
            target=self._main, name=f"agent-{self.pilot.id}", daemon=True
        )
        self._threads.append(t)
        t.start()

    def stop(self) -> None:
        self._stop.set()
        self._unsubscribe()

    def kill(self) -> None:
        """Simulated node crash: stop heartbeating immediately, abandon CUs."""
        self._dead.set()
        self._stop.set()

    def _unsubscribe(self) -> None:
        for token in self._sub_tokens:
            self.ctx.store.unsubscribe(token)
        self._sub_tokens = []

    def join(self, timeout: float = 5.0) -> None:
        for t in self._threads:
            t.join(timeout)

    @property
    def alive(self) -> bool:
        return not self._stop.is_set()

    # ----------------------------------------------------------- main loop
    def _main(self) -> None:
        try:
            self._main_loop()
        finally:
            # every exit path — retire, cancel, hardened-FAILED, crash —
            # drops the store subscriptions, or dead agents' callbacks
            # would tax every future mutation and pin the agent in memory
            self._unsubscribe()

    def _main_loop(self) -> None:
        store, pilot = self.ctx.store, self.pilot
        # Simulated batch-queue wait (T_Q_pilot).
        self.ctx.sleep_sim(pilot.description.queue_time_s)
        if self._stop.is_set():
            return
        store.hset(f"pilot:{pilot.id}", "state", PilotState.ACTIVE)
        store.hset(f"pilot:{pilot.id}", "activated_at", time.monotonic())
        # Resource info push (used by the manager for placement decisions).
        store.hset(
            f"pilot:{pilot.id}",
            "resource_info",
            {
                "slots": pilot.description.slots,
                "affinity": pilot.affinity,
                "sandbox_pd": pilot.sandbox.id,
            },
        )
        self._started_at = time.monotonic()
        self._heartbeat()  # liveness visible the instant we turn ACTIVE
        queues = [pilot.queue_name, GLOBAL_QUEUE]
        while not self._stop.is_set():
            self._heartbeat()
            if self._walltime_exceeded():
                self._retire()
                return
            # reviewed: these caches are refreshed by subscriber callbacks;
            # a stale read here only delays retirement by one loop tick —
            # the poll re-reads next iteration, and a flush_events() per
            # tick would serialize the agent loop on the dispatcher
            own_state = self._own_state()  # pdlint: disable=PD-L004
            # pdlint: disable=PD-L004
            if own_state == PilotState.FAILED and self._sandbox_failed():
                # The monitor hardened us to FAILED (we stalled past the
                # threshold) AND the FaultManager purged our sandbox — our
                # replicas can never register again.  FAILED is terminal,
                # so stop claiming; in-flight workers decline their wins.
                # (Standalone-monitor mode never fails the sandbox: there a
                # falsely-failed-but-alive agent keeps working — its
                # replicas still register and the winner CAS dedups
                # against the re-queued copy.  That also means a CU popped
                # in the ms-wide FAILED→purge window is a deliberate
                # tradeoff: it is declined and handed back once the purge
                # lands, costing at most one store-side attempt — whereas
                # gating on FAILED alone would deadlock single-pilot
                # standalone deployments on a monitor false positive.)
                self._drop_heartbeat()  # we re-wrote it above; retract
                return
            if own_state == PilotState.SUSPECT:
                # Grace period: the monitor flagged us SUSPECT (missed
                # heartbeats).  Drain in-flight CUs but claim nothing new —
                # recovery must not race a half-alive pilot.  The heartbeat
                # we just wrote flips us back to ACTIVE if we're merely slow.
                time.sleep(max(self.ctx.poll_s, 0.01))
                continue
            if not self._slots.acquire(timeout=0.02):
                continue
            try:
                item = store.pop_any(queues, timeout=self.ctx.poll_s)
            except Exception:
                self._slots.release()
                time.sleep(0.02)
                continue
            if item is None:
                self._slots.release()
                continue
            # Post-pop re-check against the STORE, not the event cache: a
            # SUSPECT hset that happened-before this claim's push is then
            # guaranteed visible here even if its notification hasn't been
            # dispatched yet.  One store read per successful claim — the
            # per-iteration checks above stay memory reads.
            authoritative = store.hget(f"pilot:{pilot.id}", "state")
            if authoritative == PilotState.SUSPECT or self._sandbox_failed():
                # SUSPECT (or a recovery purge) landed while we were
                # blocked in the pop: hand the item back instead of racing
                # recovery with a fresh claim
                store.push(GLOBAL_QUEUE, item)
                self._slots.release()
                time.sleep(max(self.ctx.poll_s, 0.01))
                continue
            cu_id = item["cu"] if isinstance(item, dict) else item
            is_dup = isinstance(item, dict) and item.get("dup", False)
            try:
                cu: ComputeUnit = self.ctx.lookup(cu_id)
            except KeyError:
                self._slots.release()
                continue
            # Affinity constraint check: a CU pulled from the global queue
            # may not be runnable here — push it back (step 4 fallthrough).
            constraint = cu.description.affinity
            if constraint and not match_affinity(constraint, pilot.affinity):
                store.push(GLOBAL_QUEUE, item)
                self._slots.release()
                time.sleep(0.01)
                continue
            if not is_dup and not cu._cas_state(CUState.PENDING, CUState.STAGING):
                # canceled or already claimed elsewhere
                self._slots.release()
                continue
            worker = threading.Thread(
                target=self._run_cu,
                args=(cu, is_dup),
                name=f"worker-{pilot.id}-{cu.id}",
                daemon=True,
            )
            self._threads.append(worker)
            worker.start()
        if not self._dead.is_set():
            store.hset(f"pilot:{pilot.id}", "state", PilotState.DONE)
            self._drop_heartbeat()

    def _heartbeat(self) -> None:
        if self._dead.is_set():
            return
        try:
            self.ctx.store.hset(
                HEARTBEATS_KEY, self.pilot.id, time.monotonic()
            )
            with self._lock:
                self.ctx.store.hset(
                    f"pilot:{self.pilot.id}", "running", sorted(self._running)
                )
        except Exception:
            pass  # transient store outage: agents survive (§4.2)

    def _own_state(self) -> Optional[str]:
        return self._own_state_cache

    def _sandbox_failed(self) -> bool:
        """True once fault recovery purged this pilot's sandbox PD — the
        point of no return: replicas written here can never register."""
        return self._sandbox_failed_flag

    def _drop_heartbeat(self) -> None:
        """Remove this pilot's heartbeat entry on orderly shutdown so the
        shared hash (the monitor's single per-tick scan) doesn't grow with
        historical pilot churn."""
        try:
            self.ctx.store.hdel(HEARTBEATS_KEY, self.pilot.id)
        except Exception:
            pass

    def _walltime_exceeded(self) -> bool:
        wt = self.pilot.description.walltime_s
        return (
            self._started_at is not None
            and time.monotonic() - self._started_at > wt
        )

    def _retire(self) -> None:
        """Walltime reached: requeue claimed-but-unfinished CUs, shut down."""
        store = self.ctx.store
        with self._lock:
            running = sorted(self._running)
        for cu_id in running:
            cu = self.ctx.lookup(cu_id)
            if store.hget(f"cu:{cu.id}", "winner") is None:
                cu._set_state(CUState.PENDING)
                store.push(GLOBAL_QUEUE, {"cu": cu.id, "dup": False})
        store.hset(f"pilot:{self.pilot.id}", "state", PilotState.DONE)
        self._drop_heartbeat()

    # -------------------------------------------------------- CU execution
    def _run_cu(self, cu: ComputeUnit, is_dup: bool) -> None:
        store, pilot, ctx = self.ctx.store, self.pilot, self.ctx
        desc = cu.description
        tm = ctx.tier_manager
        cu_ctx: Optional[CUContext] = None
        try:
            with self._lock:
                self._running[cu.id] = time.monotonic()
            if tm is not None:
                # pin inputs for the attempt (idempotent — submission
                # already pinned them): quota eviction must never drop a
                # Staging/Running CU's input chunks from under it
                tm.pins.pin_inputs(cu)
            store.hset(f"cu:{cu.id}", "pilot", pilot.id)
            cu.timings.stage_start = time.monotonic()
            # ---- stage inputs (pull-mode data management, §4.2) ----
            sim_stage = 0.0
            try:
                for du_id in desc.input_data:
                    du: DataUnit = ctx.lookup(du_id)
                    sim_stage += ctx.transfer_service.stage_in(
                        du, pilot.sandbox, pilot.affinity,
                        use_cache=desc.cache_inputs,
                    )
            except QuotaExceeded:
                # Sandbox full and eviction blocked — typically by ANOTHER
                # live consumer's pinned inputs.  That is backpressure,
                # not a failure: hand the CU back (its own pins unbind in
                # Pending, freeing the bytes) and retry once the holder
                # drains, without burning a retry attempt.  The store-side
                # wait counter bounds livelock: past the cap it falls
                # through to the normal failure/retry path.
                if is_dup:
                    return
                waits = int(store.hget(f"cu:{cu.id}", "quota_waits", 0)) + 1
                store.hset(f"cu:{cu.id}", "quota_waits", waits)
                if waits <= MAX_QUOTA_WAITS and not self._dead.is_set():
                    if cu._cas_state(CUState.STAGING, CUState.PENDING):
                        time.sleep(max(self.ctx.poll_s, 0.01))  # pace
                        admission = getattr(ctx, "admission", None)
                        if admission is not None:
                            # re-enter tenant admission: a tenant whose
                            # own resident bytes caused the pressure
                            # parks there instead of hot-looping through
                            # the global queue (starvation valve); every
                            # other case pushes back to the global queue
                            # exactly as before
                            admission.requeue(cu)
                        else:
                            store.push(
                                GLOBAL_QUEUE, {"cu": cu.id, "dup": False}
                            )
                        return
                raise
            cu.timings.stage_end = time.monotonic()
            cu.timings.sim_stage_s = sim_stage
            cu.timings.sim_prefetch_s = (
                store.hget(f"cu:{cu.id}", "sim_prefetch_s", 0.0) or 0.0
            )
            store.hset(f"cu:{cu.id}", "sim_stage_s", sim_stage)
            if not is_dup:
                cu._cas_state(CUState.STAGING, CUState.RUNNING)
            # ---- execute ----
            cu.timings.run_start = time.monotonic()
            fn = FUNCTIONS.resolve(desc.executable)
            cu_ctx = CUContext(cu, pilot, ctx)
            result = fn(cu_ctx, *desc.args, **desc.kwargs)
            if cu_ctx.lost_stream():
                # a live foreign attempt owns one of our output streams —
                # its chunks are already published; decline the win and let
                # that attempt complete (exactly-once for streamed bytes)
                return
            ctx.sleep_sim(desc.sim_compute_s)
            cu.timings.sim_compute_s = desc.sim_compute_s
            cu.timings.run_end = time.monotonic()
            if self._dead.is_set():
                return  # node died mid-flight: results are lost
            # reviewed: stale cache only delays the decline — the winner
            # CAS below still dedups against the re-queued attempt, so no
            # barrier is needed on this advisory check
            if self._sandbox_failed():  # pdlint: disable=PD-L004
                # The monitor declared us dead (false positive: we were
                # merely stalled) and recovery purged our sandbox.
                # Claiming the win now would seal output DUs whose
                # replicas the FAILED sandbox can no longer register —
                # silent data loss.  Decline, and if orphan recovery's
                # one-shot requeue ran BEFORE we claimed (so it missed
                # this CU), hand it back ourselves — otherwise it would
                # sit in STAGING/RUNNING with no winner forever.  Only if
                # the claim is still OURS (nobody re-claimed after a
                # recovery requeue) — else we'd flip another agent's
                # in-flight attempt back to PENDING.
                if (
                    not is_dup
                    and store.hget(f"cu:{cu.id}", "pilot") == pilot.id
                ):
                    for st in (CUState.STAGING, CUState.RUNNING):
                        if cu._cas_state(st, CUState.PENDING):
                            store.push(
                                GLOBAL_QUEUE, {"cu": cu.id, "dup": False}
                            )
                            break
                return
            # ---- exactly-once completion (first finisher wins) ----
            if not store.hcas(f"cu:{cu.id}", "winner", None, pilot.id):
                return  # a duplicate finished first; discard its buffers
            cu.result = result
            # ---- stage outputs: flush the winning attempt's buffered
            # writes, then seal output DUs into the sandbox PD.  Only the
            # winner ever writes/seals — a FAILED attempt or losing
            # duplicate leaves output DUs untouched and unsealed. ----
            cu_ctx.flush_outputs()
            for du_id in desc.output_data:
                du: DataUnit = ctx.lookup(du_id)
                if not pilot.sandbox.has_du(du.id):
                    # streaming DUs only pay for the not-yet-flushed tail
                    # here (put_du skips chunks the sandbox already holds)
                    ctx.transfer_service.ingest(du, pilot.sandbox)
                du.seal()
                if du.streaming:
                    # end-of-stream: the writer claim has served its
                    # purpose (the seal froze the content)
                    store.hdel(f"du:{du.id}", "stream_writer")
            store.hset(f"cu:{cu.id}", "state", CUState.DONE)
            store.hset(
                f"cu:{cu.id}",
                "timings",
                {
                    "t_q_task": cu.timings.t_q_task,
                    "t_s": cu.timings.t_s,
                    "t_c": cu.timings.t_c,
                    "sim_stage_s": cu.timings.sim_stage_s,
                    "sim_compute_s": cu.timings.sim_compute_s,
                    "sim_prefetch_s": cu.timings.sim_prefetch_s,
                },
            )
        except Exception as exc:  # noqa: BLE001 — CU failures are data
            cu.error = f"{type(exc).__name__}: {exc}"
            store.hset(f"cu:{cu.id}", "error", cu.error)
            store.hset(f"cu:{cu.id}", "traceback", traceback.format_exc())
            # the store-side counter is authoritative: orphan recovery may
            # have burned attempts while no live handle was reachable
            cu.attempts = (
                max(cu.attempts, int(store.hget(f"cu:{cu.id}", "attempts", 0)))
                + 1
            )
            store.hset(f"cu:{cu.id}", "attempts", cu.attempts)
            if cu_ctx is not None:
                # a failed attempt must leave ZERO published chunks behind:
                # roll back any streaming output this attempt was writing
                # before the retry (or the terminal failure) proceeds
                try:
                    cu_ctx.abort_stream()
                except Exception:
                    pass
            if cu.attempts <= desc.max_retries and not self._dead.is_set():
                # retry with backoff via the global queue (the failed
                # attempt's buffered output writes were discarded, so the
                # retry starts against clean output DUs)
                cu._set_state(CUState.PENDING)
                store.push(GLOBAL_QUEUE, {"cu": cu.id, "dup": False})
            else:
                cu._set_state(CUState.FAILED)
                # terminal: outputs will never materialize — fail them so
                # dataflow waiters downstream are released with the cause
                cu._fail_outputs(f"producer {cu.url} failed: {cu.error}")
        finally:
            with self._lock:
                self._running.pop(cu.id, None)
            if tm is not None and cu.state in CUState.TERMINAL:
                # terminal attempts release the inputs for eviction;
                # requeued/declined attempts keep the pin until a later
                # attempt settles (the registry also self-heals lazily)
                tm.pins.unpin_owner(cu.id)
            self._slots.release()
