"""Pilot-Data core: the paper's abstractions as a composable library.

Import surface mirrors the Pilot-API (§4.3): descriptions + services +
manager.  This package deliberately does NOT import jax — launchers must be
able to set XLA flags before jax initializes.
"""

from .affinity import Topology, make_grid_topology, make_tpu_fleet_topology, match_affinity
from .compute_unit import (
    ComputeUnit,
    ComputeUnitDescription,
    CUState,
    FUNCTIONS,
    FunctionRegistry,
)
from .coordination import (
    CoordinationStore,
    CoordinationUnavailable,
    StoreEvent,
    with_retry,
)
from .cost_model import (
    PlacementChoice,
    cheapest_replica,
    choose_replication_degree,
    decide_placement,
    estimate_td,
    estimate_tr_group,
    estimate_tr_sequential,
    estimate_ts,
    estimate_tx,
    straggler_threshold,
)
from .data_unit import (
    ChunkInfo,
    DEFAULT_CHUNK_SIZE,
    DataUnit,
    DataUnitDescription,
    DUState,
    merge_dus,
    partition_du,
)
from .faults import (
    HeartbeatMonitor,
    StragglerMitigator,
    fail_cu_terminal,
    requeue_orphans,
)
from .futures import (
    ComputeFailedError,
    CUFuture,
    DataUnitFailedError,
    DUFuture,
    FutureError,
    FutureTimeoutError,
    gather,
)
from .manager import PilotManager
from .placement import (
    Candidate,
    PlacementEngine,
    PlacementStrategy,
    list_strategies,
    make_strategy,
    register_strategy,
)
from .pilot import (
    PilotCompute,
    PilotComputeDescription,
    PilotData,
    PilotDataDescription,
    PilotState,
    QuotaExceeded,
    RuntimeContext,
)
from .recovery import FaultManager, ReplicaManager
from .replication import (
    DemandReplicator,
    replicate_group,
    replicate_sequential,
    select_heal_targets,
)
from .scheduler import AsyncScheduler, SchedulerEvent
from .services import (
    AdmissionController,
    ComputeDataService,
    DependencyTracker,
    PilotComputeService,
    PilotDataService,
)
from .session import Session
from .tenancy import (
    DEFAULT_TENANT,
    ResourceQuota,
    Tenant,
    TenantRegistry,
)
from .tiering import (
    EvictionPolicy,
    PinRegistry,
    TIERS,
    TierManager,
    Victim,
    classify_tier,
    list_eviction_policies,
    make_eviction_policy,
    register_eviction_policy,
    tier_rank,
)
from .transfer import TransferRecord, TransferService

__all__ = [
    "Topology", "make_grid_topology", "make_tpu_fleet_topology", "match_affinity",
    "ComputeUnit", "ComputeUnitDescription", "CUState", "FUNCTIONS", "FunctionRegistry",
    "CoordinationStore", "CoordinationUnavailable", "StoreEvent", "with_retry",
    "AsyncScheduler", "SchedulerEvent",
    "Candidate", "PlacementEngine", "PlacementStrategy",
    "list_strategies", "make_strategy", "register_strategy",
    "PlacementChoice", "cheapest_replica", "choose_replication_degree",
    "decide_placement", "estimate_td", "estimate_tr_group", "estimate_tr_sequential",
    "estimate_ts", "estimate_tx", "straggler_threshold",
    "ChunkInfo", "DEFAULT_CHUNK_SIZE",
    "DataUnit", "DataUnitDescription", "DUState", "merge_dus", "partition_du",
    "HeartbeatMonitor", "StragglerMitigator", "requeue_orphans",
    "fail_cu_terminal", "FaultManager", "ReplicaManager", "select_heal_targets",
    "PilotManager",
    "PilotCompute", "PilotComputeDescription", "PilotData", "PilotDataDescription",
    "PilotState", "QuotaExceeded", "RuntimeContext",
    "DemandReplicator", "replicate_group", "replicate_sequential",
    "AdmissionController", "ComputeDataService", "DependencyTracker",
    "PilotComputeService", "PilotDataService",
    "DEFAULT_TENANT", "ResourceQuota", "Tenant", "TenantRegistry",
    "Session", "CUFuture", "DUFuture", "gather",
    "FutureError", "FutureTimeoutError",
    "ComputeFailedError", "DataUnitFailedError",
    "TransferRecord", "TransferService",
    "EvictionPolicy", "PinRegistry", "TIERS", "TierManager", "Victim",
    "classify_tier", "list_eviction_policies", "make_eviction_policy",
    "register_eviction_policy", "tier_rank",
]
