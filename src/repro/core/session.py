"""Session: the declarative Pilot-API v2 facade.

The paper's API couples compute and data declaratively — a CU names its
input and output DUs and the runtime guarantees materialization order
(§4.2–4.3, Fig. 5).  :class:`Session` is that contract as the user-facing
surface: ``submit_cu`` accepts inline :class:`DataUnitDescription`s (or
existing DUs / futures) for ``input_data``/``output_data``, auto-creates
output DUs, and returns a :class:`CUFuture` whose :class:`DUFuture`
``outputs`` chain straight into downstream CUs — so a whole DAG
(map → shuffle → reduce, iterative ensembles) is submitted upfront in one
shot, wired by object instead of by id string:

    with Session(topology=topo) as s:
        s.start_pilot(resource_url="sim://cluster:pod0")
        part = s.submit_du(name="part", files={"x": b"..."})
        m = s.submit_cu(executable="map", input_data=[part],
                        output_data=[DataUnitDescription(name="inter")])
        r = s.submit_cu(executable="reduce", input_data=[m.output],
                        output_data=[DataUnitDescription(name="out")])
        print(r.result())          # no user-side waits between stages

Ordering is enforced by the runtime's DU-readiness gate (a consumer parks
in ``Waiting`` until every input DU is sealed/first-replicated), not by
the caller; under ``scheduler_mode="async"`` the release additionally
triggers the prefetch pipeline, overlapping stage *i+1*'s stage-in with
stage *i*'s execution.

The v1 surface (``PilotManager.submit_du/submit_cu`` with raw id strings)
remains as thin deprecated shims.
"""

from __future__ import annotations

import warnings
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

from .compute_unit import ComputeUnitDescription
from .data_unit import DataUnit, DataUnitDescription
from .futures import CUFuture, DUFuture, FutureDispatcher, gather
from .pilot import PilotCompute, PilotData
from .tenancy import DEFAULT_TENANT, ResourceQuota

#: anything submit_cu accepts as a data reference
DataRef = Union[str, DataUnit, DUFuture, DataUnitDescription]


class Session:
    """One attached Pilot-API v2 client: a facade over a PilotManager.

    Construct standalone (``Session(topology=...)`` forwards every kwarg to
    :class:`~repro.core.manager.PilotManager`) or attach to an existing
    manager (``Session(manager=mgr)`` / ``mgr.session``).  A standalone
    session owns its manager and shuts it down on ``close()``/context exit;
    an attached session leaves the manager running.

    A session is also the unit of *tenancy*: ``tenant=`` names the owner,
    ``priority=`` / ``quota=`` register its QoS class with the manager's
    :class:`~repro.core.tenancy.TenantRegistry`.  Every DU/CU submitted
    through this session is stamped with the session tenant (unless the
    description already names one), which is what admission control,
    fair-share placement and tenant-aware eviction key on.  Single-tenant
    callers need zero changes: the default tenant keeps the exact pre-QoS
    behavior.
    """

    def __init__(
        self,
        manager: Optional[Any] = None,
        *,
        tenant: str = DEFAULT_TENANT,
        priority: int = 0,
        quota: Optional[ResourceQuota] = None,
        **manager_kwargs: Any,
    ):
        if manager is not None and manager_kwargs:
            raise ValueError("pass either manager= or manager kwargs, not both")
        if manager is None:
            from .manager import PilotManager  # local import: cycle

            manager = PilotManager(**manager_kwargs)
            self._owns_manager = True
        else:
            self._owns_manager = False
        self.manager = manager
        self.tenant = tenant
        if tenant != DEFAULT_TENANT or priority != 0 or quota is not None:
            # re-registering the same tenant name updates its QoS class
            # (latest wins) — two sessions may share one tenant
            manager.cds.admission.registry.register(
                tenant, priority=priority, quota=quota
            )
        self._dispatcher = FutureDispatcher(manager.store)
        self._closed = False
        manager._attach_session(self)

    # ----------------------------------------------------------- delegation
    @property
    def ctx(self):
        return self.manager.ctx

    @property
    def cds(self):
        return self.manager.cds

    @property
    def store(self):
        return self.manager.store

    @property
    def topology(self):
        return self.manager.topology

    @property
    def transfer(self):
        return self.manager.transfer

    @property
    def scheduler(self):
        return self.manager.scheduler

    @property
    def heartbeat_monitor(self):
        return self.manager.heartbeat_monitor

    @property
    def straggler_mitigator(self):
        return self.manager.straggler_mitigator

    @property
    def fault_manager(self):
        """The self-healing pipeline (``enable_fault_manager=True``):
        replica purge on pilot death, replication-factor enforcement,
        lineage recomputation.  None when not enabled."""
        return self.manager.fault_manager

    @property
    def tier_manager(self):
        """The storage-hierarchy layer: tier classification, access
        stats, quota-driven eviction, and mem-tier cache promotion."""
        return self.manager.tier_manager

    def recovering_dus(self) -> List[str]:
        """DU ids currently being rebuilt after total replica loss
        (state ``Recovering``); empty when the data layer is healthy."""
        from .recovery import recovering_dus

        return recovering_dus(self.store)

    def start_pilot(self, **kw) -> PilotCompute:
        return self.manager.start_pilot(**kw)

    def start_pilot_data(self, **kw) -> PilotData:
        return self.manager.start_pilot_data(**kw)

    def register_function(self, name: str, fn=None):
        return self.manager.register_function(name, fn)

    def cu_states(self) -> Dict[str, str]:
        return self.manager.cu_states()

    def pilot_states(self) -> Dict[str, str]:
        return self.manager.pilot_states()

    def decisions(self) -> List[Dict]:
        return self.cds.decisions()

    # ----------------------------------------------------------------- data
    def _stamp_tenant(self, desc: Any) -> Any:
        """Stamp the session tenant onto a DU/CU description in place.

        A description that already names a non-default tenant wins — it
        was set deliberately (e.g. submitting on another tenant's behalf).
        """
        if desc.tenant == DEFAULT_TENANT and self.tenant != DEFAULT_TENANT:
            desc.tenant = self.tenant
        return desc

    def submit_du(
        self,
        desc: Optional[DataUnitDescription] = None,
        *,
        target: Optional[PilotData] = None,
        **kw: Any,
    ) -> DUFuture:
        """Create a DU and stage it into an affinity-appropriate PD;
        returns a :class:`DUFuture` (typically already materialized, since
        first staging is synchronous)."""
        if desc is None:
            desc = DataUnitDescription(**kw)
        elif kw:
            raise ValueError("pass a description or kwargs, not both")
        du = self.cds.submit_data_unit(self._stamp_tenant(desc), target=target)
        return DUFuture(du, self.store, dispatcher=self._dispatcher)

    def create_du(
        self, desc: Optional[DataUnitDescription] = None, **kw: Any
    ) -> DUFuture:
        """Create an *empty placeholder* DU without staging it: a dataflow
        handle whose content a producer CU materializes later.  Consumers
        submitted against it park in ``Waiting`` until the producer seals
        it — this is how a consumer can be submitted before its producer."""
        if desc is None:
            desc = DataUnitDescription(**kw)
        elif kw:
            raise ValueError("pass a description or kwargs, not both")
        du = self.cds.create_data_unit(self._stamp_tenant(desc))
        return DUFuture(du, self.store, dispatcher=self._dispatcher)

    def create_streaming_du(
        self, desc: Optional[DataUnitDescription] = None, **kw: Any
    ) -> DUFuture:
        """Create an empty *streaming* placeholder DU: the producer CU
        publishes chunk prefixes incrementally (``CUContext.flush_output``)
        and consumers are released the moment ``ready_chunks`` chunks (or
        ``ready_fraction`` of the expected total, given a ``size_hint``)
        are published — before the producer seals."""
        if desc is None:
            kw.setdefault("streaming", True)
            desc = DataUnitDescription(**kw)
        elif kw:
            raise ValueError("pass a description or kwargs, not both")
        if not desc.streaming:
            raise ValueError("create_streaming_du needs streaming=True")
        du = self.cds.create_data_unit(self._stamp_tenant(desc))
        return DUFuture(du, self.store, dispatcher=self._dispatcher)

    # -------------------------------------------------------------- compute
    def _resolve_input(self, ref: DataRef) -> str:
        if isinstance(ref, DataUnitDescription):
            # inline input: create + stage it now, depend on the result
            return self.submit_du(ref).id
        return self._ref_id(ref, role="input")

    def _resolve_output(self, ref: DataRef) -> DUFuture:
        if isinstance(ref, DataUnitDescription):
            return self.create_du(ref)
        if isinstance(ref, DUFuture):
            return ref
        if isinstance(ref, DataUnit):
            return DUFuture(ref, self.store, dispatcher=self._dispatcher)
        du_id = self._ref_id(ref, role="output")
        return DUFuture(self._du_handle(du_id), self.store, dispatcher=self._dispatcher)

    def _ref_id(self, ref: DataRef, role: str) -> str:
        if isinstance(ref, (DataUnit, DUFuture)):
            return ref.id
        if isinstance(ref, str):
            warnings.warn(
                f"Pilot-API v1: raw DU id strings in {role}_data are "
                f"deprecated; pass the DataUnit/DUFuture object (or an "
                f"inline DataUnitDescription)",
                DeprecationWarning,
                stacklevel=4,
            )
            return ref
        raise TypeError(
            f"{role}_data entries must be DataUnit, DUFuture, "
            f"DataUnitDescription or id str, got {type(ref).__name__}"
        )

    def _du_handle(self, du_id: str) -> DataUnit:
        try:
            return self.ctx.lookup(du_id)
        except KeyError:
            # remote DU known only to the store: re-attach a handle
            return DataUnit(DataUnitDescription(), self.store, du_id=du_id)

    def submit_cu(
        self,
        desc: Optional[ComputeUnitDescription] = None,
        *,
        input_data: Sequence[DataRef] = (),
        output_data: Sequence[DataRef] = (),
        pilot: Optional[Union[str, PilotCompute]] = None,
        **kw: Any,
    ) -> CUFuture:
        """Submit a CU whose data dependencies are declared by object.

        ``input_data``/``output_data`` accept :class:`DataUnit`,
        :class:`DUFuture` (e.g. another CU's output), or an inline
        :class:`DataUnitDescription` (inputs are created+staged, outputs
        auto-created as placeholders).  Returns a :class:`CUFuture`; its
        ``outputs`` chain into downstream submissions, so an entire DAG can
        be submitted before any CU has run.
        """
        if desc is not None:
            if kw or input_data or output_data or pilot is not None:
                raise ValueError(
                    "pass a ComputeUnitDescription or kwargs, not both"
                )
            cu = self.cds.submit_compute_unit(self._stamp_tenant(desc))
            outs = [
                DUFuture(self._du_handle(i), self.store, dispatcher=self._dispatcher)
                for i in desc.output_data
            ]
            return CUFuture(cu, self.store, outputs=outs, dispatcher=self._dispatcher)
        out_futures = [self._resolve_output(o) for o in output_data]
        kw.setdefault("tenant", self.tenant)
        cud = ComputeUnitDescription(
            input_data=[self._resolve_input(i) for i in input_data],
            output_data=[o.id for o in out_futures],
            pilot=pilot.id if isinstance(pilot, PilotCompute) else pilot,
            **kw,
        )
        cu = self.cds.submit_compute_unit(cud)
        return CUFuture(
            cu, self.store, outputs=out_futures, dispatcher=self._dispatcher
        )

    # -------------------------------------------------------------- control
    def gather(self, futures: Iterable[Any], timeout: float = 120.0) -> List[Any]:
        return gather(futures, timeout=timeout)

    def wait(self, timeout: float = 120.0) -> bool:
        """Block until every submitted CU is terminal (event-driven)."""
        return self.cds.wait(timeout=timeout)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        # drain this session's future-dispatcher thread *before* the
        # manager (and ultimately the store's event dispatcher) can go
        # away — a dispatcher outliving the store deadlocks futures
        # waiting on events that will never be delivered
        self._dispatcher.stop()
        self.manager._detach_session(self)
        if self._owns_manager:
            self.manager.shutdown()

    # v1-compat spelling used all over the manager surface
    def shutdown(self) -> None:
        self.close()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"<Session mode={self.manager.scheduler_mode} "
            f"owns_manager={self._owns_manager} closed={self._closed}>"
        )
