"""Pluggable placement strategies + the shared candidate estimator.

The §6.1 calculus produces, for every (CU, pilot) pair, the two numbers the
paper trades off — expected queue wait T_Q and expected staging cost T_X.
*How those numbers turn into a placement* is policy, and this module makes
policy pluggable: a :class:`PlacementStrategy` ranks the candidate list,
and strategies register by name so schedulers (sync and async alike) and
benchmarks select them from one registry.

Both execution modes share :class:`PlacementEngine` for the estimates and a
strategy instance for the ranking, which is what guarantees the two modes
reproduce identical placement decisions for identical store state.

Built-in strategies (the five benchmarked in ``bench_placement``):

  * ``cost``        — minimize T_Q + T_X (the paper's §6.1 rule; default);
  * ``data-local``  — compute-to-data: minimize staging first, queue second;
  * ``queue-depth`` — load-balance on T_Q only (data-blind);
  * ``round-robin`` — deterministic rotation over pilots (baseline);
  * ``random``      — seeded uniform choice (baseline / tie-break probe).
"""

from __future__ import annotations

import abc
import dataclasses
import random
import threading
from typing import Callable, Dict, List, Optional, Sequence

from .affinity import match_affinity
from .compute_unit import ComputeUnit
from .pilot import PilotCompute, PilotState, RuntimeContext


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One (CU, pilot) pairing with its §6.1 cost terms."""

    pilot: PilotCompute
    t_queue: float
    t_stage: float
    #: fractional chunk locality: bytes of the CU's input chunks already
    #: present at the pilot (sandbox-cached or linkable) / total input
    #: bytes.  1.0 = fully local (or no inputs), 0.0 = everything remote.
    #: Partial replicas score partially — the chunk-granular replacement
    #: for the old boolean has-replica test.
    locality: float = 1.0

    @property
    def score(self) -> float:
        return self.t_queue + self.t_stage

    @property
    def strategy(self) -> str:
        """Which direction §6.1 says this pairing moves: data or compute."""
        return (
            "data-to-compute" if self.t_queue >= self.t_stage
            else "compute-to-data"
        )


class PlacementEngine:
    """Computes strategy-independent candidate costs for a CU.

    Estimates are the same math the sync scheduler has always used:
    T_Q from declared per-CU compute seconds of work already bound to the
    pilot, T_X as the cheapest-replica staging cost of each input DU (via
    the transfer service's replica-aware cache)."""

    def __init__(self, ctx: RuntimeContext, avg_cu_estimate_s: float = 0.05):
        self.ctx = ctx
        self.avg_cu_estimate_s = avg_cu_estimate_s

    def pilot_tq_estimate(self, pilot: PilotCompute) -> float:
        """Expected wait before ``pilot`` could start one more CU."""
        st = pilot.state
        if st not in PilotState.PLACEABLE:
            return float("inf")
        tq = 0.0
        if st == PilotState.PROVISIONING:
            tq += pilot.description.queue_time_s

        def cu_cost(cu_id: str) -> float:
            try:
                d = self.ctx.lookup(cu_id).description
                return max(
                    d.sim_compute_s, d.est_compute_s, self.avg_cu_estimate_s
                )
            except KeyError:
                return self.avg_cu_estimate_s

        pending = [
            item["cu"] if isinstance(item, dict) else item
            for item in self.ctx.store.qpeek(pilot.queue_name)
        ]
        running = pilot.running_cus()
        total = sum(cu_cost(c) for c in (*pending, *running))
        free = pilot.slots - len(running) - len(pending)
        if free <= 0:
            tq += total / max(1, pilot.slots)
        return max(tq, 0.0)

    def stage_estimate(self, cu: ComputeUnit, pilot: PilotCompute) -> float:
        """Σ over input DUs of the striped multi-source staging cost of the
        *missing chunks* to ``pilot`` (0 for sandbox cache hits and linkable
        full replicas; partial holdings only pay for the remainder)."""
        t_stage = 0.0
        ts = self.ctx.transfer_service
        for du_id in cu.description.input_data:
            du = self.ctx.lookup(du_id)
            if pilot.sandbox.has_du(du.id):
                continue  # pilot-level cache hit
            t_stage += ts.estimate_stage_cost(du, pilot.affinity, pilot.sandbox)
        return t_stage

    def chunk_locality(self, cu: ComputeUnit, pilot: PilotCompute) -> float:
        """Fraction of the CU's input bytes whose chunks are already at the
        pilot — in its sandbox or in any PD linkable from its location.
        A DU replicated halfway scores 0.5, not 0 (the chunk-granular
        upgrade of the old boolean ``has_du`` locality test)."""
        ts = self.ctx.transfer_service
        total = 0
        local = 0
        for du_id in cu.description.input_data:
            du = self.ctx.lookup(du_id)
            chunks = du.chunks
            total += du.size
            if not chunks:
                continue
            here = set(pilot.sandbox.chunks_held(du.id))
            for pd_id, idxs in du.chunk_holders().items():
                if pd_id == pilot.sandbox.id or pd_id not in self.ctx.objects:
                    continue
                pd = self.ctx.lookup(pd_id)
                if ts.is_linkable(pd, pilot.affinity):
                    here.update(idxs)
            local += sum(chunks[i].size for i in here if i < len(chunks))
        return 1.0 if total == 0 else local / total

    def candidates(
        self, cu: ComputeUnit, pilots: Sequence[PilotCompute]
    ) -> List[Candidate]:
        """All affinity-admissible, placeable pilots with their costs.
        Terminal pilots never qualify; neither do SUSPECT ones — a pilot
        in its missed-heartbeat grace period drains in-flight work but
        must not be handed anything new (it may be about to fail, and
        recovery would race the binding)."""
        constraint = cu.description.affinity
        out: List[Candidate] = []
        for p in pilots:
            if p.state not in PilotState.PLACEABLE:
                continue
            if constraint and not match_affinity(constraint, p.affinity):
                continue
            out.append(
                Candidate(
                    pilot=p,
                    t_queue=self.pilot_tq_estimate(p),
                    t_stage=self.stage_estimate(cu, p),
                    locality=self.chunk_locality(cu, p),
                )
            )
        return out


class PlacementStrategy(abc.ABC):
    """Ranks candidates best-first.  Implementations must be deterministic
    given their construction arguments and the submission order (stateful
    strategies like round-robin/random advance exactly once per ``rank``)."""

    #: registry key; subclasses override
    name: str = "?"

    @abc.abstractmethod
    def rank(
        self, cu: ComputeUnit, candidates: Sequence[Candidate]
    ) -> List[Candidate]:
        ...


_REGISTRY: Dict[str, Callable[..., PlacementStrategy]] = {}
_registry_lock = threading.Lock()


def register_strategy(name: str):
    """Class decorator: register a strategy factory under ``name``."""

    def deco(cls):
        cls.name = name
        with _registry_lock:
            _REGISTRY[name] = cls
        return cls

    return deco


def make_strategy(name: str, **kwargs) -> PlacementStrategy:
    with _registry_lock:
        if name not in _REGISTRY:
            raise KeyError(
                f"unknown placement strategy {name!r} "
                f"(registered: {sorted(_REGISTRY)})"
            )
        factory = _REGISTRY[name]
    return factory(**kwargs)


def list_strategies() -> List[str]:
    with _registry_lock:
        return sorted(_REGISTRY)


@register_strategy("cost")
class CostStrategy(PlacementStrategy):
    """§6.1: minimize T_Q + T_X; pilot id breaks ties deterministically."""

    def rank(self, cu, candidates):
        return sorted(candidates, key=lambda c: (c.score, c.pilot.id))


@register_strategy("data-local")
class DataLocalStrategy(PlacementStrategy):
    """Compute-to-data: fractional chunk locality dominates the ordering —
    the pilot already holding the most input bytes (partial replicas
    count pro rata) wins; residual staging cost and queue wait break
    ties."""

    def rank(self, cu, candidates):
        return sorted(
            candidates,
            key=lambda c: (-c.locality, c.t_stage, c.t_queue, c.pilot.id),
        )


@register_strategy("queue-depth")
class QueueDepthStrategy(PlacementStrategy):
    """Data-blind load balancing on expected queue wait."""

    def rank(self, cu, candidates):
        return sorted(
            candidates, key=lambda c: (c.t_queue, c.t_stage, c.pilot.id)
        )


@register_strategy("round-robin")
class RoundRobinStrategy(PlacementStrategy):
    """Rotate over pilots in id order; one advance per ranked CU."""

    def __init__(self) -> None:
        self._next = 0
        self._lock = threading.Lock()

    def rank(self, cu, candidates):
        if not candidates:
            return []
        ordered = sorted(candidates, key=lambda c: c.pilot.id)
        with self._lock:
            start = self._next % len(ordered)
            self._next += 1
        return ordered[start:] + ordered[:start]


@register_strategy("random")
class RandomStrategy(PlacementStrategy):
    """Seeded uniform choice — deterministic under a fixed seed and
    submission order."""

    def __init__(self, seed: int = 0) -> None:
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    def rank(self, cu, candidates):
        ordered = sorted(candidates, key=lambda c: c.pilot.id)
        with self._lock:
            self._rng.shuffle(ordered)
        return ordered
