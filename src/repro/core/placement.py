"""Pluggable placement strategies + the shared candidate estimator.

The §6.1 calculus produces, for every (CU, pilot) pair, the two numbers the
paper trades off — expected queue wait T_Q and expected staging cost T_X.
*How those numbers turn into a placement* is policy, and this module makes
policy pluggable: a :class:`PlacementStrategy` ranks the candidate list,
and strategies register by name so schedulers (sync and async alike) and
benchmarks select them from one registry.

Both execution modes share :class:`PlacementEngine` for the estimates and a
strategy instance for the ranking, which is what guarantees the two modes
reproduce identical placement decisions for identical store state.

Built-in strategies (the five benchmarked in ``bench_placement``):

  * ``cost``        — minimize T_Q + T_X (the paper's §6.1 rule; default);
  * ``data-local``  — compute-to-data: minimize staging first, queue second;
  * ``queue-depth`` — load-balance on T_Q only (data-blind);
  * ``round-robin`` — deterministic rotation over pilots (baseline);
  * ``random``      — seeded uniform choice (baseline / tie-break probe).
"""

from __future__ import annotations

import abc
import dataclasses
import random
import threading
from typing import Callable, Dict, List, Optional, Sequence

from .affinity import match_affinity
from .compute_unit import ComputeUnit
from .pilot import PilotCompute, PilotState, RuntimeContext


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One (CU, pilot) pairing with its §6.1 cost terms."""

    pilot: PilotCompute
    t_queue: float
    t_stage: float
    #: fractional chunk locality: bytes of the CU's input chunks already
    #: present at the pilot (sandbox-cached or linkable) / total input
    #: bytes.  1.0 = fully local (or no inputs), 0.0 = everything remote.
    #: Partial replicas score partially — the chunk-granular replacement
    #: for the old boolean has-replica test.
    locality: float = 1.0
    #: effective tier bandwidth (bytes/s): the bytes-weighted backend
    #: bandwidth of the best *local* holder of each input chunk — a DU
    #: cached in a DRAM-tier PD at the pilot scores ~20 GB/s where the
    #: same bytes on a site-shared parallel FS score ~4 GB/s and a chunk
    #: with no local copy scores 0.  Locality says how MUCH is local;
    #: this says how FAST the local copy serves.
    tier_bw: float = 0.0

    @property
    def score(self) -> float:
        return self.t_queue + self.t_stage

    @property
    def strategy(self) -> str:
        """Which direction §6.1 says this pairing moves: data or compute."""
        return (
            "data-to-compute" if self.t_queue >= self.t_stage
            else "compute-to-data"
        )


class PlacementEngine:
    """Computes strategy-independent candidate costs for a CU.

    Estimates are the same math the sync scheduler has always used:
    T_Q from declared per-CU compute seconds of work already bound to the
    pilot, T_X as the cheapest-replica staging cost of each input DU (via
    the transfer service's replica-aware cache)."""

    def __init__(self, ctx: RuntimeContext, avg_cu_estimate_s: float = 0.05):
        self.ctx = ctx
        self.avg_cu_estimate_s = avg_cu_estimate_s

    def pilot_tq_estimate(self, pilot: PilotCompute) -> float:
        """Expected wait before ``pilot`` could start one more CU."""
        st = pilot.state
        if st not in PilotState.PLACEABLE:
            return float("inf")
        tq = 0.0
        if st == PilotState.PROVISIONING:
            tq += pilot.description.queue_time_s

        def cu_cost(cu_id: str) -> float:
            try:
                d = self.ctx.lookup(cu_id).description
                return max(
                    d.sim_compute_s, d.est_compute_s, self.avg_cu_estimate_s
                )
            except KeyError:
                return self.avg_cu_estimate_s

        pending = [
            item["cu"] if isinstance(item, dict) else item
            for item in self.ctx.store.qpeek(pilot.queue_name)
        ]
        running = pilot.running_cus()
        total = sum(cu_cost(c) for c in (*pending, *running))
        free = pilot.slots - len(running) - len(pending)
        if free <= 0:
            tq += total / max(1, pilot.slots)
        return max(tq, 0.0)

    def stage_estimate(self, cu: ComputeUnit, pilot: PilotCompute) -> float:
        """Σ over input DUs of the striped multi-source staging cost of the
        *missing chunks* to ``pilot`` (0 for sandbox cache hits and linkable
        full replicas; partial holdings only pay for the remainder)."""
        t_stage = 0.0
        ts = self.ctx.transfer_service
        tenant = getattr(cu.description, "tenant", None)
        for du_id in cu.description.input_data:
            du = self.ctx.lookup(du_id)
            if pilot.sandbox.has_du(du.id):
                continue  # pilot-level cache hit
            t_stage += ts.estimate_stage_cost(
                du, pilot.affinity, pilot.sandbox, tenant=tenant
            )
        return t_stage

    def _chunk_presence(self, cu: ComputeUnit, pilot: PilotCompute) -> tuple:
        """ONE scan over the CU's input chunk holders at ``pilot``,
        returning ``(locality, tier_bw)`` — the locality fraction and the
        bytes-weighted bandwidth of each chunk's best local holder.  The
        two scores share the per-holder store reads (``chunk_holders`` +
        linkability), which would otherwise be paid twice per candidate
        on the placement hot path."""
        ts = self.ctx.transfer_service
        total = 0
        local = 0
        weighted = 0.0
        sandbox_bw = pilot.sandbox.backend.profile.bandwidth
        for du_id in cu.description.input_data:
            du = self.ctx.lookup(du_id)
            chunks = du.chunks
            total += du.size
            if not chunks:
                continue
            best: Dict[int, float] = {
                i: sandbox_bw for i in pilot.sandbox.chunks_held(du.id)
            }
            for pd_id, idxs in du.chunk_holders().items():
                if pd_id == pilot.sandbox.id or pd_id not in self.ctx.objects:
                    continue
                pd = self.ctx.lookup(pd_id)
                if not ts.is_linkable(pd, pilot.affinity):
                    continue
                bw = pd.backend.profile.bandwidth
                for i in idxs:
                    if bw > best.get(i, 0.0):
                        best[i] = bw
            for i, bw in best.items():
                if i < len(chunks):
                    local += chunks[i].size
                    weighted += chunks[i].size * bw
        if total == 0:
            return 1.0, 0.0
        return local / total, weighted / total

    def chunk_locality(self, cu: ComputeUnit, pilot: PilotCompute) -> float:
        """Fraction of the CU's input bytes whose chunks are already at the
        pilot — in its sandbox or in any PD linkable from its location.
        A DU replicated halfway scores 0.5, not 0 (the chunk-granular
        upgrade of the old boolean ``has_du`` locality test)."""
        return self._chunk_presence(cu, pilot)[0]

    def tier_bandwidth(self, cu: ComputeUnit, pilot: PilotCompute) -> float:
        """Bytes-weighted effective bandwidth of the CU's input chunks at
        ``pilot``: each chunk contributes its best local (sandbox or
        linkable) holder's backend bandwidth, chunks with no local copy
        contribute 0.  Distinguishes two fully-local candidates whose
        replicas live in different storage tiers."""
        return self._chunk_presence(cu, pilot)[1]

    def candidates(
        self,
        cu: ComputeUnit,
        pilots: Sequence[PilotCompute],
        tier_bw: bool = False,
    ) -> List[Candidate]:
        """All affinity-admissible, placeable pilots with their costs.
        Terminal pilots never qualify; neither do SUSPECT ones — a pilot
        in its missed-heartbeat grace period drains in-flight work but
        must not be handed anything new (it may be about to fail, and
        recovery would race the binding).

        ``tier_bw`` additionally scores each candidate's effective tier
        bandwidth — an extra O(chunks × holders) scan per pilot, so it is
        computed only for strategies that declare ``uses_tier_bw``."""
        constraint = cu.description.affinity
        out: List[Candidate] = []
        for p in pilots:
            if p.state not in PilotState.PLACEABLE:
                continue
            if constraint and not match_affinity(constraint, p.affinity):
                continue
            locality, bw = self._chunk_presence(cu, p)
            out.append(
                Candidate(
                    pilot=p,
                    t_queue=self.pilot_tq_estimate(p),
                    t_stage=self.stage_estimate(cu, p),
                    locality=locality,
                    tier_bw=bw if tier_bw else 0.0,
                )
            )
        return out


class PlacementStrategy(abc.ABC):
    """Ranks candidates best-first.  Implementations must be deterministic
    given their construction arguments and the submission order (stateful
    strategies like round-robin/random advance exactly once per ``rank``)."""

    #: registry key; subclasses override
    name: str = "?"
    #: strategies that rank on Candidate.tier_bw set this True so the
    #: engine computes it (it costs an extra per-chunk holder scan)
    uses_tier_bw: bool = False
    #: runtime context, attached by :meth:`bind` — tenant-aware
    #: strategies read the TenantRegistry and queue state through it
    ctx: Optional[RuntimeContext] = None

    def bind(self, ctx: RuntimeContext) -> None:
        """Attach the runtime context (called once by the CDS).  The base
        implementation just stores it; cost-only strategies ignore it."""
        self.ctx = ctx

    @abc.abstractmethod
    def rank(
        self, cu: ComputeUnit, candidates: Sequence[Candidate]
    ) -> List[Candidate]:
        ...


_REGISTRY: Dict[str, Callable[..., PlacementStrategy]] = {}
_registry_lock = threading.Lock()


def register_strategy(name: str):
    """Class decorator: register a strategy factory under ``name``."""

    def deco(cls):
        cls.name = name
        with _registry_lock:
            _REGISTRY[name] = cls
        return cls

    return deco


def make_strategy(name: str, **kwargs) -> PlacementStrategy:
    with _registry_lock:
        if name not in _REGISTRY:
            raise KeyError(
                f"unknown placement strategy {name!r} "
                f"(registered: {sorted(_REGISTRY)})"
            )
        factory = _REGISTRY[name]
    return factory(**kwargs)


def list_strategies() -> List[str]:
    with _registry_lock:
        return sorted(_REGISTRY)


@register_strategy("cost")
class CostStrategy(PlacementStrategy):
    """§6.1: minimize T_Q + T_X; pilot id breaks ties deterministically."""

    def rank(self, cu, candidates):
        return sorted(candidates, key=lambda c: (c.score, c.pilot.id))


@register_strategy("data-local")
class DataLocalStrategy(PlacementStrategy):
    """Compute-to-data: fractional chunk locality dominates the ordering —
    the pilot already holding the most input bytes (partial replicas
    count pro rata) wins; among equally-local candidates the one whose
    replicas sit in the *faster storage tier* (effective tier bandwidth)
    ranks first; residual staging cost and queue wait break ties."""

    uses_tier_bw = True

    def rank(self, cu, candidates):
        return sorted(
            candidates,
            key=lambda c: (
                -c.locality,
                -c.tier_bw,
                c.t_stage,
                c.t_queue,
                c.pilot.id,
            ),
        )


@register_strategy("queue-depth")
class QueueDepthStrategy(PlacementStrategy):
    """Data-blind load balancing on expected queue wait."""

    def rank(self, cu, candidates):
        return sorted(
            candidates, key=lambda c: (c.t_queue, c.t_stage, c.pilot.id)
        )


@register_strategy("round-robin")
class RoundRobinStrategy(PlacementStrategy):
    """Rotate over pilots in id order; one advance per ranked CU."""

    def __init__(self) -> None:
        self._next = 0
        self._lock = threading.Lock()

    def rank(self, cu, candidates):
        if not candidates:
            return []
        ordered = sorted(candidates, key=lambda c: c.pilot.id)
        with self._lock:
            start = self._next % len(ordered)
            self._next += 1
        return ordered[start:] + ordered[:start]


def _queued_cu_ids(store, queue_name: str) -> List[str]:
    return [
        item["cu"] if isinstance(item, dict) else item
        for item in store.qpeek(queue_name)
    ]


@register_strategy("weighted-fair-share")
class WeightedFairShareStrategy(PlacementStrategy):
    """Tenant-fair §6.1: cost plus a same-tenant backlog penalty.

    Each candidate's score is T_Q + T_X plus a penalty proportional to how
    many of the *submitting tenant's own* CUs already sit in that pilot's
    queue, divided by the tenant's fair-share weight.  A flooding tenant
    therefore spreads itself across pilots (its own backlog repels it)
    instead of monopolizing one queue after another, while a light tenant
    — with no backlog anywhere — ranks on pure cost and slips in front of
    the flood.  Weighted round-robin across tenants, emergent rather than
    scheduled: higher weight → smaller penalty → denser packing allowed.

    Degenerates to exactly the ``cost`` ordering when the registry is
    absent or every queued CU belongs to the submitting tenant's own
    single-tenant world."""

    def __init__(self, penalty_s: float = 0.05) -> None:
        #: seconds of score penalty per own-tenant queued CU at weight 1.0
        self.penalty_s = penalty_s

    def rank(self, cu, candidates):
        ctx = self.ctx
        registry = getattr(ctx, "tenant_registry", None) if ctx else None
        if registry is None:
            return sorted(candidates, key=lambda c: (c.score, c.pilot.id))
        tenant = getattr(cu.description, "tenant", None) or "default"
        weight = registry.weight(tenant)
        store = ctx.store

        def penalty(c: Candidate) -> float:
            own = 0
            for cu_id in _queued_cu_ids(store, c.pilot.queue_name):
                holder = store.hget(f"cu:{cu_id}", "tenant") or "default"
                if holder == tenant:
                    own += 1
            return own * self.penalty_s / weight

        return sorted(
            candidates, key=lambda c: (c.score + penalty(c), c.pilot.id)
        )


@register_strategy("priority")
class PriorityStrategy(PlacementStrategy):
    """Priority-discounted §6.1: queue wait counts only the work of
    tenants at equal-or-higher priority.  Lower-priority queued CUs are
    bypassable (the admission controller's queued-only preemption can
    displace them), so a high-priority CU ranks pilots as if that backlog
    were absent — it optimizes for where IT will start soonest, and the
    preemption step in ``ComputeDataService.place`` then makes the
    assumption real.  Ties (and the registry-less case) fall back to the
    plain cost ordering."""

    def __init__(self, avg_cu_estimate_s: float = 0.05) -> None:
        self.avg_cu_estimate_s = avg_cu_estimate_s

    def _cu_estimate(self, cu_id: str) -> float:
        try:
            d = self.ctx.lookup(cu_id).description
            return max(
                d.sim_compute_s, d.est_compute_s, self.avg_cu_estimate_s
            )
        except KeyError:
            return self.avg_cu_estimate_s

    def rank(self, cu, candidates):
        ctx = self.ctx
        registry = getattr(ctx, "tenant_registry", None) if ctx else None
        if registry is None:
            return sorted(candidates, key=lambda c: (c.score, c.pilot.id))
        tenant = getattr(cu.description, "tenant", None) or "default"
        my_pri = registry.get(tenant).priority
        store = ctx.store

        def discounted_tq(c: Candidate) -> float:
            tq = 0.0
            for cu_id in _queued_cu_ids(store, c.pilot.queue_name):
                holder = store.hget(f"cu:{cu_id}", "tenant") or "default"
                if registry.get(holder).priority >= my_pri:
                    tq += self._cu_estimate(cu_id)
            for cu_id in c.pilot.running_cus():
                # running work is never preemptible: it always counts
                tq += self._cu_estimate(cu_id)
            return tq / max(1, c.pilot.slots)

        return sorted(
            candidates,
            key=lambda c: (
                discounted_tq(c) + c.t_stage,
                c.score,
                c.pilot.id,
            ),
        )


@register_strategy("random")
class RandomStrategy(PlacementStrategy):
    """Seeded uniform choice — deterministic under a fixed seed and
    submission order."""

    def __init__(self, seed: int = 0) -> None:
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    def rank(self, cu, candidates):
        ordered = sorted(candidates, key=lambda c: c.pilot.id)
        with self._lock:
            self._rng.shuffle(ordered)
        return ordered
