"""Fault tolerance & straggler mitigation (paper §4.2 "Fault Tolerance" and
the §6.4 lessons).

The paper's design: all framework state lives in the coordination store, so
components can crash, reconnect and resume; transfers retry; and the
evaluation observed "failures due to high loads, wall time limits and file
transfer errors" plus heavy-tailed stragglers ("CUs started later on a
machine run longer", "the first resource must not be the best one").

This module supplies the *active* policies on top of that substrate:

  * :class:`HeartbeatMonitor` — detects dead pilots (missed heartbeats) and
    re-queues their claimed-but-unfinished CUs to the global queue;
  * :class:`StragglerMitigator` — duplicates long-running idempotent CUs
    onto other pilots; the exactly-once "winner" CAS in the agent makes the
    first finisher authoritative;
  * :func:`requeue_orphans` — the shared recovery primitive.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

from .agent import GLOBAL_QUEUE
from .compute_unit import CUState, ComputeUnit
from .cost_model import straggler_threshold
from .pilot import PilotCompute, PilotState, RuntimeContext


def requeue_orphans(ctx: RuntimeContext, pilot_id: str) -> List[str]:
    """Re-queue every CU the (dead) pilot had claimed but not won, AND
    drain its pilot-specific queue back to the global queue (queued-but-
    unclaimed work must not die with the pilot)."""
    store = ctx.store
    requeued = []
    # drain the dead pilot's queue
    while True:
        item = store.pop(f"queue:pilot:{pilot_id}", timeout=0.0)
        if item is None:
            break
        store.push(GLOBAL_QUEUE, item)
        cu_id = item["cu"] if isinstance(item, dict) else item
        requeued.append(cu_id)
    for key in store.hkeys("cu:"):
        cu_id = key.split(":", 1)[1]
        rec = store.hgetall(key)
        if rec.get("pilot") != pilot_id:
            continue
        if rec.get("state") in (CUState.STAGING, CUState.RUNNING) and (
            rec.get("winner") is None
        ):
            try:
                cu: ComputeUnit = ctx.lookup(cu_id)
                cu.attempts += 1
                if cu.attempts > cu.description.max_retries:
                    cu._set_state(CUState.FAILED)
                    continue
            except KeyError:
                pass
            store.hset(key, "state", CUState.PENDING)
            store.push(GLOBAL_QUEUE, {"cu": cu_id, "dup": False})
            requeued.append(cu_id)
    return requeued


class HeartbeatMonitor:
    """Declares a pilot failed after ``timeout_s`` without a heartbeat and
    recovers its workload."""

    def __init__(self, ctx: RuntimeContext, timeout_s: float = 0.5, poll_s: float = 0.05):
        self.ctx = ctx
        self.timeout_s = timeout_s
        self.poll_s = poll_s
        self._stop = threading.Event()
        self.failures: List[str] = []
        self._thread = threading.Thread(
            target=self._loop, name="heartbeat-monitor", daemon=True
        )

    def start(self) -> "HeartbeatMonitor":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2.0)

    def _loop(self) -> None:
        store = self.ctx.store
        while not self._stop.is_set():
            now = time.monotonic()
            try:
                keys = store.hkeys("pilot:")
            except Exception:
                time.sleep(self.poll_s)
                continue
            for key in keys:
                rec = store.hgetall(key)
                if rec.get("state") != PilotState.ACTIVE:
                    continue
                hb = rec.get("heartbeat", 0.0)
                if now - hb > self.timeout_s:
                    pilot_id = key.split(":", 1)[1]
                    store.hset(key, "state", PilotState.FAILED)
                    self.failures.append(pilot_id)
                    requeue_orphans(self.ctx, pilot_id)
            time.sleep(self.poll_s)


class StragglerMitigator:
    """Duplicate-launches slow CUs (speculative execution).

    Policy: once at least ``min_samples`` CUs of the workload completed, any
    RUNNING CU older than ``factor`` × median completed duration is pushed
    (as a duplicate) to the global queue — another pilot races it; the
    agent's winner-CAS keeps completion exactly-once.  Only CUs marked
    idempotent are eligible.
    """

    def __init__(
        self,
        ctx: RuntimeContext,
        factor: float = 2.5,
        min_samples: int = 3,
        poll_s: float = 0.05,
    ):
        self.ctx = ctx
        self.factor = factor
        self.min_samples = min_samples
        self.poll_s = poll_s
        self._stop = threading.Event()
        self._duplicated: Dict[str, float] = {}
        self.duplicates: List[str] = []
        self._thread = threading.Thread(
            target=self._loop, name="straggler-mitigator", daemon=True
        )

    def start(self) -> "StragglerMitigator":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2.0)

    def _completed_durations(self) -> List[float]:
        out = []
        for key in self.ctx.store.hkeys("cu:"):
            rec = self.ctx.store.hgetall(key)
            t = rec.get("timings")
            if rec.get("state") == CUState.DONE and t:
                out.append(t.get("t_c", 0.0))
        return out

    def _loop(self) -> None:
        store = self.ctx.store
        while not self._stop.is_set():
            time.sleep(self.poll_s)
            try:
                durations = self._completed_durations()
            except Exception:
                continue
            if len(durations) < self.min_samples:
                continue
            threshold = straggler_threshold(durations, self.factor)
            now = time.monotonic()
            for key in store.hkeys("cu:"):
                cu_id = key.split(":", 1)[1]
                if cu_id in self._duplicated:
                    continue
                rec = store.hgetall(key)
                if rec.get("state") != CUState.RUNNING or rec.get("winner"):
                    continue
                try:
                    cu: ComputeUnit = self.ctx.lookup(cu_id)
                except KeyError:
                    continue
                if not cu.description.kwargs.get("idempotent", True):
                    continue
                started = cu.timings.run_start or cu.timings.stage_start
                if started and (now - started) > threshold:
                    store.push(GLOBAL_QUEUE, {"cu": cu_id, "dup": True})
                    self._duplicated[cu_id] = now
                    self.duplicates.append(cu_id)
