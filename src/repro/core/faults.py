"""Fault tolerance & straggler mitigation (paper §4.2 "Fault Tolerance" and
the §6.4 lessons).

The paper's design: all framework state lives in the coordination store, so
components can crash, reconnect and resume; transfers retry; and the
evaluation observed "failures due to high loads, wall time limits and file
transfer errors" plus heavy-tailed stragglers ("CUs started later on a
machine run longer", "the first resource must not be the best one").

This module supplies the *active* policies on top of that substrate:

  * :class:`HeartbeatMonitor` — detects dying pilots.  A pilot that misses
    heartbeats first enters a grace-period ``SUSPECT`` state (non-placeable;
    schedulers route around it, its agent stops claiming new work so
    in-flight CUs drain); continued silence hardens it to ``FAILED``, a
    fresh heartbeat returns it to ``ACTIVE``.  The per-tick cost is O(1 +
    changes), not O(keyspace): liveness is ONE ``hgetall`` of the shared
    heartbeats hash and pilot states are tracked incrementally off the
    store's keyspace notifications;
  * :class:`StragglerMitigator` — duplicates long-running idempotent CUs
    onto other pilots; the exactly-once "winner" CAS in the agent makes the
    first finisher authoritative.  The RUNNING set and the completed-
    duration sample are maintained incrementally off store events, so a
    tick issues store ops only for actual straggler candidates —
    O(changes), not O(pilots × CUs);
  * :func:`requeue_orphans` / :func:`fail_cu_terminal` — the shared
    recovery primitives.  Orphan retry accounting rides the store-side
    ``attempts`` counter (a crash-looping pilot cannot retry a CU forever
    just because no live handle resolves), and exhausted retries fail
    through the full dataflow cascade (output DUs go FAILED, waiting
    consumers are released with the upstream cause).

Pilot *death recovery* — purging the dead sandbox's replicas, re-enforcing
per-DU replication factors and lineage recomputation — lives in
:mod:`repro.core.recovery`; the monitor hands failures to it via the
``on_failure`` callback.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Deque, Dict, List, Optional, Set

from .agent import GLOBAL_QUEUE
from .compute_unit import CUState, ComputeUnit
from .cost_model import straggler_threshold
from .coordination import StoreEvent
from .data_unit import DUState
from .pilot import HEARTBEATS_KEY, PilotState, RuntimeContext


def fail_cu_terminal(
    ctx: RuntimeContext, cu_id: str, reason: str, respect_winner: bool = True
) -> bool:
    """Terminally fail a CU *store-side*, cascading to its output DUs.

    Works without a live :class:`ComputeUnit` handle (the description is
    read back from the store), so orphan recovery on a reconnected manager
    fails dataflow consumers instead of leaving them parked forever.

    The exactly-once winner CAS is poisoned FIRST: a straggler duplicate
    still in flight must not claim the win after the failure cascade fired
    (it would flip the CU to DONE and re-seal outputs whose consumers were
    already failed over).  If a real winner already landed the CU in fact
    completed — with ``respect_winner`` (the orphan-recovery default) the
    failure is then abandoned and False returned; recovery paths that fail
    an already-DONE CU's *future* (impossible lineage recomputation) pass
    ``respect_winner=False``.
    """
    store = ctx.store
    if not store.hcas(f"cu:{cu_id}", "winner", None, "__failed__"):
        winner = store.hget(f"cu:{cu_id}", "winner")
        if respect_winner and winner != "__failed__":
            return False  # a duplicate beat us to completion: let it stand
    store.hset(f"cu:{cu_id}", "error", reason)
    store.hset(f"cu:{cu_id}", "state", CUState.FAILED)
    desc = store.hget(f"cu:{cu_id}", "desc") or {}
    for du_id in desc.get("output_data", ()):
        if store.hget(f"du:{du_id}", "state") != DUState.READY:
            store.hset(
                f"du:{du_id}", "error",
                f"producer cu://{cu_id} failed: {reason}",
            )
            store.hset(f"du:{du_id}", "state", DUState.FAILED)
    try:
        cu: ComputeUnit = ctx.lookup(cu_id)
        cu.error = reason
    except KeyError:
        pass
    if ctx.tier_manager is not None:
        ctx.tier_manager.pins.unpin_owner(cu_id)
    return True


def requeue_orphans(
    ctx: RuntimeContext, pilot_id: str, deps=None
) -> List[str]:
    """Re-queue every CU the (dead) pilot had claimed but not won, AND
    drain its pilot-specific queue back to the global queue (queued-but-
    unclaimed work must not die with the pilot).

    Retry accounting is store-side: each orphan recovery bumps the CU's
    ``attempts`` hash field whether or not a live handle resolves, and a CU
    whose retries are exhausted goes through :func:`fail_cu_terminal` so
    its output DUs fail and dataflow consumers are released with the cause.

    ``deps`` (a :class:`~repro.core.services.DependencyTracker`) re-parks
    orphans whose input DUs are mid-``Recovering`` on the dependency gate
    instead of re-queueing them into a staging path that cannot succeed
    yet; they release the moment the recovered DU re-seals.
    """
    store = ctx.store

    def repark_if_recovering(cu_id: str) -> bool:
        """Park a CU whose inputs are mid-``Recovering`` on the dependency
        gate (re-attaching a handle from the store when none is live) —
        re-queueing it would burn its retry budget on staging that cannot
        succeed until the recovered DU re-seals."""
        if deps is None:
            return False
        desc_json = store.hget(f"cu:{cu_id}", "desc") or {}
        unmet = {
            du_id
            for du_id in desc_json.get("input_data", ())
            if store.hget(f"du:{du_id}", "state") == DUState.RECOVERING
        }
        if not unmet:
            return False
        try:
            cu = ctx.lookup(cu_id)
        except KeyError:
            from .compute_unit import ComputeUnitDescription

            cu = ComputeUnit(
                ComputeUnitDescription(**desc_json), store, cu_id=cu_id
            )
            ctx.register(cu)
        store.hset(f"cu:{cu_id}", "state", CUState.WAITING)
        deps.add(cu, unmet)
        return True

    requeued = []
    # drain the dead pilot's queue (no attempt charge: this work was never
    # claimed, the pilot just happened to be its queue)
    while True:
        item = store.pop(f"queue:pilot:{pilot_id}", timeout=0.0)
        if item is None:
            break
        cu_id = item["cu"] if isinstance(item, dict) else item
        if not repark_if_recovering(cu_id):
            store.push(GLOBAL_QUEUE, item)
        requeued.append(cu_id)
    for key in store.hkeys("cu:"):
        cu_id = key.split(":", 1)[1]
        rec = store.hgetall(key)
        if rec.get("pilot") != pilot_id:
            continue
        if rec.get("state") in (CUState.STAGING, CUState.RUNNING) and (
            rec.get("winner") is None
        ):
            attempts = int(rec.get("attempts", 0)) + 1
            store.hset(key, "attempts", attempts)
            max_retries = (rec.get("desc") or {}).get("max_retries", 2)
            try:
                cu: ComputeUnit = ctx.lookup(cu_id)
                cu.attempts = max(cu.attempts, attempts)
            except KeyError:
                pass  # store-side counters carry the accounting regardless
            if attempts > max_retries:
                fail_cu_terminal(
                    ctx, cu_id,
                    f"pilot {pilot_id} died and retries are exhausted "
                    f"({attempts} attempts > max_retries={max_retries})",
                )
                continue
            if repark_if_recovering(cu_id):
                requeued.append(cu_id)
                continue
            store.hset(key, "state", CUState.PENDING)
            store.push(GLOBAL_QUEUE, {"cu": cu_id, "dup": False})
            requeued.append(cu_id)
    return requeued


class HeartbeatMonitor:
    """Pilot liveness: ACTIVE → SUSPECT (grace) → FAILED, event-driven.

    Per tick the monitor issues ONE store read (``hgetall`` of the shared
    heartbeats hash); the set of pilots worth checking is maintained
    incrementally from ``pilot:`` keyspace notifications, so total store
    traffic per tick is O(1 + state changes) regardless of keyspace size
    (``bench_faults`` proves this on the store's op counter).

    ``on_suspect(pilot_id)`` / ``on_failure(pilot_id)`` hook the
    FaultManager's recovery pipeline in.  When no ``on_failure`` is
    supplied the monitor itself requeues the dead pilot's orphans
    (standalone mode — the pre-recovery behaviour).
    """

    def __init__(
        self,
        ctx: RuntimeContext,
        timeout_s: float = 0.5,
        poll_s: float = 0.05,
        suspect_timeout_s: Optional[float] = None,
        on_suspect: Optional[Callable[[str], None]] = None,
        on_failure: Optional[Callable[[str], None]] = None,
    ):
        self.ctx = ctx
        self.timeout_s = timeout_s
        self.suspect_timeout_s = (
            suspect_timeout_s if suspect_timeout_s is not None
            else timeout_s / 2.0
        )
        self.poll_s = poll_s
        self.on_suspect = on_suspect
        self.on_failure = on_failure
        self._stop = threading.Event()
        self.failures: List[str] = []
        self.suspects: List[str] = []
        self._lock = threading.Lock()
        #: pilot id -> last observed state (fed by keyspace notifications;
        #: seeded once from the store at construction).  Subscribe FIRST,
        #: seed after: a transition landing between the two is then either
        #: delivered as an event or visible to the seed read — never lost.
        self._states: Dict[str, str] = {}
        store = ctx.store
        self._token = store.subscribe(self._on_event, prefix="pilot:")
        # store reads OUTSIDE self._lock (the event callback takes it while
        # holding the store lock — nesting them the other way deadlocks)
        seeded = {
            key.split(":", 1)[1]: store.hget(key, "state")
            for key in store.hkeys("pilot:")
        }
        with self._lock:
            for pid, state in seeded.items():
                # an event that already arrived is newer than our read
                self._states.setdefault(pid, state)
        self._thread = threading.Thread(
            target=self._loop, name="heartbeat-monitor", daemon=True
        )

    def _on_event(self, ev: StoreEvent) -> None:
        # store callback (dispatcher thread): in-memory bookkeeping only
        if ev.op == "hset" and ev.field == "state":
            with self._lock:
                self._states[ev.key.split(":", 1)[1]] = ev.value

    def start(self) -> "HeartbeatMonitor":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self.ctx.store.unsubscribe(self._token)
        if self._thread.is_alive():
            self._thread.join(timeout=2.0)

    def _tick(self, now: Optional[float] = None) -> None:
        """One liveness pass (exposed for tests/benchmarks)."""
        store = self.ctx.store
        # events are delivered off the mutating thread: barrier first so
        # _states reflects every pilot transition already written (the
        # flush is not a store op — ticks stay O(changes))
        store.flush_events()
        now = time.monotonic() if now is None else now
        heartbeats = store.hgetall(HEARTBEATS_KEY)  # the single scan
        with self._lock:
            watched = [
                (pid, st) for pid, st in self._states.items()
                if st in (PilotState.ACTIVE, PilotState.SUSPECT)
            ]
        for pilot_id, state in watched:
            silence = now - heartbeats.get(pilot_id, 0.0)
            key = f"pilot:{pilot_id}"
            if silence > self.timeout_s:
                # hard failure: CAS so a racing recovery/agent write wins
                if store.hcas(key, "state", state, PilotState.FAILED):
                    # dead pilots never heartbeat again: drop the entry so
                    # the shared hash doesn't grow with historical churn
                    store.hdel(HEARTBEATS_KEY, pilot_id)
                    self.failures.append(pilot_id)
                    if self.on_failure is not None:
                        self.on_failure(pilot_id)
                    else:
                        requeue_orphans(self.ctx, pilot_id)
            elif silence > self.suspect_timeout_s:
                if state == PilotState.ACTIVE and store.hcas(
                    key, "state", PilotState.ACTIVE, PilotState.SUSPECT
                ):
                    self.suspects.append(pilot_id)
                    if self.on_suspect is not None:
                        self.on_suspect(pilot_id)
            elif state == PilotState.SUSPECT:
                # heartbeats resumed inside the grace window: reinstate
                store.hcas(
                    key, "state", PilotState.SUSPECT, PilotState.ACTIVE
                )

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                self._tick()
            except Exception:
                pass  # transient store outage: monitor survives (§4.2)
            time.sleep(self.poll_s)


class StragglerMitigator:
    """Duplicate-launches slow CUs (speculative execution).

    Policy: once at least ``min_samples`` CUs of the workload completed, any
    RUNNING CU older than ``factor`` × median completed duration is pushed
    (as a duplicate) to the global queue — another pilot races it; the
    agent's winner-CAS keeps completion exactly-once.  Only CUs marked
    idempotent are eligible.

    The scan is incremental: the RUNNING set and the completed-duration
    sample are maintained from ``cu:`` keyspace notifications (state
    transitions carry the membership, ``timings`` writes carry the
    durations — no store read-back at all), so one tick touches the store
    only for candidates already past the threshold.
    """

    def __init__(
        self,
        ctx: RuntimeContext,
        factor: float = 2.5,
        min_samples: int = 3,
        poll_s: float = 0.05,
    ):
        self.ctx = ctx
        self.factor = factor
        self.min_samples = min_samples
        self.poll_s = poll_s
        self._stop = threading.Event()
        self._lock = threading.Lock()
        #: cu_id -> monotonic time the RUNNING transition was observed
        self._running: Dict[str, float] = {}
        #: bounded rolling sample — the threshold tracks the recent
        #: workload instead of growing with session age
        self._durations: Deque[float] = collections.deque(maxlen=512)
        self._duplicated: Dict[str, float] = {}
        self._ineligible: Set[str] = set()
        self.duplicates: List[str] = []
        # Subscribe FIRST, then seed from the store, so a mitigator
        # attached to an in-progress run sees pre-existing RUNNING CUs and
        # completed-duration samples AND cannot lose a transition landing
        # during the scan (events carry the changes from here on).  Store
        # reads stay outside self._lock — the event callback takes it
        # while holding the store lock.
        self._token = ctx.store.subscribe(self._on_event, prefix="cu:")
        now = time.monotonic()
        store = ctx.store
        running_seed: List[str] = []
        duration_seed: List[float] = []
        for key in store.hkeys("cu:"):
            rec = store.hgetall(key)
            state = rec.get("state")
            if state == CUState.RUNNING:
                running_seed.append(key.split(":", 1)[1])
            t = rec.get("timings")
            if state == CUState.DONE and isinstance(t, dict):
                duration_seed.append(float(t.get("t_c", 0.0)))
        with self._lock:
            for cu_id in running_seed:
                self._running.setdefault(cu_id, now)
            self._durations.extend(duration_seed)
        self._thread = threading.Thread(
            target=self._loop, name="straggler-mitigator", daemon=True
        )

    def _on_event(self, ev: StoreEvent) -> None:
        # store callback (dispatcher thread): in-memory bookkeeping only
        if ev.op != "hset":
            return
        cu_id = ev.key.split(":", 1)[1]
        if ev.field == "state":
            with self._lock:
                if ev.value == CUState.RUNNING:
                    self._running.setdefault(cu_id, time.monotonic())
                else:
                    self._running.pop(cu_id, None)
                    if ev.value in CUState.TERMINAL:
                        # terminal CUs can never be duplicated again:
                        # drop their dedup bookkeeping so long sessions
                        # don't accumulate it
                        self._duplicated.pop(cu_id, None)
                        self._ineligible.discard(cu_id)
        elif ev.field == "timings" and isinstance(ev.value, dict):
            with self._lock:
                self._durations.append(float(ev.value.get("t_c", 0.0)))

    def start(self) -> "StragglerMitigator":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self.ctx.store.unsubscribe(self._token)
        if self._thread.is_alive():
            self._thread.join(timeout=2.0)

    def _tick(self, now: Optional[float] = None) -> None:
        """One speculative-execution pass (exposed for tests/benchmarks).
        Store ops: O(candidates past threshold), zero on a quiet tick."""
        store = self.ctx.store
        # barrier: fold in cu: transitions already written but still in
        # flight on the dispatcher (flush_events is not a store op)
        store.flush_events()
        with self._lock:
            if len(self._durations) < self.min_samples:
                return
            threshold = straggler_threshold(list(self._durations), self.factor)
            now = time.monotonic() if now is None else now
            candidates = [
                (cu_id, started)
                for cu_id, started in self._running.items()
                if cu_id not in self._duplicated
                and cu_id not in self._ineligible
                and (now - started) > threshold
            ]
        for cu_id, _ in candidates:
            try:
                cu: ComputeUnit = self.ctx.lookup(cu_id)
            except KeyError:
                continue
            if not cu.description.kwargs.get("idempotent", True):
                with self._lock:
                    self._ineligible.add(cu_id)
                continue
            if store.hget(f"cu:{cu_id}", "winner"):
                # already finished — drop it here too, covering a stale
                # seed entry whose terminal event predated the seeding scan
                with self._lock:
                    self._running.pop(cu_id, None)
                continue
            store.push(GLOBAL_QUEUE, {"cu": cu_id, "dup": True})
            with self._lock:
                self._duplicated[cu_id] = now
            self.duplicates.append(cu_id)

    def _loop(self) -> None:
        while not self._stop.is_set():
            time.sleep(self.poll_s)
            try:
                self._tick()
            except Exception:
                continue
