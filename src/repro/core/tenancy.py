"""Multi-tenant identity, quotas, and fair-share accounting.

The paper frames Pilot-Data as an abstraction for *shared* distributed
infrastructure, and the P* model / pilot-job survey (PAPERS.md) both name
multi-user contention for pilots as the defining production problem.  This
module is the identity layer for that: a :class:`Tenant` is a named
principal with a scheduling ``priority`` and a :class:`ResourceQuota`;
the :class:`TenantRegistry` (attached to the runtime context as
``ctx.tenant_registry``) tracks who exists, how much work each tenant has
in flight, and how much service each has received — the numbers the
AdmissionController (``core/services.py``), the ``weighted-fair-share`` /
``priority`` placement strategies (``core/placement.py``), tenant-aware
eviction (``core/tiering.py``) and the transfer cost model
(``core/transfer.py``) all rank on.

Single-tenant deployments need zero changes: every CU/DU defaults to the
``default`` tenant, whose quota is unlimited, so admission is a
pass-through and every fair-share computation degenerates to the
pre-tenancy behavior.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional, Set

#: the implicit tenant of every CU/DU that never names one — unlimited
#: quota, priority 0, weight 1.0 (exact pre-tenancy semantics)
DEFAULT_TENANT = "default"


@dataclasses.dataclass
class ResourceQuota:
    """Per-tenant resource ceilings.  ``None`` means unlimited.

    * ``cu_slots`` — max CUs admitted past the AdmissionController at
      once (Pending-on-a-queue through Running); excess submissions are
      *parked*, not failed, and re-admitted as earlier CUs turn terminal.
    * ``sandbox_bytes`` — max bytes of the tenant's DU chunks resident
      across all Pilot-Data at admission time; a tenant over this ceiling
      has further CU admissions parked until its bytes drain or evict.
    * ``transfer_bw_share`` — relative weight for the transfer-bandwidth
      share (and the fair-share deficit): a tenant with weight 2 competing
      with one at weight 1 models 2/3 of the contended bandwidth.
    """

    cu_slots: Optional[int] = None
    sandbox_bytes: Optional[int] = None
    transfer_bw_share: float = 1.0

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Tenant:
    """One named principal sharing the runtime."""

    name: str
    #: scheduling priority — higher preempts *queued* (never running) CUs
    #: of strictly lower-priority tenants when starved
    priority: int = 0
    quota: ResourceQuota = dataclasses.field(default_factory=ResourceQuota)

    def to_json(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "priority": self.priority,
            "quota": self.quota.to_json(),
        }


class TenantRegistry:
    """Who the tenants are and what they are currently consuming.

    Usage accounting (in-flight CU ids, served sim-seconds of service,
    resident sandbox bytes) is written by the AdmissionController and read
    by the placement strategies and the transfer cost model.  Unknown
    tenant names auto-register with defaults, so stamping a bare name on a
    description is enough to participate.
    """

    def __init__(self, ctx: Any = None):
        self.ctx = ctx
        self._lock = threading.Lock()
        self._tenants: Dict[str, Tenant] = {
            DEFAULT_TENANT: Tenant(DEFAULT_TENANT)
        }
        #: tenant -> CU ids admitted and not yet terminal
        self._inflight: Dict[str, Set[str]] = {}
        #: tenant -> accumulated admitted work (estimate seconds) — the
        #: deficit counter weighted fair-share admission orders on
        self._served: Dict[str, float] = {}

    # ----------------------------------------------------------- membership
    def register(
        self,
        name: str,
        priority: int = 0,
        quota: Optional[ResourceQuota] = None,
    ) -> Tenant:
        """Create or update a tenant (idempotent; later registrations win)."""
        with self._lock:
            tenant = self._tenants.get(name)
            if tenant is None:
                tenant = Tenant(
                    name=name,
                    priority=priority,
                    quota=quota or ResourceQuota(),
                )
                self._tenants[name] = tenant
            else:
                tenant.priority = priority
                if quota is not None:
                    tenant.quota = quota
            return tenant

    def get(self, name: Optional[str]) -> Tenant:
        name = name or DEFAULT_TENANT
        with self._lock:
            tenant = self._tenants.get(name)
            if tenant is None:
                tenant = Tenant(name)
                self._tenants[name] = tenant
            return tenant

    def tenants(self) -> List[Tenant]:
        with self._lock:
            return [self._tenants[n] for n in sorted(self._tenants)]

    @property
    def multi_tenant(self) -> bool:
        """True once anything beyond the bare default tenant exists — the
        switch that turns admission from a pass-through into a gate."""
        with self._lock:
            if len(self._tenants) > 1:
                return True
            d = self._tenants[DEFAULT_TENANT]
            return (
                d.priority != 0
                or d.quota.cu_slots is not None
                or d.quota.sandbox_bytes is not None
            )

    def min_priority(self) -> int:
        with self._lock:
            return min(t.priority for t in self._tenants.values())

    # ----------------------------------------------------------- accounting
    def weight(self, name: Optional[str]) -> float:
        return max(self.get(name).quota.transfer_bw_share, 1e-9)

    def note_admitted(self, name: str, cu_id: str, est_s: float) -> None:
        with self._lock:
            self._inflight.setdefault(name, set()).add(cu_id)
            self._served[name] = self._served.get(name, 0.0) + est_s

    def note_removed(self, name: str, cu_id: str) -> None:
        with self._lock:
            self._inflight.get(name, set()).discard(cu_id)

    def inflight(self, name: str) -> int:
        with self._lock:
            return len(self._inflight.get(name, ()))

    def served(self, name: str) -> float:
        with self._lock:
            return self._served.get(name, 0.0)

    def deficit_key(self, name: str) -> float:
        """Weighted service received — LOWER means more starved.  The
        admission drain and fair-share ordering pick the smallest."""
        return self.served(name) / self.weight(name)

    def active_tenants(self) -> List[str]:
        """Tenants with admitted, non-terminal CUs (the bandwidth rivals)."""
        with self._lock:
            return sorted(n for n, s in self._inflight.items() if s)

    def bw_share(self, name: Optional[str]) -> float:
        """This tenant's fraction of contended transfer bandwidth: its
        weight over the total weight of all *active* tenants (itself
        included).  1.0 when it has the infrastructure to itself."""
        name = name or DEFAULT_TENANT
        rivals = [t for t in self.active_tenants() if t != name]
        if not rivals:
            return 1.0
        mine = self.weight(name)
        total = mine + sum(self.weight(t) for t in rivals)
        return mine / total

    def resident_bytes(self, name: str) -> int:
        """Bytes of this tenant's DU chunks currently resident across all
        live Pilot-Data — the number ``sandbox_bytes`` quotas gate on.
        Computed on demand from PD accounting (admission-time only, so the
        O(PDs × DUs) scan stays off every hot path)."""
        if self.ctx is None:
            return 0
        total = 0
        store = self.ctx.store
        for obj in list(self.ctx.objects.values()):
            holdings = getattr(obj, "du_bytes", None)
            if holdings is None:
                continue
            for du_id, nbytes in holdings().items():
                owner = store.hget(f"du:{du_id}", "tenant") or DEFAULT_TENANT
                if owner == name:
                    total += nbytes
        return total
