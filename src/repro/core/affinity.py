"""Affinity model: logical resource topology tree with weighted edges.

Paper §5: "data centers and machines are organized in a logical topology
tree.  The further the distance between two resources, the smaller their
affinity. ... this model ... can be enhanced by assigning weights to each
edge to reflect dynamical changes in factors that contribute to
connectivity."

A location is a colon-separated label, e.g. ``"cluster:pod0:host3"`` (the
paper's user-defined affinity label from the Pilot description).  Every
prefix of a label is a node in the tree; each node carries the bandwidth and
latency of its *uplink* (edge to its parent).  The effective bandwidth
between two locations is the bottleneck (min) edge along the tree path; the
latency is the sum.

For the TPU adaptation the levels are cluster → pod → host → device and the
default uplink constants mirror the assignment's hardware model (ICI within a
pod, DCN across pods, PCIe host↔device).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

GB = 1e9


@dataclasses.dataclass
class _Node:
    label: str  # full label, e.g. "cluster:pod0:host3"
    parent: Optional[str]
    uplink_bw: float  # bytes/sec to parent
    uplink_lat: float  # seconds to parent
    meta: Dict[str, float] = dataclasses.field(default_factory=dict)


def _prefixes(label: str) -> List[str]:
    parts = label.split(":")
    return [":".join(parts[: i + 1]) for i in range(len(parts))]


class Topology:
    """A weighted logical topology tree over affinity labels."""

    #: default uplink (bandwidth bytes/s, latency s) per tree depth,
    #: depth 1 = site/pod uplink to the cluster root (WAN/DCN), deeper =
    #: faster, more local links.  Chosen to mirror TPU-fleet tiers:
    #: DCN ~ 25 GB/s per pod, pod fabric ~ 50 GB/s/link ICI, host PCIe ~ 16 GB/s.
    DEFAULT_TIER_BW = {1: 25 * GB, 2: 50 * GB, 3: 16 * GB, 4: 819 * GB}
    DEFAULT_TIER_LAT = {1: 1e-3, 2: 5e-6, 3: 2e-6, 4: 1e-7}

    def __init__(self) -> None:
        self._nodes: Dict[str, _Node] = {}

    # ------------------------------------------------------------ building
    def register(
        self,
        label: str,
        bandwidth: Optional[float] = None,
        latency: Optional[float] = None,
        **meta: float,
    ) -> None:
        """Register a location (and implicitly all its ancestors).

        ``bandwidth``/``latency`` describe the *uplink* of the deepest node
        in ``label``; ancestors get tier defaults unless already registered.
        """
        prefixes = _prefixes(label)
        for depth, prefix in enumerate(prefixes, start=1):
            is_leaf_of_label = prefix == label
            if prefix in self._nodes:
                if is_leaf_of_label:
                    node = self._nodes[prefix]
                    if bandwidth is not None:
                        node.uplink_bw = bandwidth
                    if latency is not None:
                        node.uplink_lat = latency
                    node.meta.update(meta)
                continue
            parent = prefixes[depth - 2] if depth >= 2 else None
            bw = (
                bandwidth
                if (is_leaf_of_label and bandwidth is not None)
                else self.DEFAULT_TIER_BW.get(depth, self.DEFAULT_TIER_BW[max(self.DEFAULT_TIER_BW)])
            )
            lat = (
                latency
                if (is_leaf_of_label and latency is not None)
                else self.DEFAULT_TIER_LAT.get(depth, self.DEFAULT_TIER_LAT[max(self.DEFAULT_TIER_LAT)])
            )
            self._nodes[prefix] = _Node(
                prefix, parent, bw, lat, dict(meta) if is_leaf_of_label else {}
            )

    def ensure(self, label: str) -> None:
        if label not in self._nodes:
            self.register(label)

    def labels(self) -> List[str]:
        return sorted(self._nodes)

    def set_edge_weight(
        self, label: str, bandwidth: Optional[float] = None, latency: Optional[float] = None
    ) -> None:
        """Dynamically re-weight an uplink (paper: weights "reflect dynamical
        changes in factors that contribute to connectivity")."""
        self.ensure(label)
        node = self._nodes[label]
        if bandwidth is not None:
            node.uplink_bw = bandwidth
        if latency is not None:
            node.uplink_lat = latency

    # ------------------------------------------------------------- queries
    def _path_to_root(self, label: str) -> List[str]:
        self.ensure(label)
        path = []
        cur: Optional[str] = label
        while cur is not None:
            path.append(cur)
            cur = self._nodes[cur].parent
        return path

    def common_ancestor(self, a: str, b: str) -> Optional[str]:
        pa = set(self._path_to_root(a))
        for node in self._path_to_root(b):
            if node in pa:
                return node
        return None

    def path_edges(self, a: str, b: str) -> List[_Node]:
        """Edges (as child nodes) on the tree path a→b, excluding the LCA."""
        if a == b:
            return []
        lca = self.common_ancestor(a, b)
        edges: List[_Node] = []
        for start in (a, b):
            cur: Optional[str] = start
            while cur is not None and cur != lca:
                edges.append(self._nodes[cur])
                cur = self._nodes[cur].parent
            if cur is None and lca is not None:
                raise ValueError(f"disconnected labels {a!r}, {b!r}")
        return edges

    def distance(self, a: str, b: str) -> int:
        """Tree hop distance (number of edges on the path)."""
        return len(self.path_edges(a, b))

    def affinity(self, a: str, b: str) -> float:
        """Paper: "The smaller the distance between two resources, the larger
        the affinity."  Normalized to (0, 1], 1 == same location."""
        return 2.0 ** (-self.distance(a, b))

    def bandwidth(self, a: str, b: str) -> float:
        """Bottleneck bandwidth along the tree path (bytes/s); inf if a==b
        (a co-located transfer is a logical link, §4.3.2)."""
        edges = self.path_edges(a, b)
        if not edges:
            return float("inf")
        return min(e.uplink_bw for e in edges)

    def latency(self, a: str, b: str) -> float:
        return sum(e.uplink_lat for e in self.path_edges(a, b))

    def same_subtree(self, a: str, b: str, level: int = 1) -> bool:
        """True if a and b share an ancestor at the given depth (1=site)."""
        pa, pb = _prefixes(a), _prefixes(b)
        return len(pa) >= level and len(pb) >= level and pa[level - 1] == pb[level - 1]


def match_affinity(constraint: Optional[str], location: str) -> bool:
    """Does ``location`` satisfy an affinity *constraint*?

    Paper §5: "CUs and DUs can constrain their execution resource to a
    particular affinity (e.g. to a certain location or sub-tree in the
    logical resource topology)."  A constraint matches itself and any
    descendant label.
    """
    if not constraint:
        return True
    return location == constraint or location.startswith(constraint + ":")


def make_tpu_fleet_topology(
    pods: int = 2,
    hosts_per_pod: int = 4,
    dcn_bw: float = 25 * GB,
    ici_bw: float = 50 * GB,
    pcie_bw: float = 16 * GB,
    cluster: str = "cluster",
) -> Tuple[Topology, List[str]]:
    """Convenience: build the TPU-fleet topology used across tests/benchmarks.

    Returns (topology, host labels)."""
    topo = Topology()
    hosts = []
    for p in range(pods):
        topo.register(f"{cluster}:pod{p}", bandwidth=dcn_bw, latency=1e-3)
        for h in range(hosts_per_pod):
            label = f"{cluster}:pod{p}:host{h}"
            topo.register(label, bandwidth=ici_bw, latency=5e-6)
            hosts.append(label)
    return topo, hosts


def make_grid_topology(sites: Iterable[Tuple[str, float, float]]) -> Topology:
    """Build a paper-style multi-site grid topology.

    ``sites``: iterable of (label, uplink_bandwidth_bytes_per_s, latency_s),
    e.g. the XSEDE/OSG site set of §6 with measured WAN bandwidths.
    """
    topo = Topology()
    for label, bw, lat in sites:
        topo.register(label, bandwidth=bw, latency=lat)
    return topo
