"""The paper's placement calculus (§6.1), made programmatic.

Parameters (paper notation):
  * ``T_Q``      — queue waiting time at a resource.  ``T_Q_pilot`` is the
                   pilot's provisioning/queue time, ``T_Q_task`` the
                   pilot-internal queueing time.
  * ``T_C``      — compute time of a task.
  * ``T_X``      — raw transfer time.
  * ``T_S``      — staging time = ``T_X + T_register``.
  * ``T_R(R)``   — time to replicate over R sites.
  * ``T_D``      — time until data is accessible across all resources;
                   with replication, ``T_D = T_R(R) + T_S``.

Decision rules implemented exactly as §6.1 lays them out:
  * "If the expected T_X is larger than the T_Q, then the compute is
    assigned to a site first, and subsequently data is placed" — i.e.
    data-to-compute; otherwise compute-to-data.
  * "Resources co-located with data replicas, with the lowest queue waiting
    time present optimal choice."
  * Partial/incremental replication: start with a subset of sites, grow the
    replication factor while co-located compute capacity is insufficient.

All functions are *pure* — they are shared between the threaded runtime
scheduler and the discrete-event simulator, so policy decisions are
identical in both mechanisms.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .affinity import Topology


# ------------------------------------------------------------------ T_* terms
def estimate_tx(nbytes: int, src: str, dst: str, topo: Topology) -> float:
    """Transfer time of ``nbytes`` from src to dst along the topology path."""
    if src == dst:
        return 0.0  # logical link (co-located PD, §4.3.2)
    bw = topo.bandwidth(src, dst)
    lat = topo.latency(src, dst)
    if bw == float("inf"):
        return lat
    return lat + nbytes / bw


def estimate_ts(
    nbytes: int, src: str, dst: str, topo: Topology, t_register: float = 0.0
) -> float:
    """Staging = transfer + catalog registration (paper: T_register was
    measured negligible; kept as an explicit term anyway)."""
    return estimate_tx(nbytes, src, dst, topo) + t_register


def estimate_tr_sequential(
    nbytes: int, src: str, targets: Sequence[str], topo: Topology
) -> float:
    """Sequential replication: one replica after the other from the source."""
    return sum(estimate_tx(nbytes, src, dst, topo) for dst in targets)


def estimate_tr_group(
    nbytes: int, src: str, targets: Sequence[str], topo: Topology
) -> float:
    """Group replication: already-completed replicas serve as sources.

    Models the fan-out the paper observed with iRODS group replication
    (Fig. 8: group ≫ sequential): each round every holder pushes to one new
    target, so completion takes ~ceil(log2(R+1)) rounds instead of R rounds.
    Round time is the slowest transfer scheduled in that round (greedy:
    nearest targets first).
    """
    if not targets:
        return 0.0
    holders = [src]
    remaining = sorted(
        targets, key=lambda dst: estimate_tx(nbytes, src, dst, topo)
    )
    t = 0.0
    while remaining:
        n = min(len(holders), len(remaining))
        batch, remaining = remaining[:n], remaining[n:]
        round_t = max(
            estimate_tx(nbytes, h, d, topo) for h, d in zip(holders, batch)
        )
        t += round_t
        holders.extend(batch)
    return t


def estimate_td(
    nbytes: int,
    src: str,
    targets: Sequence[str],
    topo: Topology,
    mode: str = "group",
    t_register: float = 0.0,
) -> float:
    """T_D: time at which data is accessible across all listed resources."""
    if mode == "group":
        tr = estimate_tr_group(nbytes, src, targets, topo)
    elif mode == "sequential":
        tr = estimate_tr_sequential(nbytes, src, targets, topo)
    else:
        raise ValueError(f"unknown replication mode {mode!r}")
    return tr + t_register * len(targets)


# -------------------------------------------------------------- decisions
@dataclasses.dataclass(frozen=True)
class PlacementChoice:
    """Outcome of the §6.1 trade-off for one (CU, candidate pilot) pair."""

    pilot_id: str
    strategy: str  # "compute-to-data" | "data-to-compute"
    t_queue: float
    t_stage: float  # data movement this choice implies
    score: float  # estimated completion-relevant cost (lower is better)


def decide_placement(
    input_bytes_by_location: Dict[str, int],
    pilots: Sequence[Tuple[str, str, float]],
    topo: Topology,
    affinity_constraint: Optional[str] = None,
) -> List[PlacementChoice]:
    """Rank candidate pilots for a CU by the §6.1 calculus.

    Args:
      input_bytes_by_location: bytes of required input data per *replica
        location* label (a DU replicated at several PDs contributes its
        size at each location; the estimator picks the cheapest replica).
      pilots: (pilot_id, location_label, expected_T_Q) triples.
      topo: weighted topology tree.
      affinity_constraint: optional subtree constraint (paper §5).

    Returns choices sorted best-first.  For each pilot the staging cost is
    the sum over required DUs of the *cheapest replica* transfer; the
    strategy is "compute-to-data" when staging dominates queueing
    (T_X > T_Q ⇒ better to move compute to the data's site; the returned
    ranking already reflects that because co-located pilots get t_stage≈0).
    """
    from .affinity import match_affinity

    choices: List[PlacementChoice] = []
    for pilot_id, loc, t_q in pilots:
        if not match_affinity(affinity_constraint, loc):
            continue
        t_stage = 0.0
        for replica_loc, nbytes in input_bytes_by_location.items():
            t_stage += estimate_tx(nbytes, replica_loc, loc, topo)
        strategy = "data-to-compute" if t_q >= t_stage else "compute-to-data"
        choices.append(
            PlacementChoice(
                pilot_id=pilot_id,
                strategy=strategy,
                t_queue=t_q,
                t_stage=t_stage,
                score=t_q + t_stage,
            )
        )
    choices.sort(key=lambda c: (c.score, c.pilot_id))
    return choices


def cheapest_replica(
    nbytes: int, replicas: Sequence[str], dst: str, topo: Topology
) -> Tuple[Optional[str], float]:
    """Pick the replica with the lowest T_X to ``dst`` (paper §6.4: "the
    optimized replication mechanism ... utilizes the replica closest to the
    target site")."""
    best, best_t = None, float("inf")
    for r in replicas:
        t = estimate_tx(nbytes, r, dst, topo)
        if t < best_t:
            best, best_t = r, t
    return best, best_t


def choose_replication_degree(
    nbytes: int,
    src: str,
    candidate_sites: Sequence[Tuple[str, int]],
    tasks: int,
    task_compute_s: float,
    topo: Topology,
    mode: str = "group",
) -> List[str]:
    """Incremental (partial) replication per §6.1's hybrid mode.

    "replication might commence over a subset of suitably chosen nodes,
    followed by a sequential increase in the replication (factor) if compute
    resources close to the replica do not have sufficient compute capacity."

    Greedy: add replica sites (cheapest-first) while the marginal replication
    cost is outweighed by the compute-parallelism gain of unlocking that
    site's slots.  Returns the ordered list of sites to replicate to.
    """
    if tasks <= 0 or not candidate_sites:
        return []
    # Cheapest-first site order.
    order = sorted(
        candidate_sites, key=lambda s: estimate_tx(nbytes, src, s[0], topo)
    )
    chosen: List[str] = []
    slots = 0

    def makespan(sites: List[str], nslots: int) -> float:
        if nslots <= 0:
            return float("inf")
        tr = (
            estimate_tr_group(nbytes, src, sites, topo)
            if mode == "group"
            else estimate_tr_sequential(nbytes, src, sites, topo)
        )
        return tr + math.ceil(tasks / nslots) * task_compute_s

    best = float("inf")
    for site, site_slots in order:
        cand = chosen + [site]
        m = makespan(cand, slots + site_slots)
        if m < best:
            chosen, slots, best = cand, slots + site_slots, m
        else:
            break  # marginal site no longer pays for itself
    return chosen


def straggler_threshold(durations: Iterable[float], factor: float = 2.5) -> float:
    """Duplicate-launch threshold: factor × median of completed durations.

    Used by the workload manager to implement the paper's §6.4 lesson ("the
    first resource must not be the best one") as an automatic policy.
    """
    ds = sorted(durations)
    if not ds:
        return float("inf")
    mid = len(ds) // 2
    median = ds[mid] if len(ds) % 2 else 0.5 * (ds[mid - 1] + ds[mid])
    return factor * median
