"""Pilot-Manager: the central coordinator (paper Fig. 1).

"The Pilot-Manager is the central entity of the framework, which is
responsible for managing the lifecycle of a set of Pilots (both
Pilot-Computes and Pilot-Data)."

:class:`PilotManager` is the one-stop construction point: it owns the
coordination store, the topology, the transfer service, the three Pilot-API
services, and the fault/straggler monitors.  It also implements the
reconnect semantics (§4.2): a second manager can attach to an existing
store (same WAL) and resolve pilots/CUs/DUs by URL.
"""

from __future__ import annotations

import contextlib
import warnings
from typing import Dict, Optional

from .affinity import Topology
from .compute_unit import ComputeUnitDescription, FUNCTIONS
from .coordination import CoordinationStore
from .data_unit import DataUnitDescription
from .faults import HeartbeatMonitor, StragglerMitigator
from .recovery import FaultManager
from .pilot import (
    PilotComputeDescription,
    PilotDataDescription,
    RuntimeContext,
)
from .scheduler import AsyncScheduler
from .tiering import TierManager
from .services import (
    ComputeDataService,
    PilotComputeService,
    PilotDataService,
)
from .transfer import TransferService


class PilotManager:
    def __init__(
        self,
        topology: Optional[Topology] = None,
        store: Optional[CoordinationStore] = None,
        wal_path: Optional[str] = None,
        time_scale: float = 0.0,
        data_mode: str = "pull",
        delayed_scheduling_s: float = 0.0,
        enable_heartbeat_monitor: bool = False,
        heartbeat_timeout_s: float = 0.5,
        suspect_timeout_s: Optional[float] = None,
        enable_fault_manager: bool = False,
        enable_straggler_mitigation: bool = False,
        straggler_factor: float = 2.5,
        scheduler_mode: str = "sync",
        placement_strategy: str = "cost",
        stage_workers: int = 4,
        eviction_policy: str = "lru",
        tier_cache_bytes: int = 0,
        tier_promote_after: int = 2,
        tier_auto_promote: bool = True,
    ):
        if scheduler_mode not in ("sync", "async"):
            raise ValueError(
                f"scheduler_mode must be 'sync' or 'async', got {scheduler_mode!r}"
            )
        self.store = store or CoordinationStore(wal_path=wal_path)
        self.topology = topology or Topology()
        self.ctx = RuntimeContext(
            store=self.store,
            topology=self.topology,
            time_scale=time_scale,
            data_mode=data_mode,
        )
        self.scheduler_mode = scheduler_mode
        self.transfer = TransferService(self.ctx)
        self.compute_service = PilotComputeService(self.ctx)
        self.data_service = PilotDataService(self.ctx)
        self.cds = ComputeDataService(
            self.ctx,
            delayed_scheduling_s=delayed_scheduling_s,
            strategy=placement_strategy,
            start_loop=(scheduler_mode == "sync"),
        )
        self.scheduler: Optional[AsyncScheduler] = None
        if scheduler_mode == "async":
            self.scheduler = AsyncScheduler(
                self.cds, stage_workers=stage_workers
            )
        # storage-hierarchy layer: tier classification + access stats,
        # quota-driven eviction (replaces hard QuotaExceeded), and — with
        # tier_cache_bytes > 0 — hot-DU promotion into a per-site mem-tier
        # cache PD, off the critical path like the async prefetch
        self.tier_manager = TierManager(
            self.ctx,
            cds=self.cds,
            eviction_policy=eviction_policy,
            cache_bytes=tier_cache_bytes,
            promote_after=tier_promote_after,
            auto_promote=tier_auto_promote,
        )
        self._session = None  # lazy Pilot-API v2 facade (see .session)
        self._sessions: list = []  # every attached Session (incl. facade)
        self.heartbeat_monitor: Optional[HeartbeatMonitor] = None
        self.straggler_mitigator: Optional[StragglerMitigator] = None
        self.fault_manager: Optional[FaultManager] = None
        if enable_fault_manager:
            # Full self-healing pipeline: pilot death purges the dead
            # sandbox's replicas, re-enforces replication factors and
            # recomputes lost DUs by lineage (implies the monitor).
            self.fault_manager = FaultManager(self.ctx, cds=self.cds)
            self.heartbeat_monitor = HeartbeatMonitor(
                self.ctx,
                timeout_s=heartbeat_timeout_s,
                suspect_timeout_s=suspect_timeout_s,
                on_suspect=self.fault_manager.on_pilot_suspect,
                on_failure=self.fault_manager.on_pilot_failed,
            ).start()
        elif enable_heartbeat_monitor:
            self.heartbeat_monitor = HeartbeatMonitor(
                self.ctx,
                timeout_s=heartbeat_timeout_s,
                suspect_timeout_s=suspect_timeout_s,
            ).start()
        if enable_straggler_mitigation:
            self.straggler_mitigator = StragglerMitigator(
                self.ctx, factor=straggler_factor
            ).start()

    # ------------------------------------------------------- convenience API
    def start_pilot(self, **kw) -> "PilotCompute":
        pilot = self.compute_service.create_pilot(PilotComputeDescription(**kw))
        self.cds.add_pilot_compute(pilot)
        return pilot

    def start_pilot_data(self, **kw) -> "PilotData":
        pd = self.data_service.create_pilot_data(PilotDataDescription(**kw))
        self.cds.add_pilot_data(pd)
        return pd

    @property
    def session(self) -> "Session":
        """The Pilot-API v2 facade attached to this manager (lazy)."""
        if self._session is None:
            from .session import Session  # local import: cycle

            self._session = Session(manager=self)
        return self._session

    # every Session registers here so shutdown() can drain their
    # dispatcher threads before the store goes away (a session attached
    # via Session(manager=...) used to outlive the store's dispatcher,
    # leaving its futures waiting on events that never arrive)
    def _attach_session(self, session) -> None:
        if session not in self._sessions:
            self._sessions.append(session)

    def _detach_session(self, session) -> None:
        if session in self._sessions:
            self._sessions.remove(session)

    # ------------------------------------------------ deprecated v1 shims
    def submit_du(self, **kw) -> "DataUnit":
        """Deprecated Pilot-API v1 entry point (kept as a thin shim)."""
        warnings.warn(
            "Pilot-API v1: PilotManager.submit_du() is deprecated; use "
            "Session.submit_du (repro.core.session) which returns a DUFuture",
            DeprecationWarning,
            stacklevel=2,
        )
        target = kw.pop("target", None)
        return self.cds.submit_data_unit(DataUnitDescription(**kw), target=target)

    def submit_cu(self, **kw) -> "ComputeUnit":
        """Deprecated Pilot-API v1 entry point (kept as a thin shim)."""
        warnings.warn(
            "Pilot-API v1: PilotManager.submit_cu() is deprecated; use "
            "Session.submit_cu which takes DU/DUFuture objects and returns "
            "a CUFuture",
            DeprecationWarning,
            stacklevel=2,
        )
        return self.cds.submit_compute_unit(ComputeUnitDescription(**kw))

    def register_function(self, name: str, fn=None):
        return FUNCTIONS.register(name, fn)

    def wait(self, timeout: float = 120.0) -> bool:
        return self.cds.wait(timeout=timeout)

    # ------------------------------------------------------------ reconnect
    def cu_states(self) -> Dict[str, str]:
        out = {}
        for key in self.store.hkeys("cu:"):
            out[key.split(":", 1)[1]] = self.store.hget(key, "state")
        return out

    def pilot_states(self) -> Dict[str, str]:
        out = {}
        for key in self.store.hkeys("pilot:"):
            out[key.split(":", 1)[1]] = self.store.hget(key, "state")
        return out

    def shutdown(self) -> None:
        # teardown order matters: every attached session's future
        # dispatcher drains FIRST (they consume store events), then the
        # scheduler reactor, then cds.cancel() — which stops the
        # dependency tracker and admission controller pumps — and only
        # then the store itself closes its event dispatcher.
        for sess in list(self._sessions):
            with contextlib.suppress(Exception):
                sess._dispatcher.stop()
        self._sessions.clear()
        if self._session is not None:
            with contextlib.suppress(Exception):
                self._session._dispatcher.stop()
            self._session = None
        if self.scheduler is not None:
            with contextlib.suppress(Exception):
                self.scheduler.stop()
        with contextlib.suppress(Exception):
            self.cds.cancel()
        with contextlib.suppress(Exception):
            self.compute_service.cancel()
        if self.heartbeat_monitor:
            self.heartbeat_monitor.stop()
        if self.straggler_mitigator:
            self.straggler_mitigator.stop()
        if self.fault_manager:
            with contextlib.suppress(Exception):
                self.fault_manager.stop()
        if self.tier_manager is not None:
            with contextlib.suppress(Exception):
                self.tier_manager.stop()
        self.store.close()

    def __enter__(self) -> "PilotManager":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
