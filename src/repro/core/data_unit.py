"""Data-Unit: the paper's primary data abstraction (§4.3.2).

"A DU is defined as an immutable container for a logical group of 'affine'
data files ... completely decoupled from its physical location and can be
stored in different kinds of backends ... Replicas of a DU can reside in
different Pilot-Data."

Key semantics implemented here:
  * logical identity: a DU has a location-invariant URL ``du://<id>`` that
    stays valid for its whole lifetime ("a simple and useful notion of
    distributed logical location that from an application's perspective is
    invariant over the lifetime");
  * an application-level hierarchical namespace *within* the DU (relative
    file paths), independent of the backend's namespace (object stores are
    flat — the adaptor encodes);
  * immutability after seal: files can be added while the DU is NEW; once
    sealed (first successful staging), mutation raises;
  * replica set: the DU tracks which Pilot-Data hold a full copy; all state
    is mirrored in the coordination store so any client can resolve the DU
    from anywhere (the "distributed namespace").
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import zlib
from typing import Callable, Dict, List, Optional

from .coordination import CoordinationStore


class DUState:
    NEW = "New"
    PENDING = "Pending"  # staging to first PD in flight
    READY = "Ready"  # >= 1 replica materialized; sealed
    FAILED = "Failed"
    DELETED = "Deleted"


_ids = itertools.count()
_ids_lock = threading.Lock()


def _next_id(prefix: str) -> str:
    with _ids_lock:
        return f"{prefix}-{next(_ids):06d}"


@dataclasses.dataclass
class DataUnitDescription:
    """JSON-able description (paper: DUD objects 'defined in the JSON
    format')."""

    name: str = ""
    #: initial content: relative path -> bytes
    files: Dict[str, bytes] = dataclasses.field(default_factory=dict)
    #: affinity constraint label (subtree of the topology) or None
    affinity: Optional[str] = None
    #: size hint for placement when content is produced later (output DUs)
    size_hint: int = 0

    def to_json(self) -> Dict:
        return {
            "name": self.name,
            "files": sorted(self.files),
            "affinity": self.affinity,
            "size_hint": self.size_hint,
        }


class DataUnit:
    """A logical, immutable, replicable group of files."""

    def __init__(
        self,
        description: DataUnitDescription,
        store: CoordinationStore,
        du_id: Optional[str] = None,
    ):
        self.id = du_id or _next_id("du")
        self.description = description
        self._store = store
        self._lock = threading.RLock()
        self._files: Dict[str, bytes] = dict(description.files)
        self._sealed = False
        self._manifest: Dict[str, int] = {
            k: len(v) for k, v in self._files.items()
        }
        self._checksums: Dict[str, int] = {
            k: zlib.crc32(v) for k, v in self._files.items()
        }
        #: bumped on every replica-set change; replica-resolution caches key
        #: their entries on (du id, this counter) and so self-invalidate
        self._loc_version = 0
        store.hset(f"du:{self.id}", "state", DUState.NEW)
        store.hset(f"du:{self.id}", "name", description.name)
        store.hset(f"du:{self.id}", "affinity", description.affinity)
        store.hset(f"du:{self.id}", "locations", [])
        store.hset(f"du:{self.id}", "manifest", dict(self._manifest))

    # ------------------------------------------------------------- identity
    @property
    def url(self) -> str:
        """Location-invariant logical URL (single-level namespace, §4 cap. 3)."""
        return f"du://{self.id}"

    @property
    def state(self) -> str:
        return self._store.hget(f"du:{self.id}", "state", DUState.NEW)

    @property
    def locations(self) -> List[str]:
        """Pilot-Data ids currently holding a full replica."""
        return list(self._store.hget(f"du:{self.id}", "locations", []))

    @property
    def manifest(self) -> Dict[str, int]:
        return dict(self._manifest)

    @property
    def size(self) -> int:
        return sum(self._manifest.values())

    @property
    def affinity(self) -> Optional[str]:
        return self.description.affinity

    @property
    def locations_version(self) -> int:
        with self._lock:
            return self._loc_version

    def checksum(self, relpath: str) -> int:
        return self._checksums[relpath]

    # ----------------------------------------------------------- mutation
    def add_file(self, relpath: str, data: bytes) -> None:
        """Add a file to a not-yet-sealed DU (application-level hierarchical
        namespace: ``relpath`` may contain '/')."""
        with self._lock:
            if self._sealed:
                raise RuntimeError(
                    f"{self.url} is immutable (sealed); create a new DU instead"
                )
            if relpath.startswith("/") or ".." in relpath.split("/"):
                raise ValueError(f"bad DU-relative path {relpath!r}")
            self._files[relpath] = bytes(data)
            self._manifest[relpath] = len(data)
            self._checksums[relpath] = zlib.crc32(data)
            self._store.hset(f"du:{self.id}", "manifest", dict(self._manifest))

    def seal(self) -> None:
        with self._lock:
            self._sealed = True

    @property
    def sealed(self) -> bool:
        return self._sealed

    # -------------------------------------------------------- content access
    def read(self, relpath: str) -> bytes:
        """Read file content from local staging buffer (pre-seal) — replica
        reads go through PilotData.fetch_du_file."""
        with self._lock:
            if relpath not in self._files:
                raise KeyError(f"{self.url} has no staged copy of {relpath!r}")
            return self._files[relpath]

    def iter_files(self):
        with self._lock:
            return list(self._files.items())

    def drop_local_buffer(self) -> None:
        """Release the in-process staging buffer once replicas exist (the DU
        content then lives only in Pilot-Data backends)."""
        with self._lock:
            if not self.locations:
                raise RuntimeError("refusing to drop buffer with no replica")
            self._files = {}

    # ----------------------------------------------------------- state mgmt
    def _set_state(self, state: str) -> None:
        self._store.hset(f"du:{self.id}", "state", state)

    def _add_location(self, pd_id: str) -> None:
        with self._lock:
            locs = self.locations
            if pd_id not in locs:
                locs.append(pd_id)
                self._loc_version += 1
                self._store.hset(f"du:{self.id}", "locations", locs)
            self._set_state(DUState.READY)
            self._sealed = True

    def _remove_location(self, pd_id: str) -> None:
        with self._lock:
            locs = [l for l in self.locations if l != pd_id]
            self._loc_version += 1
            self._store.hset(f"du:{self.id}", "locations", locs)

    def wait(self, timeout: float = 30.0) -> str:
        """Block until the DU reaches a terminal-or-ready state."""
        import time

        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            s = self.state
            if s in (DUState.READY, DUState.FAILED, DUState.DELETED):
                return s
            time.sleep(0.005)
        return self.state

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"<DataUnit {self.url} state={self.state} files={len(self._manifest)} "
            f"bytes={self.size} replicas={len(self.locations)}>"
        )


def partition_du(
    du: DataUnit,
    n_parts: int,
    store: CoordinationStore,
    name: Optional[str] = None,
) -> List[DataUnit]:
    """Partition a DU's files round-robin into ``n_parts`` new DUs.

    Paper §4.1 usage mode 3: "Support common data processing patterns, such
    as data-partitioning, parallel processing and output gathering" — files
    are the partitioning granularity, matching the BWA read-file splits of
    §6.3.
    """
    if n_parts <= 0:
        raise ValueError("n_parts must be positive")
    files = du.iter_files()
    if not files:
        raise RuntimeError(f"{du.url}: no local buffer to partition from")
    parts: List[DataUnit] = []
    base = name or du.description.name or du.id
    for i in range(n_parts):
        desc = DataUnitDescription(
            name=f"{base}.part{i}", affinity=du.description.affinity
        )
        parts.append(DataUnit(desc, store))
    for idx, (relpath, data) in enumerate(sorted(files)):
        parts[idx % n_parts].add_file(relpath, data)
    return parts


def merge_dus(
    dus: List[DataUnit], store: CoordinationStore, name: str = "merged"
) -> DataUnit:
    """Gather pattern: merge several DUs' files into one new DU (output
    gathering)."""
    desc = DataUnitDescription(name=name)
    out = DataUnit(desc, store)
    for du in dus:
        for relpath, data in du.iter_files():
            out.add_file(f"{du.id}/{relpath}", data)
    return out
