"""Data-Unit: the paper's primary data abstraction (§4.3.2).

"A DU is defined as an immutable container for a logical group of 'affine'
data files ... completely decoupled from its physical location and can be
stored in different kinds of backends ... Replicas of a DU can reside in
different Pilot-Data."

Key semantics implemented here:
  * logical identity: a DU has a location-invariant URL ``du://<id>`` that
    stays valid for its whole lifetime ("a simple and useful notion of
    distributed logical location that from an application's perspective is
    invariant over the lifetime");
  * an application-level hierarchical namespace *within* the DU (relative
    file paths), independent of the backend's namespace (object stores are
    flat — the adaptor encodes);
  * immutability after seal: files can be added while the DU is NEW; once
    sealed (first successful staging), mutation raises.  The seal is
    persisted in the coordination store, so *remote* clients attached to
    the same store observe immutability too;
  * **chunk manifest**: the DU's logical content (files concatenated in
    sorted-relpath order) is split into fixed-size chunks with per-chunk
    checksums; files map onto contiguous byte (and therefore chunk)
    ranges.  The chunk is the granularity of the *physical* layer —
    Pilot-Data hold chunk sets, transfers move chunks, and partial
    replicas are first-class — while the logical API (``du://`` URL, file
    namespace, immutability) is untouched;
  * replica set: ``locations`` lists the Pilot-Data holding a FULL replica
    (every chunk); ``chunk_holders`` exposes the per-PD chunk sets
    (including partial holders).  All state is mirrored in the
    coordination store so any client can resolve the DU from anywhere
    (the "distributed namespace").
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import threading
import zlib
from typing import Dict, Iterable, List, Optional, Tuple

from .coordination import CoordinationStore

#: physical chunk size (bytes).  Small enough that the multi-MB simulated
#: datasets of the benchmarks split into dozens of chunks (so striping has
#: parallelism to exploit), large enough that checksum bookkeeping stays
#: negligible for the KB-scale DUs the tests use.
DEFAULT_CHUNK_SIZE = 64 * 1024


class DUState:
    NEW = "New"
    PENDING = "Pending"  # staging to first PD in flight
    READY = "Ready"  # >= 1 full replica materialized; sealed
    #: every replica was lost (pilot churn) and the runtime is rebuilding
    #: the content — by re-ingesting the local buffer or by re-running the
    #: recorded producer CU (lineage recomputation); consumers re-park on
    #: the DU until it re-seals
    RECOVERING = "Recovering"
    FAILED = "Failed"
    DELETED = "Deleted"


_ids = itertools.count()
_ids_lock = threading.Lock()


def _next_id(prefix: str) -> str:
    with _ids_lock:
        return f"{prefix}-{next(_ids):06d}"


@dataclasses.dataclass(frozen=True)
class ChunkInfo:
    """One fixed-size slice of the DU's canonical byte stream."""

    index: int
    size: int
    checksum: int  # crc32 of the chunk's bytes


@dataclasses.dataclass
class DataUnitDescription:
    """JSON-able description (paper: DUD objects 'defined in the JSON
    format')."""

    name: str = ""
    #: initial content: relative path -> bytes
    files: Dict[str, bytes] = dataclasses.field(default_factory=dict)
    #: affinity constraint label (subtree of the topology) or None
    affinity: Optional[str] = None
    #: size hint for placement when content is produced later (output DUs)
    size_hint: int = 0
    #: physical chunking granularity for this DU's replicas
    chunk_size: int = DEFAULT_CHUNK_SIZE
    #: minimum number of live FULL replicas the runtime keeps for this DU;
    #: the ReplicaManager re-replicates (chunk-striped, failure-domain-
    #: aware) whenever pilot churn drops holdings below this
    replication_factor: int = 1
    #: streaming mode: the producer publishes chunks incrementally (ordered
    #: ``published`` prefix events on the store stream) and consumers may be
    #: released on a chunk *prefix* instead of the seal
    streaming: bool = False
    #: readiness threshold for streaming consumers: release waiters once
    #: this many chunks are published (``first_k_chunks`` mode)
    ready_chunks: int = 1
    #: alternative threshold as a fraction of the expected chunk count
    #: (derived from ``size_hint``/``chunk_size``); overrides ``ready_chunks``
    #: when set and a size hint is available
    ready_fraction: Optional[float] = None
    #: owning tenant (multi-tenant QoS: sandbox-byte quotas and
    #: tenant-aware eviction); "default" = unlimited/neutral
    tenant: str = "default"

    def to_json(self) -> Dict:
        return {
            "name": self.name,
            "tenant": self.tenant,
            "files": sorted(self.files),
            "affinity": self.affinity,
            "size_hint": self.size_hint,
            "chunk_size": self.chunk_size,
            "replication_factor": self.replication_factor,
            "streaming": self.streaming,
            "ready_chunks": self.ready_chunks,
            "ready_fraction": self.ready_fraction,
        }

    def resolved_ready_chunks(self) -> int:
        """The readiness threshold in whole chunks (``ready_fraction`` is
        resolved against the expected chunk count from ``size_hint``)."""
        if self.ready_fraction is not None and self.size_hint > 0:
            expected = max(1, math.ceil(self.size_hint / self.chunk_size))
            return max(1, math.ceil(self.ready_fraction * expected))
        return max(1, int(self.ready_chunks))


class DataUnit:
    """A logical, immutable, replicable group of files."""

    def __init__(
        self,
        description: DataUnitDescription,
        store: CoordinationStore,
        du_id: Optional[str] = None,
    ):
        if description.chunk_size <= 0:
            raise ValueError("chunk_size must be positive")
        self.id = du_id or _next_id("du")
        self.description = description
        self._store = store
        self._lock = threading.RLock()
        self._files: Dict[str, bytes] = dict(description.files)
        self._manifest: Dict[str, int] = {
            k: len(v) for k, v in self._files.items()
        }
        self._checksums: Dict[str, int] = {
            k: zlib.crc32(v) for k, v in self._files.items()
        }
        #: streaming flag is immutable for the DU's lifetime — cache it so
        #: the hot chunking paths never round-trip through the store
        self._streaming = bool(description.streaming)
        #: canonical stream order.  Sealed-at-once DUs use sorted-relpath
        #: order (deterministic regardless of insertion order); streaming
        #: DUs use *append* order — chunk ``i`` must be final the moment it
        #: is published, which sorted order cannot guarantee.
        self._file_order: List[str] = sorted(self._files)
        #: chunk table is recomputed lazily after mutations (adding N files
        #: would otherwise re-chunk the whole stream N times)
        self._chunks: List[ChunkInfo] = []
        self._file_ranges: Dict[str, Tuple[int, int]] = {}
        #: sorted (stream offset, relpath) pairs for chunk_data bisection
        self._file_offsets: List[Tuple[int, str]] = []
        self._offset_keys: List[int] = []
        self._chunks_dirty = True
        #: bumped on every replica/chunk-set change; replica-resolution
        #: caches key their entries on (du id, this counter) and so
        #: self-invalidate
        self._loc_version = 0
        prior = store.hgetall(f"du:{self.id}") if du_id is not None else {}
        if prior.get("state") is not None:
            # Re-attach to an existing DU record (reconnect semantics): the
            # store is authoritative — adopt its manifest/chunks/seal
            # instead of resetting them, so a second client's handle cannot
            # wipe the persisted seal or the replica bookkeeping.
            if self._files:
                if prior.get("sealed", False):
                    raise RuntimeError(
                        f"du://{self.id} is sealed; cannot re-create it "
                        f"with new content"
                    )
            else:
                description.chunk_size = prior.get(
                    "chunk_size", description.chunk_size
                )
                description.replication_factor = prior.get(
                    "replication_factor", description.replication_factor
                )
                self._manifest = dict(prior.get("manifest", {}))
                self._checksums = dict(prior.get("checksums", {}))
                self._streaming = bool(prior.get("streaming", False))
                description.streaming = self._streaming
                self._file_order = list(
                    prior.get("file_order", None) or sorted(self._manifest)
                )
                self._chunks = [
                    ChunkInfo(index=i, size=s, checksum=c)
                    for i, (s, c) in enumerate(prior.get("chunks", []))
                ]
                self._compute_file_ranges()
                self._chunks_dirty = False
            return
        store.hset(f"du:{self.id}", "state", DUState.NEW)
        store.hset(f"du:{self.id}", "name", description.name)
        store.hset(f"du:{self.id}", "affinity", description.affinity)
        # tenant is read store-side (eviction ordering, byte accounting,
        # transfer attribution) so no live handle is ever required
        store.hset(f"du:{self.id}", "tenant", description.tenant)
        store.hset(f"du:{self.id}", "locations", [])
        store.hset(f"du:{self.id}", "manifest", dict(self._manifest))
        store.hset(f"du:{self.id}", "checksums", dict(self._checksums))
        store.hset(f"du:{self.id}", "sealed", False)
        store.hset(f"du:{self.id}", "chunk_size", description.chunk_size)
        store.hset(
            f"du:{self.id}", "replication_factor",
            description.replication_factor,
        )
        if self._streaming:
            store.hset(f"du:{self.id}", "streaming", True)
            store.hset(f"du:{self.id}", "published", 0)
            store.hset(
                f"du:{self.id}", "ready_chunks",
                description.resolved_ready_chunks(),
            )
            store.hset(f"du:{self.id}", "file_order", list(self._file_order))
        self._ensure_chunks()

    # ------------------------------------------------------------- identity
    @property
    def url(self) -> str:
        """Location-invariant logical URL (single-level namespace, §4 cap. 3)."""
        return f"du://{self.id}"

    @property
    def state(self) -> str:
        return self._store.hget(f"du:{self.id}", "state", DUState.NEW)

    @property
    def locations(self) -> List[str]:
        """Pilot-Data ids currently holding a FULL replica (every chunk).

        Partial holders — PDs with some but not all chunks — are visible
        through :meth:`chunk_holders` instead.
        """
        return list(self._store.hget(f"du:{self.id}", "locations", []))

    @property
    def manifest(self) -> Dict[str, int]:
        return dict(self._manifest)

    @property
    def size(self) -> int:
        return sum(self._manifest.values())

    @property
    def affinity(self) -> Optional[str]:
        return self.description.affinity

    @property
    def locations_version(self) -> int:
        with self._lock:
            return self._loc_version

    @property
    def replication_factor(self) -> int:
        return int(
            self._store.hget(
                f"du:{self.id}", "replication_factor",
                self.description.replication_factor,
            )
        )

    def checksum(self, relpath: str) -> int:
        return self._checksums[relpath]

    # ------------------------------------------------------------- chunking
    def _order(self) -> List[str]:
        """Relpaths in canonical stream order: append order for streaming
        DUs (published chunk prefixes must stay byte-stable), sorted
        otherwise."""
        if self._streaming:
            return list(self._file_order)
        return sorted(self._manifest)

    def _compute_file_ranges(self) -> None:
        """(Re)derive per-file byte ranges + the bisection index from the
        manifest (called under the lock or during construction)."""
        ranges: Dict[str, Tuple[int, int]] = {}
        offsets: List[Tuple[int, str]] = []
        off = 0
        for rel in self._order():
            n = self._manifest[rel]
            ranges[rel] = (off, off + n)
            offsets.append((off, rel))
            off += n
        self._file_ranges = ranges
        self._file_offsets = offsets
        self._offset_keys = [o for o, _ in offsets]

    def _ensure_chunks(self) -> None:
        """Recompute the chunk table from the canonical stream (files
        concatenated in sorted-relpath order) and mirror it to the store."""
        with self._lock:
            if not self._chunks_dirty:
                return
            csize = self.description.chunk_size
            self._compute_file_ranges()
            chunks: List[ChunkInfo] = []
            stream = b"".join(
                self._files.get(rel, b"") for rel in self._order()
            )
            for i in range(0, len(stream), csize):
                piece = stream[i : i + csize]
                chunks.append(
                    ChunkInfo(
                        index=i // csize,
                        size=len(piece),
                        checksum=zlib.crc32(piece),
                    )
                )
            self._chunks = chunks
            self._chunks_dirty = False
            self._store.hset(
                f"du:{self.id}",
                "chunks",
                [[c.size, c.checksum] for c in chunks],
            )

    @property
    def chunk_size(self) -> int:
        return self.description.chunk_size

    @property
    def chunks(self) -> List[ChunkInfo]:
        self._ensure_chunks()
        with self._lock:
            return list(self._chunks)

    @property
    def n_chunks(self) -> int:
        self._ensure_chunks()
        with self._lock:
            return len(self._chunks)

    def chunk_data(self, index: int) -> bytes:
        """Bytes of one chunk, sliced out of the local staging buffer."""
        import bisect

        self._ensure_chunks()
        with self._lock:
            if index < 0 or index >= len(self._chunks):
                raise IndexError(f"{self.url} has no chunk {index}")
            if not self._files and self._manifest:
                raise RuntimeError(
                    f"{self.url}: local buffer dropped; read chunks from a replica"
                )
            csize = self.description.chunk_size
            start, end = index * csize, index * csize + self._chunks[index].size
            # bisect to the first file overlapping the chunk's byte range
            # (a linear scan from file 0 per chunk would make staging
            # O(n_chunks × n_files))
            fi = max(0, bisect.bisect_right(self._offset_keys, start) - 1)
            out = bytearray()
            for lo, rel in self._file_offsets[fi:]:
                if lo >= end:
                    break
                data = self._files[rel]
                hi = lo + len(data)
                if hi > start:
                    out += data[max(0, start - lo) : end - lo]
            return bytes(out)

    def file_range(self, relpath: str) -> Tuple[int, int]:
        """Byte range [start, end) of ``relpath`` in the canonical stream."""
        self._ensure_chunks()
        with self._lock:
            if relpath not in self._file_ranges:
                raise KeyError(f"{self.url} has no file {relpath!r}")
            return self._file_ranges[relpath]

    def chunks_for_file(self, relpath: str) -> List[int]:
        """Chunk indices covering ``relpath`` (empty file → empty list)."""
        start, end = self.file_range(relpath)
        if start == end:
            return []
        csize = self.description.chunk_size
        return list(range(start // csize, (end - 1) // csize + 1))

    # ------------------------------------------------------ chunk holdings
    def chunk_holders(self) -> Dict[str, List[int]]:
        """PD id -> sorted chunk indices held there (partial AND full)."""
        raw = self._store.hgetall(f"du:{self.id}:chunks")
        return {pd: list(idx) for pd, idx in raw.items()}

    def chunks_at(self, pd_id: str) -> List[int]:
        return list(self._store.hget(f"du:{self.id}:chunks", pd_id, []))

    def _add_chunks(self, pd_id: str, indices: Iterable[int]) -> None:
        """Register chunks held by ``pd_id``; promotes the PD into
        ``locations`` once it covers every chunk.  A first physical replica
        (even partial) seals the DU — and the seal is written to the store
        so every client observes it.

        Streaming DUs are the exception: chunk registrations arrive *while
        the producer is still writing*, so they must neither seal the DU
        nor promote a momentarily-complete holder to ``locations``/Ready
        (the chunk table is still growing — "complete" is not final until
        the producer calls :meth:`seal`)."""
        self._ensure_chunks()
        with self._lock:
            held = set(self._store.hget(f"du:{self.id}:chunks", pd_id, []))
            held.update(int(i) for i in indices)
            self._loc_version += 1
            self._store.hset(
                f"du:{self.id}:chunks", pd_id, sorted(held)
            )
            live_stream = self._streaming and not self.sealed
            if len(held) >= len(self._chunks) and not live_stream:
                locs = self.locations
                if pd_id not in locs:
                    locs.append(pd_id)
                    self._store.hset(f"du:{self.id}", "locations", locs)
                self._set_state(DUState.READY)
            if not live_stream:
                self.seal()

    def _add_location(self, pd_id: str) -> None:
        """Register a full replica at ``pd_id`` (all chunks at once)."""
        self._add_chunks(pd_id, range(self.n_chunks))

    def _drop_chunks(self, pd_id: str, indices: Iterable[int]) -> None:
        """Unregister chunks evicted from ``pd_id`` (quota eviction / cache
        demotion).  The location version bumps so resolve/estimate caches
        invalidate, and a holder that no longer covers every chunk is
        demoted from ``locations`` back to a partial holder.  The seal is
        untouched: eviction drops *redundant* replicas, never content."""
        dropped = set(int(i) for i in indices)
        if not dropped:
            return
        self._ensure_chunks()
        with self._lock:
            held = set(self._store.hget(f"du:{self.id}:chunks", pd_id, []))
            held -= dropped
            self._loc_version += 1
            if held:
                self._store.hset(f"du:{self.id}:chunks", pd_id, sorted(held))
            else:
                self._store.hdel(f"du:{self.id}:chunks", pd_id)
            if len(held) < len(self._chunks):
                locs = self.locations
                if pd_id in locs:
                    locs = [loc for loc in locs if loc != pd_id]
                    self._store.hset(f"du:{self.id}", "locations", locs)

    def _remove_location(self, pd_id: str) -> None:
        with self._lock:
            locs = [loc for loc in self.locations if loc != pd_id]
            self._loc_version += 1
            self._store.hset(f"du:{self.id}", "locations", locs)
            self._store.hdel(f"du:{self.id}:chunks", pd_id)

    def has_full_coverage(self) -> bool:
        """True iff the union of all registered holders (full AND partial)
        still covers every chunk — i.e. a full replica can be rebuilt by
        striping, no lineage recomputation needed."""
        self._ensure_chunks()
        held: set = set()
        for idxs in self.chunk_holders().values():
            held.update(idxs)
        with self._lock:
            return len(held) >= len(self._chunks)

    def begin_recovery(self) -> None:
        """All replicas of this sealed DU were lost: reopen it for a
        producer re-run (lineage recomputation).

        Clears every holding, un-seals the DU and parks it in
        ``Recovering`` — consumers submitted against it gate on the
        re-seal exactly like they gated on the first materialization.
        Assumes the producer is deterministic (re-runs rewrite the same
        logical content)."""
        with self._lock:
            self._loc_version += 1
            self._store.hset(f"du:{self.id}", "locations", [])
            for pd_id in list(self._store.hgetall(f"du:{self.id}:chunks")):
                self._store.hdel(f"du:{self.id}:chunks", pd_id)
            self._store.hset(f"du:{self.id}", "sealed", False)
            if self._streaming:
                # the re-run streams from scratch; a stale published prefix
                # would release prefix-mode consumers against zero holders
                self._store.hset(f"du:{self.id}", "published", 0)
            self._store.hset(f"du:{self.id}", "state", DUState.RECOVERING)

    # ----------------------------------------------------------- mutation
    def add_file(self, relpath: str, data: bytes) -> None:
        """Add a file to a not-yet-sealed DU (application-level hierarchical
        namespace: ``relpath`` may contain '/')."""
        with self._lock:
            if self.sealed:
                raise RuntimeError(
                    f"{self.url} is immutable (sealed); create a new DU instead"
                )
            if relpath.startswith("/") or ".." in relpath.split("/"):
                raise ValueError(f"bad DU-relative path {relpath!r}")
            if relpath not in self._manifest:
                self._file_order.append(relpath)
            self._files[relpath] = bytes(data)
            self._manifest[relpath] = len(data)
            self._checksums[relpath] = zlib.crc32(data)
            self._chunks_dirty = True
            self._store.hset(f"du:{self.id}", "manifest", dict(self._manifest))
            self._store.hset(f"du:{self.id}", "checksums", dict(self._checksums))
            if self._streaming:
                self._store.hset(
                    f"du:{self.id}", "file_order", list(self._file_order)
                )

    def seal(self) -> None:
        """Freeze the DU.  Persisted to the coordination store so remote
        clients attached to the same store observe immutability too.

        For a streaming DU the seal is the producer's end-of-stream marker:
        it publishes the final chunk count (the trailing partial chunk only
        becomes visible here) and retro-promotes any holder that already
        covers every chunk — promotions that were deliberately withheld
        while the chunk table was still growing."""
        with self._lock:
            self._ensure_chunks()
            if not self._store.hget(f"du:{self.id}", "sealed", False):
                self._store.hset(f"du:{self.id}", "sealed", True)
                if self._streaming:
                    self._promote_full_holders()
                    self.publish_prefix(len(self._chunks))

    @property
    def sealed(self) -> bool:
        return bool(self._store.hget(f"du:{self.id}", "sealed", False))

    # ----------------------------------------------------------- streaming
    @property
    def streaming(self) -> bool:
        """True if this DU publishes chunks incrementally (stream mode)."""
        return self._streaming

    @property
    def published(self) -> int:
        """Length of the published chunk prefix (monotone while one
        producer attempt streams; reset only by :meth:`reset_stream`)."""
        return int(self._store.hget(f"du:{self.id}", "published", 0) or 0)

    @property
    def stream_threshold(self) -> int:
        """Published-chunk count at which prefix-mode consumers release."""
        return int(self._store.hget(f"du:{self.id}", "ready_chunks", 1) or 1)

    def available_chunks(self) -> int:
        """Chunks a consumer may read *now*: the published prefix while the
        stream is live, every chunk once sealed."""
        if not self._streaming or self.sealed:
            return self.n_chunks
        return min(self.published, self.n_chunks)

    def publishable_chunks(self) -> int:
        """Chunks whose bytes are final and may be published: all of them
        once sealed, only the *full* chunks mid-stream (the trailing
        partial chunk may still grow as files are appended)."""
        self._ensure_chunks()
        with self._lock:
            if self.sealed:
                return len(self._chunks)
            return self.size // self.description.chunk_size

    def publish_prefix(self, upto: int) -> int:
        """Advance the published prefix to ``upto`` chunks (monotone; the
        ``published`` hset is the ordered chunk-availability event consumers
        and the DependencyTracker react to).  Returns the new prefix."""
        if not self._streaming:
            raise RuntimeError(f"{self.url} is not a streaming DU")
        with self._lock:
            upto = min(int(upto), self.publishable_chunks())
            cur = self.published
            if upto > cur:
                self._store.hset(f"du:{self.id}", "published", upto)
                return upto
            return cur

    def _promote_full_holders(self) -> None:
        """Promote every holder covering the (now final) chunk table into
        ``locations`` and mark the DU Ready — called under the lock at
        stream seal."""
        n = len(self._chunks)
        locs = self.locations
        changed = False
        for pd_id, idxs in self.chunk_holders().items():
            if len(set(idxs)) >= n and pd_id not in locs:
                locs.append(pd_id)
                changed = True
        if changed or (locs and self.state != DUState.READY):
            self._loc_version += 1
            self._store.hset(f"du:{self.id}", "locations", locs)
        if locs:
            self._set_state(DUState.READY)

    def reset_stream(self) -> None:
        """Roll a *failed producer attempt's* partial stream back to zero
        so the retry re-streams from a clean slate (exactly-once: a losing
        attempt must leave no published chunks behind).

        Clears the logical content (manifest/checksums/file order/chunk
        table) and the published prefix.  Holder registrations for stale
        chunk indices are dropped with a loc-version bump; like lineage
        recomputation, this assumes the producer is deterministic."""
        if not self._streaming:
            raise RuntimeError(f"{self.url} is not a streaming DU")
        with self._lock:
            if self.sealed:
                raise RuntimeError(f"{self.url} is sealed; cannot reset")
            self._files = {}
            self._manifest = {}
            self._checksums = {}
            self._file_order = []
            self._chunks = []
            self._chunks_dirty = True
            self._loc_version += 1
            self._store.hset(f"du:{self.id}", "manifest", {})
            self._store.hset(f"du:{self.id}", "checksums", {})
            self._store.hset(f"du:{self.id}", "file_order", [])
            self._store.hset(f"du:{self.id}", "chunks", [])
            for pd_id in list(self._store.hgetall(f"du:{self.id}:chunks")):
                self._store.hdel(f"du:{self.id}:chunks", pd_id)
            self._store.hset(f"du:{self.id}", "published", 0)

    # -------------------------------------------------------- content access
    def read(self, relpath: str) -> bytes:
        """Read file content from local staging buffer (pre-seal) — replica
        reads go through PilotData.fetch_du_file."""
        with self._lock:
            if relpath not in self._files:
                raise KeyError(f"{self.url} has no staged copy of {relpath!r}")
            return self._files[relpath]

    def iter_files(self):
        with self._lock:
            return list(self._files.items())

    def drop_local_buffer(self) -> None:
        """Release the in-process staging buffer once replicas exist (the DU
        content then lives only in Pilot-Data backends)."""
        with self._lock:
            if not self.locations:
                raise RuntimeError("refusing to drop buffer with no replica")
            self._files = {}

    # ----------------------------------------------------------- state mgmt
    def _set_state(self, state: str) -> None:
        self._store.hset(f"du:{self.id}", "state", state)

    def wait(self, timeout: float = 30.0) -> str:
        """Block until the DU reaches a terminal-or-ready state.

        Event-driven: waits on the coordination store's keyspace
        notifications for this DU's state field (poll only as a coarse
        fallback against missed events)."""
        terminal = (DUState.READY, DUState.FAILED, DUState.DELETED)
        return self._store.wait_field(
            f"du:{self.id}",
            "state",
            lambda s: s in terminal,
            timeout=timeout,
            default=DUState.NEW,
        )

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"<DataUnit {self.url} state={self.state} files={len(self._manifest)} "
            f"bytes={self.size} chunks={self.n_chunks} replicas={len(self.locations)}>"
        )


def partition_du(
    du: DataUnit,
    n_parts: int,
    store: CoordinationStore,
    name: Optional[str] = None,
) -> List[DataUnit]:
    """Partition a DU's files round-robin into ``n_parts`` new DUs.

    Paper §4.1 usage mode 3: "Support common data processing patterns, such
    as data-partitioning, parallel processing and output gathering" — files
    are the partitioning granularity, matching the BWA read-file splits of
    §6.3.
    """
    if n_parts <= 0:
        raise ValueError("n_parts must be positive")
    files = du.iter_files()
    if not files:
        raise RuntimeError(f"{du.url}: no local buffer to partition from")
    parts: List[DataUnit] = []
    base = name or du.description.name or du.id
    for i in range(n_parts):
        desc = DataUnitDescription(
            name=f"{base}.part{i}",
            affinity=du.description.affinity,
            chunk_size=du.description.chunk_size,
        )
        parts.append(DataUnit(desc, store))
    for idx, (relpath, data) in enumerate(sorted(files)):
        parts[idx % n_parts].add_file(relpath, data)
    return parts


def merge_dus(
    dus: List[DataUnit], store: CoordinationStore, name: str = "merged"
) -> DataUnit:
    """Gather pattern: merge several DUs' files into one new DU (output
    gathering).

    The merge propagates the sources' affinity when they all agree (a
    gather of pod0-affine partitions is itself pod0-affine), and verifies
    each copied file against the source's recorded checksum — a corrupted
    staging buffer fails loudly instead of silently poisoning the merged
    DU.  A source whose local buffer was dropped (content only in
    Pilot-Data backends) cannot be merged from here and raises.
    """
    affinities = {du.description.affinity for du in dus}
    affinity = affinities.pop() if len(affinities) == 1 else None
    desc = DataUnitDescription(name=name, affinity=affinity)
    out = DataUnit(desc, store)
    for du in dus:
        files = dict(du.iter_files())
        if du.manifest and not files:
            raise RuntimeError(
                f"{du.url}: local buffer dropped; re-stage from a replica "
                f"before merging"
            )
        for relpath, data in files.items():
            if zlib.crc32(data) != du.checksum(relpath):
                raise RuntimeError(
                    f"{du.url}/{relpath}: checksum mismatch during merge"
                )
            out.add_file(f"{du.id}/{relpath}", data)
    return out
