"""Transfer service: DU movement between Pilot-Data, with a virtual clock.

Every physical transfer is costed against the topology (bottleneck bandwidth
along the tree path) *and* the two backend profiles (a GridFTP-class backend
moves bytes faster than an SSH-class one at equal topology distance — that
is exactly the spread the paper measures in Fig. 7).  Real bytes move
immediately (container-local); the *simulated* duration is recorded per
transfer so benchmarks reproduce the paper's timing analysis
deterministically.

Co-location resolves to a **logical link** (§4.3.2: "In the best case, the
Pilot-Data of the dependent DUs is co-located on the same resource as the
CU, i.e. the data can be directly accessed via a logical filesystem link").
A PD is visible to a pilot when the PD's affinity label is an ancestor of
(or equal to) the pilot's location — e.g. a shared filesystem registered at
the site level is linkable from every host in the site.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import List, Optional, Tuple

from .affinity import match_affinity
from .cost_model import cheapest_replica
from .data_unit import DataUnit
from .pilot import PilotData, RuntimeContext


@dataclasses.dataclass
class TransferRecord:
    du_id: str
    src_pd: Optional[str]  # None == initial staging from the submission host
    dst_pd: str
    nbytes: int
    sim_seconds: float
    wall_seconds: float
    linked: bool = False  # True == logical link, no bytes moved
    t_submit_sim: float = 0.0


class TransferService:
    """Moves/links DUs between PDs and accounts simulated T_X/T_S/T_R."""

    def __init__(self, ctx: RuntimeContext):
        self.ctx = ctx
        ctx.transfer_service = self
        self._records: List[TransferRecord] = []
        self._lock = threading.Lock()
        self._sim_now = 0.0

    # ------------------------------------------------------------- costing
    def simulated_transfer_time(
        self, nbytes: int, src: PilotData, dst: PilotData
    ) -> float:
        topo = self.ctx.topology
        lat = (
            topo.latency(src.affinity, dst.affinity)
            + src.backend.profile.op_latency
            + dst.backend.profile.op_latency
        )
        bw = min(
            topo.bandwidth(src.affinity, dst.affinity),
            src.backend.profile.bandwidth,
            dst.backend.profile.bandwidth,
        )
        xfer = 0.0 if bw == float("inf") else nbytes / bw
        return lat + xfer + dst.backend.profile.register_latency

    def simulated_ingest_time(self, nbytes: int, dst: PilotData) -> float:
        """Initial staging from the submission host into a PD (paper Fig. 7:
        T_S per backend).  When the runtime declares a submission-host
        topology label, the transfer is additionally bottlenecked by that
        uplink (a gateway node's WAN link, like the paper's GW68)."""
        p = dst.backend.profile
        bw = p.bandwidth
        lat = p.op_latency
        sub = self.ctx.submission_label
        if sub is not None:
            bw = min(bw, self.ctx.topology.bandwidth(sub, dst.affinity))
            lat += self.ctx.topology.latency(sub, dst.affinity)
        return lat + nbytes / bw + p.register_latency

    # ------------------------------------------------------------ mechanics
    def is_linkable(self, pd: PilotData, location: str) -> bool:
        """Can a pilot at ``location`` access ``pd`` without a transfer?"""
        return match_affinity(pd.affinity, location) or pd.affinity == location

    def record(self, rec: TransferRecord) -> None:
        with self._lock:
            self._records.append(rec)
            self._sim_now += rec.sim_seconds

    def records(self) -> List[TransferRecord]:
        with self._lock:
            return list(self._records)

    def total_sim_seconds(self) -> float:
        with self._lock:
            return sum(r.sim_seconds for r in self._records)

    def reset_records(self) -> None:
        with self._lock:
            self._records.clear()

    def ingest(self, du: DataUnit, dst: PilotData) -> float:
        """Initial staging of a freshly-described DU into its first PD."""
        t0 = time.monotonic()
        nbytes = dst.put_du(du)
        sim = self.simulated_ingest_time(nbytes, dst)
        self.ctx.sleep_sim(sim)
        self.record(
            TransferRecord(
                du_id=du.id,
                src_pd=None,
                dst_pd=dst.id,
                nbytes=nbytes,
                sim_seconds=sim,
                wall_seconds=time.monotonic() - t0,
            )
        )
        return sim

    def replicate(self, du: DataUnit, src: PilotData, dst: PilotData) -> float:
        """Physically replicate a DU between two PDs; returns simulated T_X."""
        t0 = time.monotonic()
        nbytes = dst.copy_du_from(du, src)
        sim = self.simulated_transfer_time(nbytes, src, dst)
        self.ctx.sleep_sim(sim)
        self.record(
            TransferRecord(
                du_id=du.id,
                src_pd=src.id,
                dst_pd=dst.id,
                nbytes=nbytes,
                sim_seconds=sim,
                wall_seconds=time.monotonic() - t0,
            )
        )
        return sim

    # --------------------------------------------------------- staging API
    def resolve_access(
        self, du: DataUnit, location: str
    ) -> Tuple[Optional[PilotData], bool]:
        """Find the best replica of ``du`` for a pilot at ``location``.

        Returns (pd, linked): ``linked`` means zero-cost direct access; else
        ``pd`` is the cheapest replica to transfer from (None if the DU has
        no replica anywhere — caller falls back to the DU's local buffer).
        """
        replicas = [
            self.ctx.lookup(pd_id)
            for pd_id in du.locations
            if pd_id in self.ctx.objects
        ]
        for pd in replicas:
            if self.is_linkable(pd, location):
                return pd, True
        if not replicas:
            return None, False
        by_label = {pd.affinity: pd for pd in replicas}
        best_label, _ = cheapest_replica(
            du.size, list(by_label), location, self.ctx.topology
        )
        return by_label[best_label], False

    def stage_in(
        self,
        du: DataUnit,
        sandbox: PilotData,
        location: str,
        use_cache: bool = True,
    ) -> float:
        """Make ``du`` available to a CU sandbox at ``location``; returns
        simulated staging seconds (0.0 for a logical link).

        ``use_cache=False`` models the paper's PD-less naive mode: every CU
        re-stages into its own sandbox — the full transfer cost is charged
        each time and the sandbox never becomes a replica."""
        if not use_cache:
            already = sandbox.has_du(du.id)
            if du.locations:
                pd, _ = self.resolve_access(du, location)
                sim = self.simulated_transfer_time(du.size, pd, sandbox)
                if not already:
                    sandbox.copy_du_from(du, pd, register=False)
            else:
                sim = self.simulated_ingest_time(du.size, sandbox)
                if not already:
                    sandbox.put_du(du, register=False)
            self.ctx.sleep_sim(sim)
            self.record(
                TransferRecord(
                    du_id=du.id,
                    src_pd=None,
                    dst_pd=sandbox.id,
                    nbytes=du.size,
                    sim_seconds=sim,
                    wall_seconds=0.0,
                )
            )
            return sim
        if sandbox.has_du(du.id):
            return 0.0  # pilot-level cache hit (data-diffusion-style reuse)
        pd, linked = self.resolve_access(du, location)
        if linked:
            self.record(
                TransferRecord(
                    du_id=du.id,
                    src_pd=pd.id,
                    dst_pd=sandbox.id,
                    nbytes=0,
                    sim_seconds=0.0,
                    wall_seconds=0.0,
                    linked=True,
                )
            )
            return 0.0
        if pd is not None:
            return self.replicate(du, pd, sandbox)
        # No replica yet: ingest straight from the DU's local buffer
        # (submission-machine pull — the paper's "naive" scenarios 1-2).
        return self.ingest(du, sandbox)
