"""Transfer service: DU movement between Pilot-Data, with a virtual clock.

Every physical transfer is costed against the topology (bottleneck bandwidth
along the tree path) *and* the two backend profiles (a GridFTP-class backend
moves bytes faster than an SSH-class one at equal topology distance — that
is exactly the spread the paper measures in Fig. 7).  Real bytes move
immediately (container-local); the *simulated* duration is recorded per
transfer so benchmarks reproduce the paper's timing analysis
deterministically.

Co-location resolves to a **logical link** (§4.3.2: "In the best case, the
Pilot-Data of the dependent DUs is co-located on the same resource as the
CU, i.e. the data can be directly accessed via a logical filesystem link").
A PD is visible to a pilot when the PD's affinity label is an ancestor of
(or equal to) the pilot's location — e.g. a shared filesystem registered at
the site level is linkable from every host in the site.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .affinity import match_affinity
from .cost_model import cheapest_replica
from .data_unit import DataUnit
from .pilot import PilotData, RuntimeContext


@dataclasses.dataclass
class TransferRecord:
    du_id: str
    src_pd: Optional[str]  # None == initial staging from the submission host
    dst_pd: str
    nbytes: int
    sim_seconds: float
    wall_seconds: float
    linked: bool = False  # True == logical link, no bytes moved
    t_submit_sim: float = 0.0
    #: wall clock (time.monotonic) at transfer start — the pipelining
    #: overlap proof reads these against CU run windows
    wall_start: float = 0.0
    #: True when issued by the async scheduler's prefetch pipeline
    pipelined: bool = False
    #: shared id for the per-DU shares of one batched bulk transfer
    batch_id: Optional[str] = None


class TransferService:
    """Moves/links DUs between PDs and accounts simulated T_X/T_S/T_R."""

    def __init__(self, ctx: RuntimeContext):
        self.ctx = ctx
        ctx.transfer_service = self
        self._records: List[TransferRecord] = []
        self._lock = threading.Lock()
        self._sim_now = 0.0
        #: (du_id, dst_pd_id) -> Event for the transfer currently moving
        #: that DU there; concurrent stagers wait instead of re-paying
        self._inflight: Dict[Tuple[str, str], threading.Event] = {}
        #: replica-resolution caches: (du_id, location) -> (loc_version, …)
        self._resolve_cache: Dict[Tuple[str, str], Tuple[int, Optional[str], bool]] = {}
        self._estimate_cache: Dict[Tuple[str, str], Tuple[int, float]] = {}
        self.cache_hits = 0
        self.cache_misses = 0
        self._batch_ids = itertools.count()

    # ------------------------------------------------------------- costing
    def simulated_transfer_time(
        self, nbytes: int, src: PilotData, dst: PilotData
    ) -> float:
        topo = self.ctx.topology
        lat = (
            topo.latency(src.affinity, dst.affinity)
            + src.backend.profile.op_latency
            + dst.backend.profile.op_latency
        )
        bw = min(
            topo.bandwidth(src.affinity, dst.affinity),
            src.backend.profile.bandwidth,
            dst.backend.profile.bandwidth,
        )
        xfer = 0.0 if bw == float("inf") else nbytes / bw
        return lat + xfer + dst.backend.profile.register_latency

    def simulated_ingest_time(self, nbytes: int, dst: PilotData) -> float:
        """Initial staging from the submission host into a PD (paper Fig. 7:
        T_S per backend).  When the runtime declares a submission-host
        topology label, the transfer is additionally bottlenecked by that
        uplink (a gateway node's WAN link, like the paper's GW68)."""
        p = dst.backend.profile
        bw = p.bandwidth
        lat = p.op_latency
        sub = self.ctx.submission_label
        if sub is not None:
            bw = min(bw, self.ctx.topology.bandwidth(sub, dst.affinity))
            lat += self.ctx.topology.latency(sub, dst.affinity)
        return lat + nbytes / bw + p.register_latency

    # ------------------------------------------------------------ mechanics
    def is_linkable(self, pd: PilotData, location: str) -> bool:
        """Can a pilot at ``location`` access ``pd`` without a transfer?"""
        return match_affinity(pd.affinity, location) or pd.affinity == location

    def record(self, rec: TransferRecord) -> None:
        with self._lock:
            self._records.append(rec)
            self._sim_now += rec.sim_seconds

    def records(self) -> List[TransferRecord]:
        with self._lock:
            return list(self._records)

    def total_sim_seconds(self) -> float:
        with self._lock:
            return sum(r.sim_seconds for r in self._records)

    def reset_records(self) -> None:
        with self._lock:
            self._records.clear()

    def ingest(self, du: DataUnit, dst: PilotData) -> float:
        """Initial staging of a freshly-described DU into its first PD."""
        t0 = time.monotonic()
        nbytes = dst.put_du(du)
        sim = self.simulated_ingest_time(nbytes, dst)
        self.ctx.sleep_sim(sim)
        self.record(
            TransferRecord(
                du_id=du.id,
                src_pd=None,
                dst_pd=dst.id,
                nbytes=nbytes,
                sim_seconds=sim,
                wall_seconds=time.monotonic() - t0,
                wall_start=t0,
            )
        )
        return sim

    def replicate(self, du: DataUnit, src: PilotData, dst: PilotData) -> float:
        """Physically replicate a DU between two PDs; returns simulated T_X."""
        t0 = time.monotonic()
        nbytes = dst.copy_du_from(du, src)
        sim = self.simulated_transfer_time(nbytes, src, dst)
        self.ctx.sleep_sim(sim)
        self.record(
            TransferRecord(
                du_id=du.id,
                src_pd=src.id,
                dst_pd=dst.id,
                nbytes=nbytes,
                sim_seconds=sim,
                wall_seconds=time.monotonic() - t0,
                wall_start=t0,
            )
        )
        return sim

    # --------------------------------------------------------- staging API
    def resolve_access(
        self, du: DataUnit, location: str
    ) -> Tuple[Optional[PilotData], bool]:
        """Find the best replica of ``du`` for a pilot at ``location``.

        Returns (pd, linked): ``linked`` means zero-cost direct access; else
        ``pd`` is the cheapest replica to transfer from (None if the DU has
        no replica anywhere — caller falls back to the DU's local buffer).

        Resolutions are memoized per (DU, location) keyed on the DU's
        replica-set version, so the repeated ``cheapest_replica`` scans of
        a hot DU collapse to a dict hit until a replica is added/removed.
        """
        ver = du.locations_version
        key = (du.id, location)
        with self._lock:
            hit = self._resolve_cache.get(key)
            if hit is not None and hit[0] == ver:
                self.cache_hits += 1
                pd_id, linked = hit[1], hit[2]
                if pd_id is None:
                    return None, False
                if pd_id in self.ctx.objects:
                    return self.ctx.lookup(pd_id), linked
            self.cache_misses += 1
        pd, linked = self._resolve_uncached(du, location)
        with self._lock:
            self._resolve_cache[key] = (ver, pd.id if pd else None, linked)
        return pd, linked

    def _resolve_uncached(
        self, du: DataUnit, location: str
    ) -> Tuple[Optional[PilotData], bool]:
        replicas = [
            self.ctx.lookup(pd_id)
            for pd_id in du.locations
            if pd_id in self.ctx.objects
        ]
        for pd in replicas:
            if self.is_linkable(pd, location):
                return pd, True
        if not replicas:
            return None, False
        by_label = {pd.affinity: pd for pd in replicas}
        best_label, _ = cheapest_replica(
            du.size, list(by_label), location, self.ctx.topology
        )
        return by_label[best_label], False

    def estimate_stage_cost(
        self, du: DataUnit, location: str, sandbox: PilotData
    ) -> float:
        """Simulated cost of making ``du`` available at ``location`` (0 for
        linkable replicas), memoized like :meth:`resolve_access`."""
        ver = du.locations_version
        key = (du.id, location)
        with self._lock:
            hit = self._estimate_cache.get(key)
            if hit is not None and hit[0] == ver:
                self.cache_hits += 1
                return hit[1]
            self.cache_misses += 1
        pd, linked = self.resolve_access(du, location)
        if linked:
            cost = 0.0
        elif pd is not None:
            _, cost = cheapest_replica(
                du.size, [pd.affinity], location, self.ctx.topology
            )
        else:
            cost = self.simulated_ingest_time(du.size, sandbox)
        with self._lock:
            self._estimate_cache[key] = (ver, cost)
        return cost

    def stage_in(
        self,
        du: DataUnit,
        sandbox: PilotData,
        location: str,
        use_cache: bool = True,
    ) -> float:
        """Make ``du`` available to a CU sandbox at ``location``; returns
        simulated staging seconds (0.0 for a logical link).

        Concurrent stagers of the same (DU, sandbox) pair — e.g. two CU
        slots sharing an input, or an agent racing the async scheduler's
        prefetch — deduplicate onto one physical transfer: the first caller
        pays and records it, later callers block until the bytes land and
        charge nothing.

        ``use_cache=False`` models the paper's PD-less naive mode: every CU
        re-stages into its own sandbox — the full transfer cost is charged
        each time and the sandbox never becomes a replica."""
        if not use_cache:
            t0 = time.monotonic()
            already = sandbox.has_du(du.id)
            if du.locations:
                pd, _ = self.resolve_access(du, location)
                sim = self.simulated_transfer_time(du.size, pd, sandbox)
                if not already:
                    sandbox.copy_du_from(du, pd, register=False)
            else:
                sim = self.simulated_ingest_time(du.size, sandbox)
                if not already:
                    sandbox.put_du(du, register=False)
            self.ctx.sleep_sim(sim)
            self.record(
                TransferRecord(
                    du_id=du.id,
                    src_pd=None,
                    dst_pd=sandbox.id,
                    nbytes=du.size,
                    sim_seconds=sim,
                    wall_seconds=0.0,
                    wall_start=t0,
                )
            )
            return sim
        key = (du.id, sandbox.id)
        while True:
            if sandbox.has_du(du.id):
                return 0.0  # pilot-level cache hit (data-diffusion reuse)
            with self._lock:
                other = self._inflight.get(key)
                if other is None:
                    done = threading.Event()
                    self._inflight[key] = done
                    break
            # Another thread is moving this DU here: wait, then re-check
            # (loop handles both completion and a failed first attempt).
            other.wait(timeout=120.0)
        try:
            pd, linked = self.resolve_access(du, location)
            if linked:
                self.record(
                    TransferRecord(
                        du_id=du.id,
                        src_pd=pd.id,
                        dst_pd=sandbox.id,
                        nbytes=0,
                        sim_seconds=0.0,
                        wall_seconds=0.0,
                        wall_start=time.monotonic(),
                        linked=True,
                    )
                )
                return 0.0
            if pd is not None:
                return self.replicate(du, pd, sandbox)
            # No replica yet: ingest straight from the DU's local buffer
            # (submission-machine pull — the paper's "naive" scenarios 1-2).
            return self.ingest(du, sandbox)
        finally:
            with self._lock:
                self._inflight.pop(key, None)
            done.set()

    # ---------------------------------------------------- pipelined staging
    def claim_bulk(
        self, dus: Sequence[DataUnit], sandbox: PilotData
    ) -> List[Tuple[DataUnit, threading.Event]]:
        """Mark the transferable subset of ``dus`` as in flight toward
        ``sandbox`` and return the claims.  The async scheduler calls this
        BEFORE the CU is pushed to a pilot queue, so an agent that claims
        the CU immediately still dedups onto the prefetch instead of racing
        it with its own per-DU transfers.  Pass the result to
        :meth:`stage_in_bulk` (or :meth:`release_claims` on abort)."""
        claimed: List[Tuple[DataUnit, threading.Event]] = []
        for du in dus:
            if du.size <= 0 or sandbox.has_du(du.id):
                continue
            key = (du.id, sandbox.id)
            with self._lock:
                if key in self._inflight:
                    continue
                done = threading.Event()
                self._inflight[key] = done
            claimed.append((du, done))
        return claimed

    def release_claims(
        self,
        claimed: List[Tuple[DataUnit, threading.Event]],
        sandbox: PilotData,
    ) -> None:
        for du, done in claimed:
            with self._lock:
                self._inflight.pop((du.id, sandbox.id), None)
            done.set()

    def stage_in_bulk(
        self,
        dus: Sequence[DataUnit],
        sandbox: PilotData,
        location: str,
        pipelined: bool = False,
        batch_id: Optional[str] = None,
        claimed: Optional[List[Tuple[DataUnit, threading.Event]]] = None,
        on_complete=None,
    ) -> float:
        """Stage several DUs into one sandbox, batching same-source
        transfers into ONE costed bulk transfer (a single per-request setup
        latency + catalog registration amortized over the batch, instead of
        paying both per DU).  Per-DU records carry byte-proportional shares
        of the bulk cost under a shared ``batch_id``.

        DUs already present, already in flight (another stager owns them),
        or empty are skipped.  Returns total simulated seconds."""
        if claimed is None:
            claimed = self.claim_bulk(dus, sandbox)
        try:
            todo: List[DataUnit] = [du for du, _ in claimed]
            if not todo:
                return 0.0
            bid = batch_id or f"batch-{next(self._batch_ids)}"
            # Resolve every DU, splitting links from per-source groups.
            groups: Dict[Optional[str], List[Tuple[DataUnit, Optional[PilotData]]]] = {}
            total_sim = 0.0
            for du in todo:
                pd, linked = self.resolve_access(du, location)
                if linked:
                    self.record(
                        TransferRecord(
                            du_id=du.id,
                            src_pd=pd.id,
                            dst_pd=sandbox.id,
                            nbytes=0,
                            sim_seconds=0.0,
                            wall_seconds=0.0,
                            wall_start=time.monotonic(),
                            linked=True,
                            pipelined=pipelined,
                            batch_id=bid,
                        )
                    )
                    continue
                groups.setdefault(pd.id if pd else None, []).append((du, pd))
            for src_id, items in groups.items():
                t0 = time.monotonic()
                src = items[0][1]
                # Materialize, then cost/record whatever actually moved —
                # if a copy fails mid-group, the DUs already in the sandbox
                # are still charged and recorded (no free transfers).
                moved: List[DataUnit] = []
                try:
                    for du, _ in items:
                        if src is None:
                            sandbox.put_du(du)
                        else:
                            sandbox.copy_du_from(du, src)
                        moved.append(du)
                finally:
                    moved_bytes = sum(du.size for du in moved)
                    if moved:
                        if src is None:
                            sim = self.simulated_ingest_time(
                                moved_bytes, sandbox
                            )
                        else:
                            sim = self.simulated_transfer_time(
                                moved_bytes, src, sandbox
                            )
                        self.ctx.sleep_sim(sim)
                        wall = time.monotonic() - t0
                        for du in moved:
                            share = (
                                sim * (du.size / moved_bytes)
                                if moved_bytes
                                else 0.0
                            )
                            self.record(
                                TransferRecord(
                                    du_id=du.id,
                                    src_pd=src_id,
                                    dst_pd=sandbox.id,
                                    nbytes=du.size,
                                    sim_seconds=share,
                                    wall_seconds=wall,
                                    wall_start=t0,
                                    pipelined=pipelined,
                                    batch_id=bid,
                                )
                            )
                        total_sim += sim
            if on_complete is not None:
                # runs BEFORE claims release, so anyone woken by the
                # release already sees the completion's side effects
                on_complete(total_sim)
            return total_sim
        finally:
            self.release_claims(claimed, sandbox)

    def lookup_dus(self, cu) -> List[DataUnit]:
        """Resolve a CU's input DU ids to live objects (unknown ids skipped)."""
        dus: List[DataUnit] = []
        for du_id in cu.description.input_data:
            try:
                dus.append(self.ctx.lookup(du_id))
            except KeyError:
                continue
        return dus

    def prefetch_inputs(self, cu, pilot, claimed=None) -> float:
        """Async-scheduler hook: bulk-stage a CU's input DUs into its
        assigned pilot's sandbox ahead of execution, so staging overlaps
        the pilot's current compute.  Records the attributed simulated
        seconds on the CU (``sim_prefetch_s``).

        With ``claimed`` provided (the scheduler claimed before pushing the
        CU), the work-list comes entirely from the claims — no re-lookup."""
        dus = [] if claimed is not None else self.lookup_dus(cu)

        def attribute(sim: float) -> None:
            if sim > 0.0:
                self.ctx.store.hset(f"cu:{cu.id}", "sim_prefetch_s", sim)

        return self.stage_in_bulk(
            dus,
            pilot.sandbox,
            pilot.affinity,
            pipelined=True,
            batch_id=f"prefetch-{cu.id}",
            claimed=claimed,
            on_complete=attribute,
        )
