"""Transfer service: chunk-granular DU movement between Pilot-Data, with a
virtual clock.

Every physical transfer is costed against the topology (bottleneck bandwidth
along the tree path) *and* the two backend profiles (a GridFTP-class backend
moves bytes faster than an SSH-class one at equal topology distance — that
is exactly the spread the paper measures in Fig. 7).  Real bytes move
immediately (container-local); the *simulated* duration is recorded per
transfer so benchmarks reproduce the paper's timing analysis
deterministically.

The unit of transfer is the **chunk** (see ``DataUnit.chunks``): a stage-in
computes the destination's *missing* chunk set, assigns each missing chunk
to its cheapest current holder — full or partial replica alike — with a
greedy list-schedule that balances bytes across sources, and then moves the
per-source groups as parallel striped waves: the simulated duration is the
``max`` over the per-source group times (like ``replicate_group``'s rounds),
so a cold stage-in stripes from N partial holders instead of serializing
from one.

Co-location resolves to a **logical link** (§4.3.2: "In the best case, the
Pilot-Data of the dependent DUs is co-located on the same resource as the
CU, i.e. the data can be directly accessed via a logical filesystem link").
A PD is visible to a pilot when the PD's affinity label is an ancestor of
(or equal to) the pilot's location — e.g. a shared filesystem registered at
the site level is linkable from every host in the site.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..backends import KeyNotFound
from .affinity import match_affinity
from .cost_model import cheapest_replica
from .data_unit import DataUnit
from .pilot import PilotData, RuntimeContext

#: re-plans allowed when an eviction races a planned fetch before raising
MAX_REPLANS = 3


@dataclasses.dataclass
class TransferRecord:
    du_id: str
    src_pd: Optional[str]  # None == initial staging from the submission host
    dst_pd: str
    nbytes: int
    sim_seconds: float
    wall_seconds: float
    linked: bool = False  # True == logical link, no bytes moved
    t_submit_sim: float = 0.0
    #: wall clock (time.monotonic) at transfer start — the pipelining
    #: overlap proof reads these against CU run windows
    wall_start: float = 0.0
    #: True when issued by the async scheduler's prefetch pipeline
    pipelined: bool = False
    #: shared id for the per-DU shares of one batched bulk transfer
    batch_id: Optional[str] = None
    #: chunks moved by this record (0 for links / legacy whole-DU records)
    chunks: int = 0
    #: True when this record is one wave of a multi-source striped fetch
    striped: bool = False


@dataclasses.dataclass
class _FetchGroup:
    """One striped wave: a set of chunks pulled from one source."""

    src: Optional[PilotData]  # None == DU local buffer (submission host)
    indices: List[int]
    nbytes: int
    sim_seconds: float


#: one stager's claim on a set of chunks moving toward one sandbox
_Claim = Tuple[DataUnit, Set[int], threading.Event]


class TransferService:
    """Moves/links DU chunks between PDs and accounts simulated T_X/T_S/T_R."""

    def __init__(self, ctx: RuntimeContext):
        self.ctx = ctx
        ctx.transfer_service = self
        self._records: List[TransferRecord] = []
        self._lock = threading.Lock()
        self._sim_now = 0.0
        #: (du_id, dst_pd_id) -> list of (chunk set, Event) claims currently
        #: in flight; the dedup is chunk-granular — a second stager only
        #: fetches chunks nobody else claimed and *waits* for the rest
        self._inflight: Dict[
            Tuple[str, str], List[Tuple[Set[int], threading.Event]]
        ] = {}
        #: replica-resolution caches, keyed on the DU's location version
        #: (bumped on every chunk-holding change, so partial-replica
        #: progress invalidates them too)
        self._resolve_cache: Dict[Tuple[str, str], Tuple[int, Optional[str], bool]] = {}
        self._estimate_cache: Dict[Tuple[str, str, str], Tuple[int, float]] = {}
        self.cache_hits = 0
        self.cache_misses = 0
        self._batch_ids = itertools.count()
        #: PDs purged after pilot death — never planned as a source or
        #: served from a cached resolution again
        self._dead_pds: Set[str] = set()
        #: (src_pd_id, du_id) -> count of in-flight fetches reading from
        #: that source; quota eviction skips leased holdings so a planned
        #: copy's source cannot vanish mid-transfer
        self._src_leases: Dict[Tuple[str, str], int] = {}
        #: monotonic stamp for du:access records (tier access statistics)
        self._access_seq = itertools.count(1)
        #: per-tenant transfer attribution (sim seconds / bytes moved),
        #: keyed by the DU's owning tenant — fairness accounting
        self._tenant_sim: Dict[str, float] = {}
        self._tenant_bytes: Dict[str, int] = {}

    # ------------------------------------------------------------- costing
    def simulated_transfer_time(
        self, nbytes: int, src: PilotData, dst: PilotData
    ) -> float:
        topo = self.ctx.topology
        lat = (
            topo.latency(src.affinity, dst.affinity)
            + src.backend.profile.op_latency
            + dst.backend.profile.op_latency
        )
        bw = min(
            topo.bandwidth(src.affinity, dst.affinity),
            src.backend.profile.bandwidth,
            dst.backend.profile.bandwidth,
        )
        xfer = 0.0 if bw == float("inf") else nbytes / bw
        return lat + xfer + dst.backend.profile.register_latency

    def simulated_ingest_time(self, nbytes: int, dst: PilotData) -> float:
        """Initial staging from the submission host into a PD (paper Fig. 7:
        T_S per backend).  When the runtime declares a submission-host
        topology label, the transfer is additionally bottlenecked by that
        uplink (a gateway node's WAN link, like the paper's GW68)."""
        p = dst.backend.profile
        bw = p.bandwidth
        lat = p.op_latency
        sub = self.ctx.submission_label
        if sub is not None:
            bw = min(bw, self.ctx.topology.bandwidth(sub, dst.affinity))
            lat += self.ctx.topology.latency(sub, dst.affinity)
        return lat + nbytes / bw + p.register_latency

    # ------------------------------------------------------------ mechanics
    def is_linkable(self, pd: PilotData, location: str) -> bool:
        """Can a pilot at ``location`` access ``pd`` without a transfer?"""
        return match_affinity(pd.affinity, location) or pd.affinity == location

    def record(self, rec: TransferRecord) -> None:
        # attribute the transfer to the DU's owning tenant (store-side
        # lookup BEFORE taking our lock — no store op under a held lock)
        tenant = (
            self.ctx.store.hget(f"du:{rec.du_id}", "tenant") or "default"
        )
        with self._lock:
            self._records.append(rec)
            self._sim_now += rec.sim_seconds
            self._tenant_sim[tenant] = (
                self._tenant_sim.get(tenant, 0.0) + rec.sim_seconds
            )
            self._tenant_bytes[tenant] = (
                self._tenant_bytes.get(tenant, 0) + rec.nbytes
            )

    def records(self) -> List[TransferRecord]:
        with self._lock:
            return list(self._records)

    def per_tenant_transfer(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant transfer totals ({tenant: {"sim_seconds", "bytes"}})
        — the fairness accounting the multi-tenant bench reports on."""
        with self._lock:
            return {
                t: {
                    "sim_seconds": self._tenant_sim.get(t, 0.0),
                    "bytes": float(self._tenant_bytes.get(t, 0)),
                }
                for t in set(self._tenant_sim) | set(self._tenant_bytes)
            }

    def total_sim_seconds(self) -> float:
        with self._lock:
            return sum(r.sim_seconds for r in self._records)

    def reset_records(self) -> None:
        with self._lock:
            self._records.clear()

    def purge_pd(self, pd_id: str) -> None:
        """A PD died (its pilot failed): stop using it immediately.

        Releases every in-flight staging claim destined for it (stagers
        waiting on those claims wake and re-plan against live holders
        instead of blocking out their full timeout) and evicts every
        cached resolution/estimate that names it as source or destination.
        Location-version bumps from the holdings purge invalidate the rest.
        """
        with self._lock:
            self._dead_pds.add(pd_id)
            for key in list(self._inflight):
                if key[1] != pd_id:
                    continue
                for _, done in self._inflight.pop(key):
                    done.set()
            self._resolve_cache = {
                k: v for k, v in self._resolve_cache.items()
                if v[1] != pd_id
            }
            self._estimate_cache = {
                k: v for k, v in self._estimate_cache.items()
                if k[2] != pd_id
            }

    def is_dead(self, pd_id: str) -> bool:
        with self._lock:
            return pd_id in self._dead_pds

    # ------------------------------------------------- eviction interlocks
    def _lease_sources(self, du: DataUnit, groups: List["_FetchGroup"]) -> None:
        with self._lock:
            for g in groups:
                if g.src is not None:
                    k = (g.src.id, du.id)
                    self._src_leases[k] = self._src_leases.get(k, 0) + 1

    def _unlease_sources(self, du: DataUnit, groups: List["_FetchGroup"]) -> None:
        with self._lock:
            for g in groups:
                if g.src is not None:
                    k = (g.src.id, du.id)
                    n = self._src_leases.get(k, 0) - 1
                    if n > 0:
                        self._src_leases[k] = n
                    else:
                        self._src_leases.pop(k, None)

    def source_leased(self, pd_id: str, du_id: str) -> bool:
        """True while an in-flight fetch reads this DU from this PD — the
        TierManager must not evict the holding out from under it."""
        with self._lock:
            return self._src_leases.get((pd_id, du_id), 0) > 0

    def inflight_chunks(self, du_id: str, dst_pd_id: str) -> Set[int]:
        """Chunks currently claimed by stagers moving toward ``dst_pd_id``
        — eviction must not drop what a transfer is about to account."""
        with self._lock:
            out: Set[int] = set()
            for idxs, _ in self._inflight.get((du_id, dst_pd_id), []):
                out |= idxs
            return out

    def _note_access(self, du: DataUnit, location: str) -> None:
        """Publish one access record for the tier layer's frequency/recency
        statistics (rides the store's existing event stream; the TierManager
        folds it in asynchronously off the store dispatcher — readers that
        need up-to-date stats barrier via ``store.flush_events()``)."""
        self.ctx.store.hset(
            "du:access",
            du.id,
            {"location": location, "n": next(self._access_seq)},
        )

    def ingest(self, du: DataUnit, dst: PilotData) -> float:
        """Initial staging of a freshly-described DU into its first PD."""
        t0 = time.monotonic()
        nbytes = dst.put_du(du)
        sim = self.simulated_ingest_time(nbytes, dst)
        self.ctx.sleep_sim(sim)
        self.record(
            TransferRecord(
                du_id=du.id,
                src_pd=None,
                dst_pd=dst.id,
                nbytes=nbytes,
                sim_seconds=sim,
                wall_seconds=time.monotonic() - t0,
                wall_start=t0,
                chunks=du.n_chunks,
            )
        )
        return sim

    def replicate(self, du: DataUnit, src: PilotData, dst: PilotData) -> float:
        """Physically replicate a DU from ``src`` (a full replica) into
        ``dst``; only the chunks ``dst`` is missing move (delta transfer).
        Returns simulated T_X."""
        t0 = time.monotonic()
        n_missing = len(dst.missing_chunks(du))
        nbytes = dst.copy_du_from(du, src)
        sim = self.simulated_transfer_time(nbytes, src, dst)
        self.ctx.sleep_sim(sim)
        self.record(
            TransferRecord(
                du_id=du.id,
                src_pd=src.id,
                dst_pd=dst.id,
                nbytes=nbytes,
                sim_seconds=sim,
                wall_seconds=time.monotonic() - t0,
                wall_start=t0,
                chunks=n_missing,
            )
        )
        return sim

    def replicate_chunks(
        self,
        du: DataUnit,
        src: PilotData,
        dst: PilotData,
        indices: Sequence[int],
    ) -> float:
        """Move an explicit chunk subset from one holder to another — the
        disperse phase of chunk-striped group replication."""
        todo = [i for i in indices if i in set(src.chunks_held(du.id))]
        if not todo:
            return 0.0
        t0 = time.monotonic()
        nbytes = dst.copy_chunks_from(du, src, todo)
        if nbytes == 0:
            return 0.0
        sim = self.simulated_transfer_time(nbytes, src, dst)
        self.ctx.sleep_sim(sim)
        self.record(
            TransferRecord(
                du_id=du.id,
                src_pd=src.id,
                dst_pd=dst.id,
                nbytes=nbytes,
                sim_seconds=sim,
                wall_seconds=time.monotonic() - t0,
                wall_start=t0,
                chunks=len(todo),
                striped=True,
            )
        )
        return sim

    # -------------------------------------------------- chunk fetch planning
    def _chunk_sources(
        self, du: DataUnit, dst: PilotData
    ) -> List[Tuple[PilotData, Set[int]]]:
        """Live PDs (full or partial holders) usable as chunk sources."""
        out: List[Tuple[PilotData, Set[int]]] = []
        with self._lock:
            dead = set(self._dead_pds)
        for pd_id, idxs in sorted(du.chunk_holders().items()):
            if pd_id == dst.id or pd_id in dead or pd_id not in self.ctx.objects:
                continue
            pd = self.ctx.lookup(pd_id)
            if idxs:
                out.append((pd, set(idxs)))
        return out

    def plan_chunk_fetch(
        self,
        du: DataUnit,
        dst: PilotData,
        location: str,
        only: Optional[Set[int]] = None,
    ) -> List[_FetchGroup]:
        """Assign each missing chunk to a source, balancing finish times.

        Greedy list-schedule: chunks (in index order, deterministic) go to
        the holder whose per-source stripe would finish earliest after
        taking the chunk — so a nearby partial holder absorbs chunks until
        its stripe is as long as the next-best source's.  Chunks held by
        nobody fall back to the DU's local buffer (submission-host ingest).
        """
        missing = dst.missing_chunks(du)
        if du.streaming and not du.sealed:
            # live stream: only the published prefix is fetchable — an
            # unpublished chunk must never fall back to the orphan path
            # (its bytes may still change under the producer's pen)
            avail = du.available_chunks()
            missing = [i for i in missing if i < avail]
        if only is not None:
            missing = [i for i in missing if i in only]
        if not missing:
            return []
        chunks = du.chunks
        holders = self._chunk_sources(du, dst)
        topo = self.ctx.topology
        lat: Dict[str, float] = {}
        bw: Dict[str, float] = {}
        for pd, _ in holders:
            lat[pd.id] = (
                topo.latency(pd.affinity, location)
                + pd.backend.profile.op_latency
                + dst.backend.profile.op_latency
                + dst.backend.profile.register_latency
            )
            bw[pd.id] = min(
                topo.bandwidth(pd.affinity, location),
                pd.backend.profile.bandwidth,
                dst.backend.profile.bandwidth,
            )
        assigned: Dict[str, List[int]] = {pd.id: [] for pd, _ in holders}
        stripe_bytes: Dict[str, int] = {pd.id: 0 for pd, _ in holders}
        orphans: List[int] = []
        for i in missing:
            best: Optional[PilotData] = None
            best_t = float("inf")
            for pd, held in holders:
                if i not in held:
                    continue
                nb = stripe_bytes[pd.id] + chunks[i].size
                t = lat[pd.id] + (0.0 if bw[pd.id] == float("inf") else nb / bw[pd.id])
                if t < best_t:
                    best, best_t = pd, t
            if best is None:
                orphans.append(i)
            else:
                assigned[best.id].append(i)
                stripe_bytes[best.id] += chunks[i].size
        groups: List[_FetchGroup] = []
        for pd, _ in holders:
            if not assigned[pd.id]:
                continue
            nb = stripe_bytes[pd.id]
            xfer = 0.0 if bw[pd.id] == float("inf") else nb / bw[pd.id]
            groups.append(
                _FetchGroup(
                    src=pd,
                    indices=assigned[pd.id],
                    nbytes=nb,
                    # same lat/bw terms as the greedy assignment above, so
                    # the planned wave time IS the charged wave time — and
                    # both honor ``location`` (which may differ from the
                    # destination PD's own affinity label)
                    sim_seconds=lat[pd.id] + xfer,
                )
            )
        if orphans:
            nb = sum(chunks[i].size for i in orphans)
            groups.append(
                _FetchGroup(
                    src=None,
                    indices=orphans,
                    nbytes=nb,
                    sim_seconds=self.simulated_ingest_time(nb, dst),
                )
            )
        return groups

    def _fetch_groups(
        self,
        du: DataUnit,
        dst: PilotData,
        groups: List[_FetchGroup],
        register: bool = True,
        pipelined: bool = False,
        batch_id: Optional[str] = None,
        location: Optional[str] = None,
        _depth: int = 0,
    ) -> float:
        """Materialize planned striped waves; simulated time is the max
        over the (parallel) per-source waves.

        Sources are leased for the duration (quota eviction skips leased
        holdings); if an eviction still raced the plan — the source lost
        the chunks between planning and leasing — the missing remainder is
        **re-planned** against the current holders instead of failing the
        stage-in."""
        if not groups:
            return 0.0
        where = location or dst.affinity
        striped = len(groups) > 1
        done_sims: List[float] = []
        raced: Set[int] = set()
        self._lease_sources(du, groups)
        try:
            for g in groups:
                t0 = time.monotonic()
                try:
                    if g.src is None:
                        dst.put_chunks(du, g.indices, register=register)
                    else:
                        dst.copy_chunks_from(du, g.src, g.indices, register=register)
                except (KeyError, KeyNotFound):
                    if _depth >= MAX_REPLANS:
                        raise
                    held = set(dst.chunks_held(du.id))
                    raced.update(i for i in g.indices if i not in held)
                    continue
                self.record(
                    TransferRecord(
                        du_id=du.id,
                        src_pd=g.src.id if g.src is not None else None,
                        dst_pd=dst.id,
                        nbytes=g.nbytes,
                        sim_seconds=g.sim_seconds,
                        wall_seconds=time.monotonic() - t0,
                        wall_start=t0,
                        pipelined=pipelined,
                        batch_id=batch_id,
                        chunks=len(g.indices),
                        striped=striped,
                    )
                )
                done_sims.append(g.sim_seconds)
        finally:
            self._unlease_sources(du, groups)
        sim = max(done_sims, default=0.0)
        self.ctx.sleep_sim(sim)
        if raced:
            # the repair wave runs strictly AFTER the first wave (and
            # sleeps itself, recursively), so the honest model is the sum
            replanned = self.plan_chunk_fetch(du, dst, where, only=raced)
            sim += self._fetch_groups(
                du,
                dst,
                replanned,
                register=register,
                pipelined=pipelined,
                batch_id=batch_id,
                location=where,
                _depth=_depth + 1,
            )
        return sim

    def heal_replica(
        self,
        du: DataUnit,
        dst: PilotData,
        groups: Optional[List[_FetchGroup]] = None,
    ) -> float:
        """Complete a partial replica: stripe ``dst``'s missing chunks in
        from their cheapest current holders.  Unlike :meth:`stage_in` this
        always materializes (no logical-link shortcut) — it is the heal
        phase of chunk-striped group replication, whose contract is that
        ``dst`` ends holding every chunk physically.

        ``groups`` lets the caller pre-plan against a fixed
        holdings snapshot; the replication driver plans all targets
        sequentially before executing them in parallel, so simulated T_R
        does not depend on thread interleaving (the deterministic-clock
        contract the CI regression gate relies on)."""
        if groups is None:
            groups = self.plan_chunk_fetch(du, dst, dst.affinity)
        return self._fetch_groups(du, dst, groups)

    # --------------------------------------------------------- staging API
    def resolve_access(
        self, du: DataUnit, location: str
    ) -> Tuple[Optional[PilotData], bool]:
        """Find the best FULL replica of ``du`` for a pilot at ``location``.

        Returns (pd, linked): ``linked`` means zero-cost direct access; else
        ``pd`` is the cheapest full replica to transfer from (None if the DU
        has no full replica anywhere — callers then stripe from partial
        holders and/or the DU's local buffer).

        Resolutions are memoized per (DU, location) keyed on the DU's
        replica-set version, so the repeated ``cheapest_replica`` scans of
        a hot DU collapse to a dict hit until a chunk holding changes.
        """
        ver = du.locations_version
        key = (du.id, location)
        with self._lock:
            hit = self._resolve_cache.get(key)
            if hit is not None and hit[0] == ver:
                self.cache_hits += 1
                pd_id, linked = hit[1], hit[2]
                if pd_id is None:
                    return None, False
                if pd_id in self.ctx.objects:
                    return self.ctx.lookup(pd_id), linked
            self.cache_misses += 1
        pd, linked = self._resolve_uncached(du, location)
        with self._lock:
            self._resolve_cache[key] = (ver, pd.id if pd else None, linked)
        return pd, linked

    def _resolve_uncached(
        self, du: DataUnit, location: str
    ) -> Tuple[Optional[PilotData], bool]:
        with self._lock:
            dead = set(self._dead_pds)
        replicas = [
            self.ctx.lookup(pd_id)
            for pd_id in du.locations
            if pd_id in self.ctx.objects and pd_id not in dead
        ]
        for pd in replicas:
            if self.is_linkable(pd, location):
                return pd, True
        if not replicas:
            return None, False
        by_label = {pd.affinity: pd for pd in replicas}
        best_label, _ = cheapest_replica(
            du.size, list(by_label), location, self.ctx.topology
        )
        return by_label[best_label], False

    def estimate_stage_cost(
        self,
        du: DataUnit,
        location: str,
        sandbox: PilotData,
        tenant: Optional[str] = None,
    ) -> float:
        """Simulated cost of making ``du`` available at ``location``: 0 for
        linkable full replicas and fully-cached sandboxes, else the striped
        multi-source fetch cost of the *missing* chunks only (max over the
        parallel per-source waves).  Memoized like :meth:`resolve_access`.

        With a ``tenant``, the cost is scaled by that tenant's share of
        the contended bandwidth (its fair-share weight over all active
        tenants' weights): competing tenants see each other's traffic in
        the placement cost model.  The scaling applies AFTER the memoized
        lookup, so the cache stays tenant-neutral (one entry per
        (du, location, sandbox), valid for every requester)."""
        ver = du.locations_version
        key = (du.id, location, sandbox.id)
        cost: Optional[float] = None
        with self._lock:
            hit = self._estimate_cache.get(key)
            if hit is not None and hit[0] == ver:
                self.cache_hits += 1
                cost = hit[1]
            else:
                self.cache_misses += 1
        if cost is None:
            _, linked = self.resolve_access(du, location)
            if linked:
                cost = 0.0
            else:
                groups = self.plan_chunk_fetch(du, sandbox, location)
                cost = max((g.sim_seconds for g in groups), default=0.0)
            with self._lock:
                self._estimate_cache[key] = (ver, cost)
        if tenant is not None and cost > 0:
            registry = getattr(self.ctx, "tenant_registry", None)
            if registry is not None:
                share = registry.bw_share(tenant)
                if share < 1.0:
                    cost = cost / max(share, 1e-9)
        return cost

    def stage_in(
        self,
        du: DataUnit,
        sandbox: PilotData,
        location: str,
        use_cache: bool = True,
        prefix: Optional[int] = None,
    ) -> float:
        """Make ``du`` available to a CU sandbox at ``location``; returns
        simulated staging seconds (0.0 for a logical link).

        For a *live streaming* DU (streaming and not yet sealed) the goal
        is the published chunk prefix — optionally capped at ``prefix``
        chunks — rather than the whole DU: the call returns once the
        sandbox holds that prefix, and the consumer re-calls as the
        producer publishes more (chunk-granular re-planning).

        Only the sandbox's *missing* chunks move, striped in parallel from
        their cheapest current holders (partial replicas included).

        The in-flight dedup is chunk-granular: concurrent stagers of the
        same (DU, sandbox) — e.g. two CU slots sharing an input, or an
        agent racing the async scheduler's prefetch — split the missing
        chunk set instead of re-paying it.  Each caller claims only the
        chunks nobody else is moving, fetches those, and *waits* for the
        claims of others, so exactly one physical transfer happens per
        chunk.

        ``use_cache=False`` models the paper's PD-less naive mode: every CU
        re-stages the whole DU into its own sandbox from one source — the
        full monolithic transfer cost is charged each time and the sandbox
        never becomes a replica."""
        if not use_cache:
            t0 = time.monotonic()
            already = sandbox.has_du(du.id)
            if du.locations:
                pd, _ = self.resolve_access(du, location)
                sim = self.simulated_transfer_time(du.size, pd, sandbox)
                if not already:
                    sandbox.copy_du_from(du, pd, register=False)
            else:
                sim = self.simulated_ingest_time(du.size, sandbox)
                if not already:
                    sandbox.put_du(du, register=False)
            self.ctx.sleep_sim(sim)
            self.record(
                TransferRecord(
                    du_id=du.id,
                    src_pd=None,
                    dst_pd=sandbox.id,
                    nbytes=du.size,
                    sim_seconds=sim,
                    wall_seconds=0.0,
                    wall_start=t0,
                    chunks=du.n_chunks,
                )
            )
            return sim
        live_stream = du.streaming and not du.sealed
        target: Optional[Set[int]] = None
        if live_stream:
            avail = du.available_chunks()
            goal = avail if prefix is None else min(prefix, avail)
            target = set(range(goal))
            if not target:
                return 0.0  # nothing published yet; caller waits and retries
        if du.n_chunks == 0 and not live_stream:
            # empty DU: register the (vacuously full) holding, move nothing
            if not sandbox.has_du(du.id):
                sandbox.put_du(du)
            return 0.0
        # one demand-access record per stage-in (hit or miss alike): the
        # TierManager's frequency/recency stats and promotion thresholds
        # ride this store event
        self._note_access(du, location)
        key = (du.id, sandbox.id)
        total_sim = 0.0
        while True:
            if target is not None:
                if target <= set(sandbox.chunks_held(du.id)):
                    return total_sim  # the requested prefix has landed
            elif sandbox.has_du(du.id):
                return total_sim  # pilot-level cache hit (data-diffusion reuse)
            pd, linked = self.resolve_access(du, location)
            if linked:
                self.record(
                    TransferRecord(
                        du_id=du.id,
                        src_pd=pd.id,
                        dst_pd=sandbox.id,
                        nbytes=0,
                        sim_seconds=0.0,
                        wall_seconds=0.0,
                        wall_start=time.monotonic(),
                        linked=True,
                    )
                )
                return total_sim
            missing = set(sandbox.missing_chunks(du))
            if target is not None:
                missing &= target
            with self._lock:
                claims = self._inflight.setdefault(key, [])
                theirs: Set[int] = set()
                for idxs, _ in claims:
                    theirs |= idxs
                mine = missing - theirs
                if mine:
                    done = threading.Event()
                    claims.append((mine, done))
                    waiting: Optional[List[threading.Event]] = None
                else:
                    # everything missing is being moved by someone else:
                    # wait for their claims to land, then re-check
                    waiting = [ev for _, ev in claims]
            if waiting is not None:
                if not waiting:
                    continue  # holdings changed mid-check; re-evaluate
                for ev in waiting:
                    ev.wait(timeout=120.0)
                continue
            try:
                groups = self.plan_chunk_fetch(du, sandbox, location, only=mine)
                if target is not None and not groups:
                    # the stream rolled back under us (failed producer
                    # attempt reset it): hand control back — the consumer
                    # re-waits on the published prefix and retries
                    return total_sim
                total_sim += self._fetch_groups(du, sandbox, groups, location=location)
            finally:
                with self._lock:
                    entries = self._inflight.get(key, [])
                    self._inflight[key] = [e for e in entries if e[1] is not done]
                    if not self._inflight[key]:
                        self._inflight.pop(key, None)
                done.set()
            # loop: either the DU is now fully held, or other stagers'
            # claims are still landing and we wait for them above

    # ---------------------------------------------------- pipelined staging
    def claim_bulk(self, dus: Sequence[DataUnit], sandbox: PilotData) -> List[_Claim]:
        """Claim the not-yet-in-flight missing chunks of ``dus`` toward
        ``sandbox`` and return the claims.  The async scheduler calls this
        BEFORE the CU is pushed to a pilot queue, so an agent that claims
        the CU immediately still dedups onto the prefetch instead of racing
        it with its own per-chunk transfers.  Pass the result to
        :meth:`stage_in_bulk` (or :meth:`release_claims` on abort)."""
        claimed: List[_Claim] = []
        for du in dus:
            if du.size <= 0 or sandbox.has_du(du.id):
                continue
            missing = set(sandbox.missing_chunks(du))
            if du.streaming and not du.sealed:
                # prefetch only what the producer has published so far; the
                # scheduler re-claims as further publish events arrive
                missing &= set(range(du.available_chunks()))
            if not missing:
                continue
            key = (du.id, sandbox.id)
            with self._lock:
                claims = self._inflight.setdefault(key, [])
                theirs: Set[int] = set()
                for idxs, _ in claims:
                    theirs |= idxs
                mine = missing - theirs
                if not mine:
                    continue
                done = threading.Event()
                claims.append((mine, done))
            claimed.append((du, mine, done))
        return claimed

    def release_claims(
        self,
        claimed: List[_Claim],
        sandbox: PilotData,
    ) -> None:
        for du, _, done in claimed:
            key = (du.id, sandbox.id)
            with self._lock:
                entries = self._inflight.get(key, [])
                self._inflight[key] = [e for e in entries if e[1] is not done]
                if not self._inflight[key]:
                    self._inflight.pop(key, None)
            done.set()

    def stage_in_bulk(
        self,
        dus: Sequence[DataUnit],
        sandbox: PilotData,
        location: str,
        pipelined: bool = False,
        batch_id: Optional[str] = None,
        claimed: Optional[List[_Claim]] = None,
        on_complete=None,
    ) -> float:
        """Stage several DUs into one sandbox, batching same-source chunk
        groups into ONE costed bulk transfer per source (a single
        per-request setup latency + catalog registration amortized over the
        batch, instead of paying both per DU) while distinct sources stripe
        in parallel (total simulated time = max over the per-source
        batches).  Per-DU records carry byte-proportional shares of their
        source batch's cost under a shared ``batch_id``.

        Chunks already present, already in flight (another stager owns
        them), or belonging to empty DUs are skipped.  Returns the
        simulated seconds of the slowest source batch."""
        if claimed is None:
            claimed = self.claim_bulk(dus, sandbox)
        try:
            if not claimed:
                return 0.0
            bid = batch_id or f"batch-{next(self._batch_ids)}"
            # Plan every DU's striped fetch, splitting links from per-source
            # groups; groups sharing a source merge into one bulk transfer.
            by_src: Dict[Optional[str], List[Tuple[DataUnit, _FetchGroup]]] = {}
            for du, mine, _ in claimed:
                src_pd, linked = self.resolve_access(du, location)
                if linked:
                    self.record(
                        TransferRecord(
                            du_id=du.id,
                            src_pd=src_pd.id if src_pd else None,
                            dst_pd=sandbox.id,
                            nbytes=0,
                            sim_seconds=0.0,
                            wall_seconds=0.0,
                            wall_start=time.monotonic(),
                            linked=True,
                            pipelined=pipelined,
                            batch_id=bid,
                        )
                    )
                    continue
                for g in self.plan_chunk_fetch(du, sandbox, location, only=mine):
                    by_src.setdefault(
                        g.src.id if g.src is not None else None, []
                    ).append((du, g))
            wave_sims: List[float] = []
            raced: List[Tuple[DataUnit, Set[int]]] = []
            for src_id, items in by_src.items():
                t0 = time.monotonic()
                src = items[0][1].src
                # Materialize, then cost/record whatever actually moved —
                # if a copy fails mid-batch, the chunks already in the
                # sandbox are still charged and recorded (no free bytes).
                moved: List[Tuple[DataUnit, _FetchGroup]] = []
                try:
                    for du, g in items:
                        self._lease_sources(du, [g])
                        try:
                            if src is None:
                                sandbox.put_chunks(du, g.indices)
                            else:
                                sandbox.copy_chunks_from(du, src, g.indices)
                        except (KeyError, KeyNotFound):
                            # eviction raced the plan: re-plan this DU's
                            # remainder against current holders below
                            held = set(sandbox.chunks_held(du.id))
                            raced.append((du, {i for i in g.indices if i not in held}))
                            continue
                        finally:
                            self._unlease_sources(du, [g])
                        moved.append((du, g))
                finally:
                    moved_bytes = sum(g.nbytes for _, g in moved)
                    if moved:
                        if src is None:
                            sim = self.simulated_ingest_time(moved_bytes, sandbox)
                        else:
                            sim = self.simulated_transfer_time(
                                moved_bytes, src, sandbox
                            )
                        wall = time.monotonic() - t0
                        for du, g in moved:
                            share = (
                                sim * (g.nbytes / moved_bytes)
                                if moved_bytes
                                else 0.0
                            )
                            self.record(
                                TransferRecord(
                                    du_id=du.id,
                                    src_pd=src_id,
                                    dst_pd=sandbox.id,
                                    nbytes=g.nbytes,
                                    sim_seconds=share,
                                    wall_seconds=wall,
                                    wall_start=t0,
                                    pipelined=pipelined,
                                    batch_id=bid,
                                    chunks=len(g.indices),
                                    striped=len(by_src) > 1,
                                )
                            )
                        wave_sims.append(sim)
            raced_sim = 0.0
            for du, missing in raced:
                if not missing:
                    continue
                replanned = self.plan_chunk_fetch(du, sandbox, location, only=missing)
                # repair fetches sleep themselves (sequentially, after the
                # batched waves) — keep them out of the parallel-wave max
                raced_sim += self._fetch_groups(
                    du,
                    sandbox,
                    replanned,
                    pipelined=pipelined,
                    batch_id=bid,
                    location=location,
                    _depth=1,
                )
            batch_sim = max(wave_sims, default=0.0)
            if batch_sim > 0.0:
                self.ctx.sleep_sim(batch_sim)
            total_sim = batch_sim + raced_sim
            if on_complete is not None:
                # runs BEFORE claims release, so anyone woken by the
                # release already sees the completion's side effects
                on_complete(total_sim)
            return total_sim
        finally:
            self.release_claims(claimed, sandbox)

    def lookup_dus(self, cu) -> List[DataUnit]:
        """Resolve a CU's input DU ids to live objects (unknown ids skipped)."""
        dus: List[DataUnit] = []
        for du_id in cu.description.input_data:
            try:
                dus.append(self.ctx.lookup(du_id))
            except KeyError:
                continue
        return dus

    def prefetch_inputs(self, cu, pilot, claimed=None) -> float:
        """Async-scheduler hook: bulk-stage a CU's missing input chunks into
        its assigned pilot's sandbox ahead of execution, so staging overlaps
        the pilot's current compute.  Records the attributed simulated
        seconds on the CU (``sim_prefetch_s``).

        With ``claimed`` provided (the scheduler claimed before pushing the
        CU), the work-list comes entirely from the claims — no re-lookup."""
        dus = [] if claimed is not None else self.lookup_dus(cu)

        def attribute(sim: float) -> None:
            if sim > 0.0:
                self.ctx.store.hset(f"cu:{cu.id}", "sim_prefetch_s", sim)

        return self.stage_in_bulk(
            dus,
            pilot.sandbox,
            pilot.affinity,
            pipelined=True,
            batch_id=f"prefetch-{cu.id}",
            claimed=claimed,
            on_complete=attribute,
        )
