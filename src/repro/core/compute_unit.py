"""Compute-Unit: the paper's task abstraction (§4.3.2).

"A CU represents a self-contained piece of work ... an application task,
i.e. a certain executable to be executed with a set of parameters and input
files."  CUs declare ``input_data`` / ``output_data`` DU dependencies; the
runtime guarantees input DUs are materialized in the CU sandbox before
execution and output files are moved to the output DUs afterwards (Fig. 5).

Executables are names resolved through a :class:`FunctionRegistry` so CU
descriptions stay JSON-able (the paper's CUDs are JSON documents shipped
through Redis) while still invoking real Python/JAX work in-process.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional

from .coordination import CoordinationStore
from .data_unit import _next_id


class CUState:
    NEW = "New"
    #: dataflow gate: some input DU is not yet sealed/first-replicated —
    #: the CU is parked until its producers materialize their outputs
    WAITING = "Waiting"
    PENDING = "Pending"  # queued (global or pilot queue)
    STAGING = "Staging"  # input DUs being materialized in the sandbox
    RUNNING = "Running"
    DONE = "Done"
    FAILED = "Failed"
    CANCELED = "Canceled"

    TERMINAL = (DONE, FAILED, CANCELED)


class FunctionRegistry:
    """Name → callable registry for CU executables."""

    def __init__(self) -> None:
        self._fns: Dict[str, Callable] = {}
        self._lock = threading.Lock()

    def register(self, name: str, fn: Optional[Callable] = None):
        if fn is None:  # decorator form

            def deco(f):
                self.register(name, f)
                return f

            return deco
        with self._lock:
            self._fns[name] = fn
        return fn

    def resolve(self, name: str) -> Callable:
        with self._lock:
            if name not in self._fns:
                raise KeyError(
                    f"executable {name!r} not registered "
                    f"(known: {sorted(self._fns)})"
                )
            return self._fns[name]


#: process-global default registry (agents resolve against this)
FUNCTIONS = FunctionRegistry()


@dataclasses.dataclass
class ComputeUnitDescription:
    """JSON-able CU description (paper's CUD)."""

    executable: str
    args: tuple = ()
    kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    input_data: List[str] = dataclasses.field(default_factory=list)  # DU ids
    output_data: List[str] = dataclasses.field(default_factory=list)  # DU ids
    cores: int = 1
    #: affinity constraint: subtree label the CU must run in, or None
    affinity: Optional[str] = None
    #: pin to a specific pilot (paper: "applications can either bind their
    #: workload directly to a Pilot ... using their own application-level
    #: scheduling")
    pilot: Optional[str] = None
    max_retries: int = 2
    #: False = paper's naive mode: re-stage inputs per CU, no replica reuse
    cache_inputs: bool = True
    #: estimated compute seconds (used by the cost model / simulator)
    est_compute_s: float = 0.0
    #: estimated simulated compute seconds for DES benchmarks
    sim_compute_s: float = 0.0
    #: owning tenant (multi-tenant QoS: admission quotas, fair-share
    #: placement, tenant-aware eviction); "default" = unlimited/neutral
    tenant: str = "default"

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["args"] = list(self.args)
        return d


@dataclasses.dataclass
class CUTimings:
    """Per-CU timing breakdown (the paper's Fig. 10 decomposition)."""

    submitted: float = 0.0
    scheduled: float = 0.0
    stage_start: float = 0.0
    stage_end: float = 0.0
    run_start: float = 0.0
    run_end: float = 0.0
    sim_stage_s: float = 0.0  # simulated T_S (virtual clock)
    sim_compute_s: float = 0.0
    #: simulated staging done AHEAD of execution by the async scheduler's
    #: prefetch pipeline (off the CU's critical path — overlapped)
    sim_prefetch_s: float = 0.0

    @property
    def t_q_task(self) -> float:  # pilot-internal queue time
        return max(0.0, self.stage_start - self.submitted)

    @property
    def t_s(self) -> float:  # wall staging time
        return max(0.0, self.stage_end - self.stage_start)

    @property
    def t_c(self) -> float:  # wall compute time
        return max(0.0, self.run_end - self.run_start)


class ComputeUnit:
    """Live handle over a submitted CU; state lives in the coordination
    store (re-connectable via its URL, §4.2)."""

    def __init__(
        self,
        description: ComputeUnitDescription,
        store: CoordinationStore,
        cu_id: Optional[str] = None,
    ):
        self.id = cu_id or _next_id("cu")
        self.description = description
        self._store = store
        self.timings = CUTimings()
        self.result: Any = None
        self.error: Optional[str] = None
        self.attempts = 0
        prior = store.hgetall(f"cu:{self.id}") if cu_id is not None else {}
        if prior.get("state") is not None:
            # Re-attach to an existing CU record (reconnect semantics, like
            # DataUnit): the store is authoritative — adopt its counters
            # instead of resetting the record from under a live workload.
            self.attempts = int(prior.get("attempts", 0))
            self.error = prior.get("error")
            return
        store.hset(f"cu:{self.id}", "state", CUState.NEW)
        store.hset(f"cu:{self.id}", "desc", description.to_json())
        store.hset(f"cu:{self.id}", "pilot", None)
        # tenant is read store-side by admission/placement/preemption so
        # they never need a live handle
        store.hset(f"cu:{self.id}", "tenant", description.tenant)
        # store-side attempt counter: orphan recovery must be able to bump
        # retries even when no live ComputeUnit handle exists (a crash-
        # looping pilot would otherwise requeue the same CU forever)
        store.hset(f"cu:{self.id}", "attempts", 0)

    @property
    def url(self) -> str:
        return f"cu://{self.id}"

    @property
    def state(self) -> str:
        return self._store.hget(f"cu:{self.id}", "state", CUState.NEW)

    @property
    def pilot_id(self) -> Optional[str]:
        return self._store.hget(f"cu:{self.id}", "pilot")

    def _set_state(self, state: str) -> None:
        self._store.hset(f"cu:{self.id}", "state", state)

    def _cas_state(self, expect: str, state: str) -> bool:
        """Exactly-once transition (straggler duplicates race on this)."""
        return self._store.hcas(f"cu:{self.id}", "state", expect, state)

    def cancel(self) -> None:
        for s in (CUState.NEW, CUState.WAITING, CUState.PENDING):
            if self._cas_state(s, CUState.CANCELED):
                # A canceled CU will never materialize its outputs: fail the
                # output DUs so downstream dataflow waiters are released with
                # a clear error instead of hanging.
                self._fail_outputs(f"producer {self.url} was canceled")
                return

    def _fail_outputs(self, reason: str) -> None:
        from .data_unit import DUState

        for du_id in self.description.output_data:
            key = f"du:{du_id}"
            if self._store.hget(key, "state") != DUState.READY:
                self._store.hset(key, "error", reason)
                self._store.hset(key, "state", DUState.FAILED)

    def wait(self, timeout: float = 60.0) -> str:
        """Block until the CU is terminal — event-driven on the store's
        keyspace notifications (no polling loop; the coarse in-wait poll is
        only a fallback against lost notifications)."""
        return self._store.wait_field(
            f"cu:{self.id}",
            "state",
            lambda s: s in CUState.TERMINAL,
            timeout=timeout,
            default=CUState.NEW,
        )

    def __repr__(self) -> str:  # pragma: no cover
        return f"<ComputeUnit {self.url} exe={self.description.executable} state={self.state}>"
