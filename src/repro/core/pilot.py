"""Pilot-Compute and Pilot-Data (§4.3.1).

"A Pilot-Compute allocates a set of computational resources (e.g. cores).
A Pilot-Data is conceptually similar and represents a physical storage
resource that is used as a logical container for dynamic data placement,
e.g. for compute-local data replicas or for caching intermediate data."
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional

from ..backends import StorageAdaptor, chunk_key, make_backend
from .affinity import Topology
from .coordination import CoordinationStore
from .data_unit import DataUnit, _next_id


class PilotState:
    NEW = "New"
    PROVISIONING = "Provisioning"  # waiting in the resource's queue (T_Q_pilot)
    ACTIVE = "Active"
    #: grace period: heartbeats missed but below the failure threshold —
    #: the pilot is non-placeable (schedulers route around it, its agent
    #: stops pulling new work) while in-flight CUs drain; a fresh heartbeat
    #: returns it to ACTIVE, continued silence hardens it to FAILED
    SUSPECT = "Suspect"
    DONE = "Done"
    FAILED = "Failed"
    CANCELED = "Canceled"

    TERMINAL = (DONE, FAILED, CANCELED)
    #: states a scheduler may bind new work to
    PLACEABLE = (NEW, PROVISIONING, ACTIVE)

#: shared hash of per-pilot heartbeat timestamps — ONE ``hgetall`` reads
#: every pilot's liveness (the HeartbeatMonitor's per-tick scan is a single
#: hash-field scan instead of O(pilots) record reads)
HEARTBEATS_KEY = "heartbeats"


@dataclasses.dataclass
class RuntimeContext:
    """Shared runtime plumbing handed to pilots/agents/services."""

    store: CoordinationStore
    topology: Topology
    #: scale simulated delays into real sleeps (0.0 = don't sleep at all;
    #: tests run at 0, demos can use e.g. 1e-3 to watch dynamics)
    time_scale: float = 0.0
    #: agent poll interval
    poll_s: float = 0.01
    #: in-process object table: id -> live DataUnit/ComputeUnit/Pilot objects
    #: (authoritative *state* stays in the coordination store; the table is
    #: how a single-process deployment resolves handles, and is rebuildable
    #: from the store on reconnect)
    objects: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: attached lazily by services (avoids an import cycle)
    transfer_service: Optional[Any] = None
    #: data management mode (§4.2): "pull" = agent stages inputs before the
    #: CU runs; "push" = the manager pre-stages at scheduling time
    data_mode: str = "pull"
    #: topology label of the submission host — ingest transfers (DU local
    #: buffer → first PD) are costed over this uplink when set
    submission_label: Optional[str] = None
    #: attached lazily by the TierManager (avoids an import cycle): owns
    #: tier classification, access stats, and quota-driven eviction
    tier_manager: Optional[Any] = None
    #: attached lazily by the AdmissionController (avoids an import
    #: cycle): per-tenant QoS gate between CU release and placement
    admission: Optional[Any] = None
    #: attached lazily alongside the admission controller: tenant
    #: identities, quotas, and fair-share usage accounting
    tenant_registry: Optional[Any] = None

    def sleep_sim(self, sim_seconds: float) -> None:
        if self.time_scale > 0 and sim_seconds > 0:
            time.sleep(sim_seconds * self.time_scale)

    def lookup(self, obj_id: str) -> Any:
        if obj_id not in self.objects:
            raise KeyError(f"unknown object id {obj_id!r}")
        return self.objects[obj_id]

    def register(self, obj: Any) -> Any:
        self.objects[obj.id] = obj
        return obj


# ---------------------------------------------------------------- Pilot-Data
@dataclasses.dataclass
class PilotDataDescription:
    """JSON-able PD description: where (backend URL + affinity) and how much."""

    service_url: str  # e.g. "sharedfs://cluster:pod0/scratch"
    affinity: str  # topology label, e.g. "cluster:pod0"
    size_quota: int = 1 << 40  # bytes
    name: str = ""
    #: explicit storage-tier override ("dram-cache" / "node-local" /
    #: "site-shared" / "archival"); empty = derive from the backend's
    #: scheme/profile (see repro.core.tiering.classify_tier)
    tier: str = ""

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class QuotaExceeded(RuntimeError):
    pass


class PilotData:
    """An allocated storage container holding DU replicas, chunk-granular.

    The physical representation is the DU's *chunk* stream: each held
    chunk is stored under the key ``<du_id>/.c/<index>`` (see
    :func:`repro.backends.base.chunk_key`); the DU-internal hierarchical
    file namespace is reassembled on read from the chunk ranges recorded
    in the DU manifest.  A PD may hold any subset of a DU's chunks — a
    *partial replica* — and still serve those chunks as a transfer source;
    it is promoted into the DU's ``locations`` only once it covers every
    chunk.
    """

    def __init__(
        self,
        description: PilotDataDescription,
        ctx: RuntimeContext,
        pd_id: Optional[str] = None,
    ):
        self.id = pd_id or _next_id("pd")
        self.description = description
        self.ctx = ctx
        self.backend: StorageAdaptor = make_backend(description.service_url)
        self.affinity = description.affinity
        ctx.topology.ensure(self.affinity)
        self._lock = threading.RLock()
        self._used = 0
        #: bytes admitted by in-flight writes, not yet accounted — the
        #: check-and-reserve admission that keeps racing stagers from
        #: jointly overshooting the quota
        self._reserved = 0
        self._dus: Dict[str, int] = {}  # du_id -> bytes held
        self._du_chunks: Dict[str, set] = {}  # du_id -> held chunk indices
        self._du_total: Dict[str, int] = {}  # du_id -> total chunks in DU
        #: DU handles seen by put/copy — lets chunk-range reads resolve the
        #: manifest even for DUs never registered in ctx.objects (e.g.
        #: partition_du/merge_dus outputs staged directly into a PD)
        self._du_objs: Dict[str, DataUnit] = {}
        ctx.store.hset(f"pd:{self.id}", "state", PilotState.ACTIVE)
        ctx.store.hset(f"pd:{self.id}", "affinity", self.affinity)
        ctx.store.hset(f"pd:{self.id}", "url", description.service_url)
        ctx.store.hset(f"pd:{self.id}", "dus", [])

    @property
    def url(self) -> str:
        return f"pd://{self.id}"

    @property
    def state(self) -> str:
        return self.ctx.store.hget(f"pd:{self.id}", "state", PilotState.NEW)

    @property
    def used_bytes(self) -> int:
        with self._lock:
            return self._used

    @property
    def free_bytes(self) -> int:
        return self.description.size_quota - self.used_bytes

    def du_ids(self) -> List[str]:
        with self._lock:
            return sorted(self._dus)

    def du_bytes(self) -> Dict[str, int]:
        """Accounting snapshot: du_id -> bytes this PD holds for it (the
        per-tenant resident-byte quotas sum these across live PDs)."""
        with self._lock:
            return dict(self._dus)

    def has_du(self, du_id: str) -> bool:
        """True iff this PD holds a FULL replica (every chunk) of the DU.

        For a streaming DU the accounting snapshot (``_du_total`` at last
        write) can lag the growing chunk table, so the live DU handle is
        consulted instead — a holder that covered the stream a moment ago
        is not "full" once the producer appends more."""
        with self._lock:
            if du_id not in self._du_chunks:
                return False
            held = len(self._du_chunks[du_id])
            total = self._du_total.get(du_id, 0)
            du = self._du_objs.get(du_id)
        if du is None:
            du = self.ctx.objects.get(du_id)
        if du is not None and du.streaming:
            total = du.n_chunks
        return held >= total

    def chunks_held(self, du_id: str) -> List[int]:
        with self._lock:
            return sorted(self._du_chunks.get(du_id, ()))

    def fetch_du_chunk(self, du_id: str, index: int) -> bytes:
        """Raw bytes of one locally-held chunk (streaming consumers read
        chunkwise as the producer publishes)."""
        with self._lock:
            if index not in self._du_chunks.get(du_id, ()):
                raise KeyError(
                    f"{self.url} holds no chunk {index} of du://{du_id}"
                )
        return self.backend.get(chunk_key(du_id, index))

    def missing_chunks(self, du: DataUnit) -> List[int]:
        """Chunk indices of ``du`` this PD does not hold yet."""
        with self._lock:
            held = self._du_chunks.get(du.id, set())
        return [i for i in range(du.n_chunks) if i not in held]

    # ------------------------------------------------------------- content
    def _reserve_space(self, du: DataUnit, nbytes: int) -> int:
        """Atomically admit ``nbytes`` against the quota (check-and-reserve
        under the lock, so racing stagers cannot jointly overshoot), with
        tier-aware eviction: when the write would exceed ``size_quota``
        the TierManager reclaims *redundant* chunk replicas (policy-
        ordered, invariant-guarded) and admission retries; only when
        eviction frees nothing does ``QuotaExceeded`` surface.  The caller
        must pair with :meth:`_release_reservation` once accounted."""
        while True:
            with self._lock:
                avail = self.description.size_quota - self._used - self._reserved
                if nbytes <= avail:
                    self._reserved += nbytes
                    return nbytes
                need = nbytes - avail
            tm = self.ctx.tier_manager
            freed = (
                tm.make_room(
                    self,
                    need,
                    exclude_du=du.id,
                    # requestor identity: a tenant's pressure reclaims its
                    # OWN redundant chunks before touching anyone else's
                    tenant=getattr(du.description, "tenant", None),
                )
                if tm is not None
                else 0
            )
            with self._lock:
                avail = self.description.size_quota - self._used - self._reserved
                if nbytes <= avail:
                    self._reserved += nbytes
                    return nbytes
            if freed <= 0:
                raise QuotaExceeded(
                    f"{self.url}: need {nbytes}B, free {avail}B"
                )
            # eviction made progress but not enough yet: try another round

    def _release_reservation(self, nbytes: int) -> None:
        with self._lock:
            self._reserved = max(0, self._reserved - nbytes)

    def _put_chunk_bytes(self, key: str, data: bytes) -> None:
        """Idempotent chunk write: chunk content is immutable (checksummed
        in the DU manifest), so a key that already holds the right bytes —
        an eviction-race re-plan, or a write-once object store revisited —
        is kept as-is.  A mismatching key (stale file from a previous run
        on a persistent filesystem backend) is replaced."""
        if self.backend.exists(key):
            try:
                if self.backend.get(key) == data:
                    return
            except Exception:
                pass
            self.backend.delete(key)
        self.backend.put(key, data)

    def _account_chunks(
        self, du: DataUnit, indices: List[int], register: bool
    ) -> int:
        """Record newly-held chunks; returns bytes newly accounted (chunks
        already held are not double-counted, so racing stagers stay
        consistent).  A PD marked FAILED (its pilot died and recovery
        purged it) records nothing: a dying agent's still-running stage-in
        must not re-register the dead sandbox as a replica holder."""
        if self.state == PilotState.FAILED:
            return 0
        chunks = du.chunks
        with self._lock:
            held = self._du_chunks.setdefault(du.id, set())
            new = [i for i in indices if i not in held]
            nbytes = sum(chunks[i].size for i in new)
            held.update(new)
            self._du_total[du.id] = len(chunks)
            self._du_objs[du.id] = du
            self._dus[du.id] = self._dus.get(du.id, 0) + nbytes
            self._used += nbytes
            self.ctx.store.hset(f"pd:{self.id}", "dus", sorted(self._dus))
        if register:
            du._add_chunks(self.id, indices)
        return nbytes

    def put_chunks(
        self, du: DataUnit, indices: List[int], register: bool = True
    ) -> int:
        """Materialize a subset of a DU's chunks from its in-process buffer
        into this PD.  Returns bytes written.  ``register=False`` stores the
        chunks without reporting this PD as a holder to the DU (transient
        per-CU sandbox staging — the paper's PD-less naive mode)."""
        chunks = du.chunks
        todo = [i for i in indices if i not in self._du_chunks.get(du.id, set())]
        nbytes = sum(chunks[i].size for i in todo)
        self._reserve_space(du, nbytes)
        try:
            for i in todo:
                self._put_chunk_bytes(chunk_key(du.id, i), du.chunk_data(i))
            self._account_chunks(du, todo, register)
        finally:
            self._release_reservation(nbytes)
        return nbytes

    def put_du(self, du: DataUnit, register: bool = True) -> int:
        """Materialize a DU's full chunk set into this PD (initial staging).
        An empty DU still records a (vacuously full) holding."""
        return self.put_chunks(du, list(range(du.n_chunks)), register=register)

    def copy_chunks_from(
        self,
        du: DataUnit,
        src: "PilotData",
        indices: List[int],
        register: bool = True,
    ) -> int:
        """Copy specific chunks of a DU from another PD (a partial holder
        suffices, as long as it has the requested chunks)."""
        src_held = set(src.chunks_held(du.id))
        missing_at_src = [i for i in indices if i not in src_held]
        if missing_at_src:
            raise KeyError(
                f"{src.url} holds no chunks {missing_at_src} of {du.url}"
            )
        chunks = du.chunks
        todo = [i for i in indices if i not in self._du_chunks.get(du.id, set())]
        nbytes = sum(chunks[i].size for i in todo)
        self._reserve_space(du, nbytes)
        try:
            for i in todo:
                self._put_chunk_bytes(
                    chunk_key(du.id, i), src.backend.get(chunk_key(du.id, i))
                )
            self._account_chunks(du, todo, register)
        finally:
            self._release_reservation(nbytes)
        return nbytes

    def copy_du_from(self, du: DataUnit, src: "PilotData", register: bool = True) -> int:
        """Replicate a DU from another PD into this one: copies the chunks
        this PD is still missing (delta transfer — a partial local holding
        only pays for the remainder)."""
        if not src.has_du(du.id):
            raise KeyError(f"{src.url} holds no replica of {du.url}")
        return self.copy_chunks_from(
            du, src, self.missing_chunks(du), register=register
        )

    def fetch_du_file(self, du_id: str, relpath: str) -> bytes:
        """Reassemble one DU file from the locally-held chunks covering its
        byte range in the canonical stream."""
        du: Optional[DataUnit] = self.ctx.objects.get(du_id) or self._du_objs.get(du_id)
        if du is None:
            raise KeyError(f"{self.url}: unknown DU {du_id!r}")
        start, end = du.file_range(relpath)
        if start == end:
            return b""
        csize = du.chunk_size
        out = bytearray()
        for i in du.chunks_for_file(relpath):
            data = self.backend.get(chunk_key(du_id, i))
            lo = i * csize
            out += data[max(0, start - lo) : max(0, end - lo)]
        return bytes(out)

    def verify_du(self, du: DataUnit) -> bool:
        """Checksum-verify every locally-held chunk against the DU's chunk
        manifest; a full replica must cover and match all chunks."""
        import zlib

        if not self.has_du(du.id):
            return False
        for c in du.chunks:
            data = self.backend.get(chunk_key(du.id, c.index))
            if len(data) != c.size or zlib.crc32(data) != c.checksum:
                return False
        return True

    def evict_chunks(self, du: DataUnit, indices: List[int]) -> int:
        """Drop a subset of a DU's locally-held chunks (quota eviction /
        cache demotion).  Returns bytes freed.

        Bookkeeping stays exact: the chunks leave this PD's accounting and
        the DU's ``du:<id>:chunks`` registry (bumping the location version
        so transfer caches invalidate), and if this PD no longer covers
        every chunk it is demoted from ``locations`` to a partial holder.
        Safety (last-copy / replication-factor / pin / in-flight checks)
        is the TierManager's job — this method only executes the drop.
        """
        chunks = du.chunks
        with self._lock:
            held = self._du_chunks.get(du.id)
            if not held:
                return 0
            todo = sorted(i for i in indices if i in held)
            if not todo:
                return 0
            nbytes = sum(chunks[i].size for i in todo if i < len(chunks))
            held.difference_update(todo)
            self._dus[du.id] = max(0, self._dus.get(du.id, 0) - nbytes)
            self._used = max(0, self._used - nbytes)
            if not held:
                self._dus.pop(du.id, None)
                self._du_chunks.pop(du.id, None)
                self._du_total.pop(du.id, None)
                self._du_objs.pop(du.id, None)
            self.ctx.store.hset(f"pd:{self.id}", "dus", sorted(self._dus))
        for i in todo:
            self.backend.delete(chunk_key(du.id, i))
        du._drop_chunks(self.id, todo)
        return nbytes

    def remove_du(self, du: DataUnit) -> None:
        with self._lock:
            nbytes = self._dus.pop(du.id, 0)
            held = self._du_chunks.pop(du.id, set())
            self._du_total.pop(du.id, None)
            self._du_objs.pop(du.id, None)
            self._used -= nbytes
            self.ctx.store.hset(f"pd:{self.id}", "dus", sorted(self._dus))
        for i in held:
            self.backend.delete(chunk_key(du.id, i))
        du._remove_location(self.id)

    def cancel(self) -> None:
        self.ctx.store.hset(f"pd:{self.id}", "state", PilotState.CANCELED)

    def __repr__(self) -> str:  # pragma: no cover
        return f"<PilotData {self.url} at {self.affinity} dus={len(self._dus)}>"


# ------------------------------------------------------------- Pilot-Compute
@dataclasses.dataclass
class PilotComputeDescription:
    """JSON-able PC description (paper: service URL + process count +
    optional backend-specific attributes)."""

    resource_url: str  # e.g. "sim://cluster:pod0:host0"
    slots: int = 1
    affinity: str = ""  # defaults to the resource_url location part
    #: simulated batch-queue wait before the pilot activates (T_Q_pilot)
    queue_time_s: float = 0.0
    walltime_s: float = float("inf")
    name: str = ""
    #: DRAM budget of the pilot's sandbox PD — the memory tier is finite,
    #: so working sets larger than this churn through quota eviction
    #: instead of growing without bound
    sandbox_quota: int = 1 << 40

    def __post_init__(self) -> None:
        if not self.affinity:
            import urllib.parse

            self.affinity = urllib.parse.urlparse(self.resource_url).netloc

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class PilotCompute:
    """A placeholder allocation of compute slots, run by a Pilot-Agent.

    The agent itself lives in :mod:`repro.core.agent`; this class manages
    lifecycle + the pilot's sandbox PD (paper: "For each Pilot instance a
    sandbox is created").
    """

    def __init__(
        self,
        description: PilotComputeDescription,
        ctx: RuntimeContext,
        pilot_id: Optional[str] = None,
    ):
        from .agent import PilotAgent  # local import to avoid cycle

        self.id = pilot_id or _next_id("pc")
        self.description = description
        self.ctx = ctx
        ctx.topology.ensure(description.affinity)
        self.sandbox = PilotData(
            PilotDataDescription(
                service_url=f"mem://{description.affinity}/sandbox-{self.id}",
                affinity=description.affinity,
                size_quota=description.sandbox_quota,
                name=f"sandbox-{self.id}",
            ),
            ctx,
        )
        st = ctx.store
        st.hset(f"pilot:{self.id}", "state", PilotState.NEW)
        st.hset(f"pilot:{self.id}", "affinity", description.affinity)
        st.hset(f"pilot:{self.id}", "slots", description.slots)
        st.hset(f"pilot:{self.id}", "queue_time_s", description.queue_time_s)
        # sandbox PD id at the top level: recovery must find the dead
        # pilot's replica holdings without a live PilotCompute handle
        st.hset(f"pilot:{self.id}", "sandbox_pd", self.sandbox.id)
        st.hset(HEARTBEATS_KEY, self.id, time.monotonic())
        self.agent = PilotAgent(self, ctx)

    @property
    def url(self) -> str:
        return f"pc://{self.id}"

    @property
    def queue_name(self) -> str:
        """The pilot-specific CU queue (§4.2's two-queue scheme)."""
        return f"queue:pilot:{self.id}"

    @property
    def state(self) -> str:
        return self.ctx.store.hget(f"pilot:{self.id}", "state", PilotState.NEW)

    @property
    def affinity(self) -> str:
        return self.description.affinity

    @property
    def slots(self) -> int:
        return self.description.slots

    def start(self) -> "PilotCompute":
        """Submit the placeholder job; the agent activates after the
        (simulated) queue wait."""
        self.ctx.store.hset(f"pilot:{self.id}", "state", PilotState.PROVISIONING)
        self.agent.start()
        return self

    def cancel(self) -> None:
        self.agent.stop()
        self.ctx.store.hset(f"pilot:{self.id}", "state", PilotState.CANCELED)
        self.ctx.store.hdel(HEARTBEATS_KEY, self.id)

    def fail(self) -> None:
        """Simulate a hard node failure (fault-injection tests).

        Deliberately does NOT touch the coordination store: a crashed node
        cannot report its own death.  The HeartbeatMonitor notices the
        missed heartbeats, marks the pilot FAILED, and re-queues its
        orphaned CUs — exactly the recovery path a real failure takes.
        """
        self.agent.kill()

    def wait_active(self, timeout: float = 30.0) -> str:
        """Block until the pilot activates (or terminates), event-driven on
        the coordination store's keyspace notifications (poll only as a
        coarse fallback)."""
        settled = (PilotState.ACTIVE, *PilotState.TERMINAL)
        return self.ctx.store.wait_field(
            f"pilot:{self.id}",
            "state",
            lambda s: s in settled,
            timeout=timeout,
            default=PilotState.NEW,
        )

    def running_cus(self) -> List[str]:
        return list(self.ctx.store.hget(f"pilot:{self.id}", "running", []))

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"<PilotCompute {self.url} at {self.affinity} "
            f"slots={self.slots} state={self.state}>"
        )
