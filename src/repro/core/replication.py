"""DU replication strategies (paper §6.2, Fig. 8, and PD2P-style demand
replication from §3).

Three strategies:
  * **sequential** — one replica after another from the original source
    (paper: SRM/iRODS sequential scenarios);
  * **group** — chunk-striped fan-out: the source first *disperses*
    distinct chunk stripes across the targets in parallel (each target
    receives ~1/N of the DU), then every target *heals* to a full replica
    by striping its missing chunks from the now-many partial holders.
    This generalizes the paper's osgGridFTPGroup fan-out ("optimized
    replication mechanism, which utilizes the replica closest to the
    target site", §6.4) from whole-DU rounds to chunk waves: only ~2
    stripe-sized waves instead of ~log2(R) full-DU rounds.  The
    ``striped=False`` mode keeps the whole-DU round behaviour for
    comparison (benchmarks report both);
  * **demand** — PD2P-style: replicate *popular* DUs to underutilized
    pilots' sites ("replicate popular datasets to underutilized resources
    for later computations"), driven by access statistics the transfer
    service already records.

All strategies return the simulated T_R, so benchmarks can reproduce the
paper's group-vs-sequential comparison quantitatively.
"""

from __future__ import annotations

import collections
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

from .cost_model import estimate_tx
from .data_unit import DataUnit
from .pilot import PilotData, RuntimeContext


def replicate_sequential(
    du: DataUnit, src: PilotData, targets: Sequence[PilotData], ctx: RuntimeContext
) -> float:
    """Chain replication; T_R = Σ T_X(src→target)."""
    t = 0.0
    for dst in targets:
        if dst.has_du(du.id):
            continue
        t += ctx.transfer_service.replicate(du, src, dst)
    return t


def _replicate_group_monolithic(
    du: DataUnit, src: PilotData, targets: Sequence[PilotData], ctx: RuntimeContext
) -> float:
    """Whole-DU fan-out: every round, each current holder feeds one new
    target (closest-first), so rounds ~ log2(R).  Returns simulated T_R
    (max over each round's parallel transfers, summed over rounds)."""
    holders: List[PilotData] = [src]
    remaining = [d for d in targets if not d.has_du(du.id)]
    remaining.sort(
        key=lambda d: estimate_tx(du.size, src.affinity, d.affinity, ctx.topology)
    )
    total = 0.0
    while remaining:
        n = min(len(holders), len(remaining))
        batch, remaining = remaining[:n], remaining[n:]
        # Pair each target with its cheapest current holder (greedy).
        round_times = []
        with ThreadPoolExecutor(max_workers=max(1, n)) as pool:
            futs = []
            for dst in batch:
                best = min(
                    holders,
                    key=lambda h: estimate_tx(
                        du.size, h.affinity, dst.affinity, ctx.topology
                    ),
                )
                futs.append(
                    pool.submit(ctx.transfer_service.replicate, du, best, dst)
                )
            for f in futs:
                round_times.append(f.result())
        total += max(round_times) if round_times else 0.0
        holders.extend(batch)
    return total


def replicate_group(
    du: DataUnit,
    src: PilotData,
    targets: Sequence[PilotData],
    ctx: RuntimeContext,
    striped: bool = True,
) -> float:
    """Group replication; chunk-striped by default (see module docstring).

    Phase 1 (disperse): the DU's chunks are dealt round-robin across the
    targets and each stripe moves src→target in parallel — wave time is the
    max over the per-target stripe transfers.  Phase 2 (heal): each target
    stages its missing chunks through the transfer service's multi-source
    striped fetch, drawing on every partial holder created in phase 1 (and
    the source), again in parallel.  Every target ends holding a full,
    registered replica.
    """
    ts = ctx.transfer_service
    remaining = [d for d in targets if not d.has_du(du.id)]
    if not remaining:
        return 0.0
    if not striped or du.n_chunks <= 1:
        return _replicate_group_monolithic(du, src, remaining, ctx)
    # closest targets first, so the cheap links carry stripes earliest
    remaining.sort(
        key=lambda d: estimate_tx(du.size, src.affinity, d.affinity, ctx.topology)
    )
    stripes: List[List[int]] = [[] for _ in remaining]
    for i in range(du.n_chunks):
        stripes[i % len(remaining)].append(i)
    disperse_times: List[float] = []
    with ThreadPoolExecutor(max_workers=len(remaining)) as pool:
        futs = [
            pool.submit(ts.replicate_chunks, du, src, dst, stripe)
            for dst, stripe in zip(remaining, stripes)
            if stripe
        ]
        disperse_times = [f.result() for f in futs]
    total = max(disperse_times) if disperse_times else 0.0
    # Plan every target's heal BEFORE executing any: all plans see the same
    # post-disperse holdings snapshot, so the simulated heal times are
    # independent of thread interleaving (sources only gain chunks during
    # the heal, so the planned copies all stay valid).
    plans = [ts.plan_chunk_fetch(du, dst, dst.affinity) for dst in remaining]
    heal_times: List[float] = []
    with ThreadPoolExecutor(max_workers=len(remaining)) as pool:
        futs = [
            pool.submit(ts.heal_replica, du, dst, plan)
            for dst, plan in zip(remaining, plans)
        ]
        heal_times = [f.result() for f in futs]
    total += max(heal_times) if heal_times else 0.0
    return total


def _site_of(label: str) -> str:
    """Failure-domain key of an affinity label: the site subtree (first
    two components).  A whole site — its shared FS, its pilots — is the
    unit that tends to die together (walltime kill, maintenance window)."""
    parts = label.split(":")
    return ":".join(parts[:2]) if len(parts) >= 2 else label


def select_heal_targets(
    ctx: RuntimeContext,
    du: DataUnit,
    candidates: Sequence[PilotData],
    n: int,
    held: Sequence[str] = (),
) -> List[PilotData]:
    """Pick up to ``n`` PDs to host new replicas of ``du``,
    failure-domain-aware: candidates in sites that do NOT already hold a
    replica rank first (so re-replication spreads copies across domains
    instead of piling them where the next churn event takes them all),
    then by transfer cost from the surviving holders, then by free space.
    Deterministic for a fixed candidate set.
    """
    if n <= 0 or not candidates:
        return []
    held_sites = {_site_of(label) for label in held}
    src_labels = [label for label in held if label]

    def cost(pd: PilotData) -> float:
        if not src_labels:
            return 0.0  # healing from the local buffer: location-agnostic
        return min(
            estimate_tx(du.size, s, pd.affinity, ctx.topology)
            for s in src_labels
        )

    ranked = sorted(
        candidates,
        key=lambda pd: (
            _site_of(pd.affinity) in held_sites,  # new domains first
            cost(pd),
            -pd.free_bytes,
            pd.id,
        ),
    )
    # never stack two new replicas into the same failure domain while an
    # untouched domain remains available
    out: List[PilotData] = []
    used_sites = set(held_sites)
    for pd in ranked:
        if len(out) >= n:
            break
        if _site_of(pd.affinity) in used_sites:
            continue
        out.append(pd)
        used_sites.add(_site_of(pd.affinity))
    for pd in ranked:
        if len(out) >= n:
            break
        if pd not in out:
            out.append(pd)
    return out


class DemandReplicator:
    """PD2P-style demand-based replication policy.

    Tracks per-DU access counts (remote stagings = cache misses).  When a DU
    has been remotely staged more than ``threshold`` times toward the same
    site subtree, it is proactively replicated to a PD in that subtree so
    later CUs link instead of transfer.
    """

    def __init__(self, ctx: RuntimeContext, threshold: int = 2):
        self.ctx = ctx
        self.threshold = threshold
        self._miss_counts: Dict[Tuple[str, str], int] = collections.Counter()
        self._lock = threading.Lock()
        self.replications: List[Tuple[str, str]] = []

    @staticmethod
    def _site_of(label: str) -> str:
        parts = label.split(":")
        return ":".join(parts[:2]) if len(parts) >= 2 else label

    def observe_staging(self, du: DataUnit, dst_location: str) -> None:
        with self._lock:
            self._miss_counts[(du.id, self._site_of(dst_location))] += 1

    def maybe_replicate(
        self, du: DataUnit, dst_location: str, site_pds: Sequence[PilotData]
    ) -> Optional[float]:
        """If demand at the destination site crossed the threshold, create a
        site-local replica.  Returns simulated T_R or None."""
        site = self._site_of(dst_location)
        with self._lock:
            if self._miss_counts[(du.id, site)] < self.threshold:
                return None
        candidates = [
            pd
            for pd in site_pds
            if self._site_of(pd.affinity) == site
            and not pd.has_du(du.id)
            and pd.free_bytes >= du.size
        ]
        if not candidates:
            return None
        dst = candidates[0]
        src_pd, _ = self.ctx.transfer_service.resolve_access(du, dst.affinity)
        if src_pd is None:
            return None
        t = self.ctx.transfer_service.replicate(du, src_pd, dst)
        with self._lock:
            self.replications.append((du.id, dst.id))
            self._miss_counts[(du.id, site)] = 0
        return t
