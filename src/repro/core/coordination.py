"""Coordination & control store — the framework's externalized state.

The paper (§4.2, "Distributed Coordination and Control Management") keeps the
*complete* state of the framework in a shared in-memory data store (Redis):
pilot/CU/DU descriptions and states, per-pilot and global work queues, and
resource information pushed by agents.  That externalization is what buys the
fault-tolerance story: managers and agents can disconnect and reconnect, the
store can be snapshotted/restarted, and clients survive transient store
failures.

This module is an embedded, thread-safe re-implementation of exactly that
protocol.  It is *not* a toy dict: it supports

  * namespaced key/value and hash records (``set/get/hset/hgetall``),
  * blocking FIFO queues (``push/pop``) — the global CU queue and the
    per-pilot queues of §4.2 map 1:1 onto these,
  * atomic compare-and-set on hash fields (used for exactly-once CU state
    transitions, e.g. straggler-duplicate "first finisher wins"),
  * durability via a JSON write-ahead log (replayable on restart),
  * fault injection (``fail_for``): operations raise
    :class:`CoordinationUnavailable` for a window, so client retry loops can
    be tested (the paper: "agent and manager are able to survive transient
    Redis failures"), and
  * keyspace notifications (``subscribe``/``unsubscribe``): mutating ops
    (``hset``/``hcas``/``push``) publish :class:`StoreEvent` records to
    registered callbacks — the Redis-keyspace-notification analogue that the
    event-driven scheduler reacts to instead of polling.

**Sharded coordination plane.**  The store is partitioned into N lock-striped
shards: every key (``cu:…``/``du:…``/``pilot:…``/``pd:…`` alike) maps to a
stable shard by a CRC of the full key, so the hot namespaces stripe across
all locks instead of funnelling through one.  The properties the schedulers
rely on survive the sharding:

  * **Total event order.**  Events are *sequenced* while the mutating shard
    lock is still held (a single atomic counter guarded by a tiny event
    lock), so ``StoreEvent.seq`` defines a store-wide total order that is
    consistent with per-key mutation order.
  * **Out-of-lock dispatch.**  Delivery moved OFF the mutating thread's
    critical section: sequenced events land on per-subscriber ordered
    delivery queues drained by a dedicated dispatcher thread, which invokes
    callbacks outside every store lock, per subscriber in exact seq order.
    Writers never wait on subscribers; subscribers may re-enter the store
    freely.  Mutators return *before* their event is delivered — consumers
    that need read-your-event determinism (manual-stepping schedulers,
    monitor ticks) call :meth:`flush_events` first.  ``dispatch="inline"``
    restores synchronous delivery (still outside the shard locks, via a
    combining drain that preserves seq order) for legacy-mode comparisons.
  * **Targeted queue wakeups.**  ``pop_any`` waiters register a per-queue
    waiter event and are woken only by pushes to *their* queues — no global
    ``notify_all`` thundering herd, no 50 ms condition poll.
  * **Group-commit WAL.**  Mutations append replay records to an in-memory
    buffer (under the shard lock, so the WAL stays a valid serialization);
    the buffer is flushed to disk outside every shard lock once
    ``wal_batch`` records accumulate, on a short timer, and on ``close()``.
    The replay format is unchanged.
  * **Indexed prefix scans.**  ``keys()``/``hkeys()`` run a bisect range
    scan over per-shard sorted key indexes — O(log n + matches) per shard,
    not O(full keyspace).

The interface is deliberately Redis-shaped so a networked store could be
substituted without touching managers or agents.
"""

from __future__ import annotations

import bisect
import collections
import dataclasses
import heapq
import json
import os
import queue
import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

#: default number of lock stripes — enough to spread cu:/du:/pilot:/pd:
#: traffic from ~100 pilots' worth of agents without measurable per-op cost
DEFAULT_SHARDS = 16

#: default group-commit size: WAL records buffered before a writer flushes
DEFAULT_WAL_BATCH = 256

#: background WAL flusher interval — bounds how stale the on-disk log can be
#: when the write rate stays below ``wal_batch``
WAL_FLUSH_INTERVAL_S = 0.02

#: cap on a single blocked ``pop_any`` wait: bounds how long an injected
#: ``fail_for`` window can go unnoticed by a parked waiter (the per-queue
#: wakeup makes real pushes land instantly; this is only the failure poll)
POP_FAIL_POLL_S = 0.5


class CoordinationUnavailable(RuntimeError):
    """Raised while the store is in an (injected or real) failure window."""


#: debug hook: when set (see repro.analysis.witness), every coordination
#: lock created from then on is wrapped by the runtime lock-order witness
_LOCK_FACTORY: Optional[Callable[..., Any]] = None


def set_lock_factory(factory: Optional[Callable[..., Any]]) -> None:
    """Install a lock factory ``factory(name, reentrant=False)`` used for
    every store lock created afterwards; ``None`` restores plain
    ``threading`` locks.  Existing stores keep the locks they were built
    with."""
    global _LOCK_FACTORY
    _LOCK_FACTORY = factory


def _make_lock(name: str, *, reentrant: bool = False):
    """Single creation point for every coordination-plane mutex, so the
    ``REPRO_LOCK_WITNESS=1`` debug mode can substitute witnessed locks
    that validate the static PD-L005 lock graph against execution."""
    if _LOCK_FACTORY is not None:
        return _LOCK_FACTORY(name, reentrant)
    return threading.RLock() if reentrant else threading.Lock()


@dataclasses.dataclass(frozen=True)
class StoreEvent:
    """One published keyspace notification.

    ``op`` is "hset" (covers hcas winners too) or "push"; ``key`` is the
    hash key or queue name; ``field`` is the hash field (None for pushes);
    ``value`` the new value / pushed item.
    """

    seq: int
    op: str
    key: str
    field: Optional[str]
    value: Any


def _default(obj: Any) -> Any:
    if isinstance(obj, (set, frozenset)):
        return sorted(obj)
    raise TypeError(f"not JSON serializable: {type(obj)}")


class _Shard:
    """One lock stripe: its own kv/hash/queue maps, sorted key indexes for
    bisect prefix scans, per-queue waiter lists, and an op counter."""

    __slots__ = (
        "lock",
        "kv",
        "hashes",
        "queues",
        "kv_index",
        "hash_index",
        "qwaiters",
        "ops",
    )

    def __init__(self) -> None:
        self.lock = _make_lock("_Shard.lock")
        self.kv: Dict[str, Any] = {}
        self.hashes: Dict[str, Dict[str, Any]] = {}
        self.queues: Dict[str, collections.deque] = {}
        #: sorted key lists kept in lockstep with kv/hashes — prefix scans
        #: bisect into these instead of walking the whole keyspace
        self.kv_index: List[str] = []
        self.hash_index: List[str] = []
        #: queue name -> waiter Events parked in pop_any; push() sets
        #: exactly these (targeted wakeup, no cross-queue thundering herd)
        self.qwaiters: Dict[str, List[threading.Event]] = {}
        self.ops = 0

    def scan(self, index: List[str], prefix: str) -> List[str]:
        """Bisect range scan: the keys in ``index`` starting with
        ``prefix``, as a slice copy — both range bounds found by bisect,
        so the stripe lock is held for O(log n + |slice copy|) with no
        per-key Python loop (PD-L006: materialization stays minimal under
        the lock; cross-shard merging happens outside it)."""
        if not prefix:
            return index[:]
        lo = bisect.bisect_left(index, prefix)
        last = prefix[-1]
        if last < "\U0010ffff":
            # upper bound: bump the prefix's final char — every key with
            # this prefix sorts strictly below it
            hi = bisect.bisect_left(index, prefix[:-1] + chr(ord(last) + 1), lo)
        else:  # degenerate max-codepoint prefix: fall back to a walk
            hi = lo
            while hi < len(index) and index[hi].startswith(prefix):
                hi += 1
        return index[lo:hi]


def _index_add(index: List[str], key: str) -> None:
    i = bisect.bisect_left(index, key)
    if i == len(index) or index[i] != key:
        index.insert(i, key)


def _index_drop(index: List[str], key: str) -> None:
    i = bisect.bisect_left(index, key)
    if i < len(index) and index[i] == key:
        del index[i]


class _Subscriber:
    """One registered callback with its ordered delivery queue.

    The dispatcher appends matched events and drains the queue in seq
    order; ``dead`` flips on unsubscribe so queued-but-undelivered events
    are dropped instead of invoking a retired callback."""

    __slots__ = ("prefix", "callback", "pending", "dead")

    def __init__(self, prefix: str, callback: Callable[[StoreEvent], None]):
        self.prefix = prefix
        self.callback = callback
        self.pending: collections.deque = collections.deque()
        self.dead = False

    def deliver(self) -> None:
        while self.pending:
            ev = self.pending.popleft()
            if self.dead:
                continue
            try:
                self.callback(ev)
            except Exception:
                pass  # a broken subscriber must not poison the dispatcher


class CoordinationStore:
    """Thread-safe, optionally durable, Redis-like coordination service.

    ``shards`` selects the number of lock stripes (1 ≈ the legacy global
    lock); ``dispatch`` is "queued" (events delivered by the dispatcher
    thread, mutators never wait) or "inline" (the mutating thread drains
    the event queue synchronously before returning — still outside the
    shard locks); ``wal_batch`` is the group-commit size (1 = flush every
    record, the legacy durability behaviour).
    """

    def __init__(
        self,
        wal_path: Optional[str] = None,
        replay: bool = True,
        *,
        shards: int = DEFAULT_SHARDS,
        dispatch: str = "queued",
        wal_batch: int = DEFAULT_WAL_BATCH,
    ):
        if dispatch not in ("queued", "inline"):
            raise ValueError(f"dispatch must be 'queued' or 'inline': {dispatch!r}")
        self._nshards = max(1, int(shards))
        self._shards = [_Shard() for _ in range(self._nshards)]
        self.dispatch_mode = dispatch
        self._fail_until = 0.0

        # ---- event plane (sequencing + subscription index + dispatcher)
        self._evlock = _make_lock("CoordinationStore._evlock")
        self._ev_cond = threading.Condition(self._evlock)
        self._seq = 0
        #: seq of the newest event actually enqueued for delivery — the
        #: flush_events barrier target (events with no matching subscriber
        #: are sequenced but complete immediately)
        self._enqueued_seq = 0
        self._delivered_seq = 0
        #: pending (event, [matched subscribers]) batches in seq order
        self._ev_pending: collections.deque = collections.deque()
        self._subs: Dict[int, _Subscriber] = {}
        self._sub_next = 0
        #: prefix -> subscriber tokens, plus the multiset of prefix lengths
        #: in use: matching a key is O(distinct prefix lengths) dict probes
        #: instead of a linear scan over every subscriber
        self._sub_prefixes: Dict[str, List[int]] = {}
        self._sub_lengths: collections.Counter = collections.Counter()
        self._dispatcher: Optional[threading.Thread] = None
        self._dispatch_stop = False
        self._inline_lock = _make_lock(
            "CoordinationStore._inline_lock", reentrant=True
        )

        # ---- durability (group-commit WAL)
        self._wal_path = wal_path
        self._wal_file = None
        self._wal_batch = max(1, int(wal_batch))
        self._wal_buf: List[str] = []
        self._wal_lock = _make_lock("CoordinationStore._wal_lock")
        self._wal_file_lock = _make_lock("CoordinationStore._wal_file_lock")
        self._wal_flusher: Optional[threading.Thread] = None
        self._wal_flusher_stop = threading.Event()
        self._op_count = 0
        if wal_path:
            if replay and os.path.exists(wal_path):
                self._replay(wal_path)
            self._wal_file = open(wal_path, "a", encoding="utf-8")
            if self._wal_batch > 1:
                self._wal_flusher = threading.Thread(
                    target=self._wal_flush_loop, name="wal-flusher", daemon=True
                )
                self._wal_flusher.start()

    # ------------------------------------------------------------- sharding
    def _shard_for(self, key: str) -> _Shard:
        """Stable key → stripe map: a CRC of the full key, so cu:/du:/
        pilot:/pd: records spread across every lock while a given key
        always lands on the same shard."""
        if self._nshards == 1:
            return self._shards[0]
        return self._shards[zlib.crc32(key.encode("utf-8")) % self._nshards]

    # ------------------------------------------------------------- failure
    def fail_for(self, seconds: float) -> None:
        """Inject a transient outage: all ops raise until the window ends."""
        self._fail_until = time.monotonic() + seconds

    def _check_up(self, shard: _Shard) -> None:
        """Liveness check + op accounting — called under ``shard``'s lock
        exactly once per public operation."""
        shard.ops += 1
        if time.monotonic() < self._fail_until:
            raise CoordinationUnavailable("coordination store unavailable")

    @property
    def ops_total(self) -> int:
        """Count of store operations issued so far (every public op checks
        liveness exactly once, so this is the op counter the O(changes)
        monitor micro-benchmarks read deltas from).  The sum over per-shard
        counters; int reads are atomic, so no lock is needed."""
        return sum(sh.ops for sh in self._shards)

    # ------------------------------------------------------------ durability
    def _log(self, op: str, *args: Any) -> bool:
        """Append one replay record to the group-commit buffer (called
        under a shard lock).  Returns True when the buffer crossed the
        batch threshold — the caller flushes AFTER releasing the shard
        lock, so file I/O never extends a critical section."""
        with self._wal_lock:
            self._op_count += 1
            if self._wal_file is None:
                return False
            self._wal_buf.append(json.dumps([op, *args], default=_default))
            return len(self._wal_buf) >= self._wal_batch

    def flush_wal(self) -> None:
        """Group-commit: write and flush every buffered WAL record.

        Batches drain in append order (the file lock serializes flushers),
        so the on-disk log remains a valid serialization prefix."""
        with self._wal_file_lock:
            with self._wal_lock:
                buf, self._wal_buf = self._wal_buf, []
            if buf and self._wal_file is not None:
                # reviewed: the file lock exists to serialize exactly this
                # I/O — it is a leaf lock, never taken under a shard or
                # event lock (PD-L005 graph), so holding it across the
                # write stalls only concurrent flushers, by design
                self._wal_file.write("\n".join(buf) + "\n")  # pdlint: disable=PD-L002
                self._wal_file.flush()  # pdlint: disable=PD-L002

    def _wal_flush_loop(self) -> None:
        while not self._wal_flusher_stop.wait(WAL_FLUSH_INTERVAL_S):
            try:
                self.flush_wal()
            except Exception:
                pass  # a closed file mid-shutdown must not kill the flusher

    def _replay(self, path: str) -> None:
        kv: Dict[str, Any] = {}
        hashes: Dict[str, Dict[str, Any]] = collections.defaultdict(dict)
        queues: Dict[str, collections.deque] = collections.defaultdict(
            collections.deque
        )
        with open(path, "r", encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    op, *args = json.loads(line)
                except (ValueError, TypeError):
                    # torn tail: a crash mid-group-commit may leave one
                    # partial record — the log is valid up to here
                    break
                if op == "set":
                    kv[args[0]] = args[1]
                elif op == "delete":
                    kv.pop(args[0], None)
                elif op == "hset":
                    hashes[args[0]][args[1]] = args[2]
                elif op == "hdel":
                    hashes.get(args[0], {}).pop(args[1], None)
                elif op == "push":
                    queues[args[0]].append(args[1])
                elif op == "pop":
                    q = queues.get(args[0])
                    if q:
                        q.popleft()
                elif op == "qremove":
                    q = queues.get(args[0])
                    if q and args[1] in q:
                        q.remove(args[1])
        for key, value in kv.items():
            sh = self._shard_for(key)
            sh.kv[key] = value
            _index_add(sh.kv_index, key)
        for key, fields in hashes.items():
            sh = self._shard_for(key)
            sh.hashes[key] = dict(fields)
            _index_add(sh.hash_index, key)
        for name, items in queues.items():
            self._shard_for(name).queues[name] = collections.deque(items)

    def close(self) -> None:
        # stop the dispatcher AFTER draining what is already sequenced, so
        # close() is also an event barrier; late mutations fall back to
        # inline delivery
        self._stop_dispatcher()
        if self._wal_flusher is not None:
            self._wal_flusher_stop.set()
            self._wal_flusher.join(timeout=2.0)
            self._wal_flusher = None
        self.flush_wal()
        with self._wal_file_lock:
            if self._wal_file is not None:
                self._wal_file.close()
                self._wal_file = None

    # -------------------------------------------------------- notifications
    def subscribe(
        self, callback: Callable[[StoreEvent], None], prefix: str = ""
    ) -> int:
        """Register ``callback`` for mutations on keys starting with
        ``prefix``.

        Delivery contract (sharded store): callbacks run on the store's
        dispatcher thread, OUTSIDE every store lock, in exact ``seq``
        order per subscriber.  They may re-enter the store freely, but a
        slow callback delays every later event (one dispatcher drains all
        subscribers), so heavy consumers should still hand off to their
        own queue/thread (see :class:`StoreEventPump`).  Mutating calls
        return before their event is delivered — use :meth:`flush_events`
        when a consumer must observe everything already written.  After
        ``unsubscribe`` returns, queued events are dropped; one callback
        already in flight on the dispatcher may still complete.
        """
        with self._evlock:
            token = self._sub_next
            self._sub_next += 1
            self._subs[token] = _Subscriber(prefix, callback)
            self._sub_prefixes.setdefault(prefix, []).append(token)
            self._sub_lengths[len(prefix)] += 1
            if (
                self.dispatch_mode == "queued"
                and self._dispatcher is None
                and not self._dispatch_stop
            ):
                # lazy: stores that never subscribe never spawn a thread
                self._dispatcher = threading.Thread(
                    target=self._dispatch_loop, name="store-dispatcher", daemon=True
                )
                self._dispatcher.start()
            return token

    def unsubscribe(self, token: int) -> None:
        with self._evlock:
            sub = self._subs.pop(token, None)
            if sub is None:
                return
            sub.dead = True
            tokens = self._sub_prefixes.get(sub.prefix)
            if tokens is not None:
                try:
                    tokens.remove(token)
                except ValueError:
                    pass
                if not tokens:
                    del self._sub_prefixes[sub.prefix]
            self._sub_lengths[len(sub.prefix)] -= 1
            if self._sub_lengths[len(sub.prefix)] <= 0:
                del self._sub_lengths[len(sub.prefix)]

    def _publish(self, op: str, key: str, field: Optional[str], value: Any) -> None:
        """Sequence one mutation and enqueue it for delivery.

        Called while the mutating shard lock is held: the event lock is
        tiny (counter + prefix-index probes + deque append), and taking it
        under the shard lock is what makes ``seq`` order consistent with
        per-key mutation order.  Actual delivery happens outside both."""
        with self._ev_cond:
            if not self._subs:
                return
            self._seq += 1
            matched: List[_Subscriber] = []
            klen = len(key)
            for plen in self._sub_lengths:
                if plen > klen:
                    continue
                for token in self._sub_prefixes.get(key[:plen], ()):
                    matched.append(self._subs[token])
            if not matched:
                return
            ev = StoreEvent(seq=self._seq, op=op, key=key, field=field, value=value)
            self._ev_pending.append((ev, matched))
            self._enqueued_seq = self._seq
            if self.dispatch_mode == "queued" and not self._dispatch_stop:
                self._ev_cond.notify_all()

    def _maybe_dispatch_inline(self) -> None:
        """Inline/fallback delivery: the mutating thread drains the pending
        queue (combining drain: whichever writer holds the drain lock
        delivers everyone's queued events in seq order), AFTER releasing
        its shard lock.  A writer returns only once its own event was
        delivered — by itself or by a concurrent writer."""
        if self.dispatch_mode == "queued" and not self._dispatch_stop:
            return
        with self._inline_lock:
            while True:
                with self._evlock:
                    if not self._ev_pending:
                        break
                    batch = list(self._ev_pending)
                    self._ev_pending.clear()
                for ev, matched in batch:
                    for sub in matched:
                        sub.pending.append(ev)
                        sub.deliver()
                with self._ev_cond:
                    self._delivered_seq = max(self._delivered_seq, batch[-1][0].seq)
                    self._ev_cond.notify_all()

    def _dispatch_loop(self) -> None:
        while True:
            with self._ev_cond:
                while not self._ev_pending and not self._dispatch_stop:
                    self._ev_cond.wait(timeout=0.5)
                if self._dispatch_stop and not self._ev_pending:
                    return
                batch = list(self._ev_pending)
                self._ev_pending.clear()
            for ev, matched in batch:
                for sub in matched:
                    sub.pending.append(ev)
                    sub.deliver()
            with self._ev_cond:
                self._delivered_seq = max(self._delivered_seq, batch[-1][0].seq)
                self._ev_cond.notify_all()

    def _stop_dispatcher(self) -> None:
        with self._ev_cond:
            self._dispatch_stop = True
            self._ev_cond.notify_all()
            dispatcher = self._dispatcher
        if dispatcher is not None:
            dispatcher.join(timeout=2.0)
            self._dispatcher = None
        self._maybe_dispatch_inline()  # anything sequenced after the stop

    def flush_events(self, timeout: float = 5.0) -> bool:
        """Barrier: block until every event sequenced before this call has
        been delivered to its subscribers.  Returns False on timeout.

        This is the determinism hook for consumers that used to rely on
        in-lock synchronous delivery (manual-stepping schedulers, monitor
        ticks, promotion drains): mutate, ``flush_events()``, then read
        the consumer's derived state.  Does not count as a store op.
        Calling it from inside a subscriber callback is a no-op (the
        dispatcher cannot wait on itself)."""
        if threading.current_thread() is self._dispatcher:
            return True
        self._maybe_dispatch_inline()
        deadline = time.monotonic() + timeout
        with self._ev_cond:
            target = self._enqueued_seq
            while self._delivered_seq < target:
                if self._dispatch_stop or self._dispatcher is None:
                    break
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._ev_cond.wait(remaining)
        return True

    def wait_field(
        self,
        key: str,
        field: str,
        predicate: Callable[[Any], bool],
        timeout: float = 30.0,
        default: Any = None,
        poll_s: float = 0.25,
    ) -> Any:
        """Block until ``predicate(hget(key, field))`` holds, event-driven.

        Subscribes to the key's keyspace notifications and sleeps on an
        Event, so waiters wake on the very mutation instead of burning a
        polling loop; ``poll_s`` bounds each sleep as a coarse fallback
        (covers a notification lost to subscriber races or store restore).
        Returns the field's final value (which may still fail the predicate
        if the timeout elapsed).
        """
        woke = threading.Event()

        def _cb(ev: StoreEvent) -> None:
            if ev.key == key and ev.field == field:
                woke.set()

        token = self.subscribe(_cb, prefix=key)
        try:
            deadline = time.monotonic() + timeout
            while True:
                # clear BEFORE reading: a mutation landing between the read
                # and the wait then re-sets the event and wakes us at once
                woke.clear()
                value = self.hget(key, field, default)
                if predicate(value):
                    return value
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return value
                woke.wait(min(remaining, poll_s))
        finally:
            self.unsubscribe(token)

    # -------------------------------------------------------------- kv ops
    def set(self, key: str, value: Any) -> None:
        sh = self._shard_for(key)
        with sh.lock:
            self._check_up(sh)
            if key not in sh.kv:
                _index_add(sh.kv_index, key)
            sh.kv[key] = value
            flush = self._log("set", key, value)
        if flush:
            self.flush_wal()

    def get(self, key: str, default: Any = None) -> Any:
        sh = self._shard_for(key)
        with sh.lock:
            self._check_up(sh)
            return sh.kv.get(key, default)

    def delete(self, key: str) -> None:
        sh = self._shard_for(key)
        with sh.lock:
            self._check_up(sh)
            if key in sh.kv:
                del sh.kv[key]
                _index_drop(sh.kv_index, key)
            flush = self._log("delete", key)
        if flush:
            self.flush_wal()

    def keys(self, prefix: str = "") -> List[str]:
        """Keys starting with ``prefix``, sorted — a bisect range scan per
        shard merged across shards: O(shards·log n + matches).  Only the
        per-shard slice copy happens under each stripe lock; the K-way
        merge of the already-sorted slices runs lock-free (PD-L006)."""
        parts: List[List[str]] = []
        for i, sh in enumerate(self._shards):
            with sh.lock:
                if i == 0:
                    self._check_up(sh)
                parts.append(sh.scan(sh.kv_index, prefix))
        return list(heapq.merge(*parts))

    # ------------------------------------------------------------ hash ops
    def hset(self, key: str, field: str, value: Any) -> None:
        sh = self._shard_for(key)
        with sh.lock:
            self._check_up(sh)
            h = sh.hashes.get(key)
            if h is None:
                h = sh.hashes[key] = {}
                _index_add(sh.hash_index, key)
            h[field] = value
            flush = self._log("hset", key, field, value)
            self._publish("hset", key, field, value)
        if flush:
            self.flush_wal()
        self._maybe_dispatch_inline()

    def hget(self, key: str, field: str, default: Any = None) -> Any:
        sh = self._shard_for(key)
        with sh.lock:
            self._check_up(sh)
            return sh.hashes.get(key, {}).get(field, default)

    def hgetall(self, key: str) -> Dict[str, Any]:
        sh = self._shard_for(key)
        with sh.lock:
            self._check_up(sh)
            return dict(sh.hashes.get(key, {}))

    def hdel(self, key: str, field: str) -> None:
        sh = self._shard_for(key)
        with sh.lock:
            self._check_up(sh)
            sh.hashes.get(key, {}).pop(field, None)
            flush = self._log("hdel", key, field)
        if flush:
            self.flush_wal()

    def hcas(self, key: str, field: str, expect: Any, value: Any) -> bool:
        """Atomic compare-and-set on a hash field.

        Returns True iff the field currently equals ``expect`` (and was set).
        This is the primitive behind exactly-once CU completion when
        straggler duplicates race (§ fault tolerance).  Atomicity is per
        key, which the shard lock provides — a key never spans shards.
        """
        sh = self._shard_for(key)
        with sh.lock:
            self._check_up(sh)
            h = sh.hashes.get(key)
            cur = None if h is None else h.get(field)
            if cur != expect:
                return False
            if h is None:
                h = sh.hashes[key] = {}
                _index_add(sh.hash_index, key)
            h[field] = value
            flush = self._log("hset", key, field, value)
            self._publish("hset", key, field, value)
        if flush:
            self.flush_wal()
        self._maybe_dispatch_inline()
        return True

    def hkeys(self, prefix: str = "") -> List[str]:
        """Hash keys starting with ``prefix``, sorted — bisect range scan
        per shard, O(shards·log n + matches) (the HeartbeatMonitor /
        StragglerMitigator O(changes) contract rides on this).  Slice
        copies under the stripe locks, lock-free merge (PD-L006)."""
        parts: List[List[str]] = []
        for i, sh in enumerate(self._shards):
            with sh.lock:
                if i == 0:
                    self._check_up(sh)
                parts.append(sh.scan(sh.hash_index, prefix))
        return list(heapq.merge(*parts))

    # ----------------------------------------------------------- queue ops
    def push(self, queue: str, item: Any) -> None:
        sh = self._shard_for(queue)
        with sh.lock:
            self._check_up(sh)
            dq = sh.queues.get(queue)
            if dq is None:
                dq = sh.queues[queue] = collections.deque()
            dq.append(item)
            flush = self._log("push", queue, item)
            # targeted wakeup: only waiters parked on THIS queue
            for waiter in sh.qwaiters.get(queue, ()):
                waiter.set()
            self._publish("push", queue, None, item)
        if flush:
            self.flush_wal()
        self._maybe_dispatch_inline()

    def pop(self, queue: str, timeout: float = 0.0) -> Optional[Any]:
        """Pop from one queue, blocking up to ``timeout`` seconds."""
        return self.pop_any([queue], timeout)

    def pop_any(self, queues: List[str], timeout: float = 0.0) -> Optional[Any]:
        """Pop the first available item from an ordered list of queues.

        An agent pulls from (its own pilot queue, the global queue) — §4.2:
        "Each Pilot-Agent generally pulls from two queues: its agent-specific
        queue and a global queue."

        Blocked callers park on a per-queue waiter event and are woken by
        the exact push (no store-wide ``notify_all``, no 50 ms poll), so an
        idle agent issues ~zero store ops while parked — one liveness
        check per wakeup pass, charged to the first queue's shard.
        """
        deadline = time.monotonic() + timeout
        waiter: Optional[threading.Event] = None
        registered: List[Tuple[_Shard, str]] = []
        try:
            while True:
                if waiter is not None:
                    waiter.clear()
                first = True
                for q in queues:
                    sh = self._shard_for(q)
                    with sh.lock:
                        if first:
                            # one liveness check + op per pass, like the
                            # legacy loop — but passes are now O(pushes)
                            self._check_up(sh)
                            first = False
                        dq = sh.queues.get(q)
                        if dq:
                            item = dq.popleft()
                            flush = self._log("pop", q)
                        else:
                            continue
                    if flush:
                        self.flush_wal()
                    return item
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return None
                if waiter is None:
                    # register FIRST, then re-check before waiting: a push
                    # landing between the check and the wait sets the
                    # event, so the wakeup cannot be lost
                    waiter = threading.Event()
                    for q in queues:
                        sh = self._shard_for(q)
                        with sh.lock:
                            sh.qwaiters.setdefault(q, []).append(waiter)
                            registered.append((sh, q))
                    continue
                waiter.wait(min(remaining, POP_FAIL_POLL_S))
        finally:
            if waiter is not None:
                for sh, q in registered:
                    with sh.lock:
                        lst = sh.qwaiters.get(q)
                        if lst is not None:
                            try:
                                lst.remove(waiter)
                            except ValueError:
                                pass
                            if not lst:
                                del sh.qwaiters[q]

    def qlen(self, queue: str) -> int:
        sh = self._shard_for(queue)
        with sh.lock:
            self._check_up(sh)
            return len(sh.queues.get(queue, ()))

    def qpeek(self, queue: str) -> List[Any]:
        sh = self._shard_for(queue)
        with sh.lock:
            self._check_up(sh)
            return list(sh.queues.get(queue, ()))

    def qremove(self, queue: str, item: Any) -> bool:
        sh = self._shard_for(queue)
        flush = False
        try:
            with sh.lock:
                self._check_up(sh)
                dq = sh.queues.get(queue)
                if dq and item in dq:
                    dq.remove(item)
                    flush = self._log("qremove", queue, item)
                    return True
                return False
        finally:
            if flush:
                self.flush_wal()

    # ----------------------------------------------------------- snapshot
    def _lock_all(self) -> None:
        # reviewed: stripes are acquired in ascending index order (and
        # _unlock_all releases in reverse), so the same-class nesting the
        # static analyzer cannot order-prove is in fact deadlock-free
        for sh in self._shards:
            sh.lock.acquire()  # pdlint: disable=PD-L005

    def _unlock_all(self) -> None:
        for sh in reversed(self._shards):
            sh.lock.release()

    def snapshot(self) -> Dict[str, Any]:
        """Point-in-time copy of the full store (all shard locks held in
        index order for a consistent cut)."""
        self._lock_all()
        try:
            kv: Dict[str, Any] = {}
            hashes: Dict[str, Dict[str, Any]] = {}
            queues: Dict[str, List[Any]] = {}
            for sh in self._shards:
                kv.update(sh.kv)
                for k, v in sh.hashes.items():
                    hashes[k] = dict(v)
                for k, v in sh.queues.items():
                    queues[k] = list(v)
            return {"kv": kv, "hashes": hashes, "queues": queues}
        finally:
            self._unlock_all()

    def restore(self, snap: Dict[str, Any]) -> None:
        self._lock_all()
        try:
            waiters: List[threading.Event] = []
            for sh in self._shards:
                for lst in sh.qwaiters.values():
                    waiters.extend(lst)
                sh.kv = {}
                sh.hashes = {}
                sh.queues = {}
                sh.kv_index = []
                sh.hash_index = []
            for key, value in snap["kv"].items():
                sh = self._shard_for(key)
                sh.kv[key] = value
                _index_add(sh.kv_index, key)
            for key, fields in snap["hashes"].items():
                sh = self._shard_for(key)
                sh.hashes[key] = dict(fields)
                _index_add(sh.hash_index, key)
            for name, items in snap["queues"].items():
                self._shard_for(name).queues[name] = collections.deque(items)
            # parked pop_any waiters must re-check against the new state
            for waiter in waiters:
                waiter.set()
        finally:
            self._unlock_all()


class StoreEventPump:
    """Subscribe → handoff queue → one daemon consumer thread.

    The subscriber contract (callbacks run on the store's dispatcher
    thread, outside the store locks, but a slow callback delays every
    later event) makes this the canonical consumption pattern for heavy
    consumers — the dependency gate and the future dispatcher both ride
    it.  ``accept`` filters on the dispatcher thread (cheap predicate
    only); ``handler`` runs accepted events on the pump thread and may
    block or re-enter the store freely.  ``inject`` enqueues a synthetic
    event, serializing caller-side re-checks with the live stream.
    """

    def __init__(
        self,
        store: "CoordinationStore",
        handler: Callable[[StoreEvent], None],
        prefix: str = "",
        accept: Optional[Callable[[StoreEvent], bool]] = None,
        name: str = "store-event-pump",
    ):
        self._store = store
        self._handler = handler
        self._accept = accept
        self._events: "queue.Queue[StoreEvent]" = queue.Queue()
        self._stop = threading.Event()
        self._token = store.subscribe(self._on_event, prefix=prefix)
        self._thread = threading.Thread(target=self._run, name=name, daemon=True)
        self._thread.start()

    def _on_event(self, ev: StoreEvent) -> None:
        if self._accept is None or self._accept(ev):
            self._events.put(ev)

    def inject(self, ev: StoreEvent) -> None:
        """Queue a synthetic event (bypasses ``accept``)."""
        self._events.put(ev)

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                ev = self._events.get(timeout=0.1)
            except queue.Empty:
                continue
            try:
                self._handler(ev)
            except Exception:
                pass  # a broken handler must not kill the pump

    def stop(self) -> None:
        self._stop.set()
        self._store.unsubscribe(self._token)
        self._thread.join(timeout=2.0)


def with_retry(
    fn: Callable[[], Any],
    retries: int = 50,
    base_delay: float = 0.02,
    max_delay: float = 0.5,
) -> Any:
    """Run ``fn`` retrying across transient :class:`CoordinationUnavailable`.

    Exponential backoff with a cap; this is the client-side half of the
    paper's "survive transient Redis failures" behaviour.
    """
    delay = base_delay
    for attempt in range(retries):
        try:
            return fn()
        except CoordinationUnavailable:
            if attempt == retries - 1:
                raise
            time.sleep(delay)
            delay = min(max_delay, delay * 2)


if os.environ.get("REPRO_LOCK_WITNESS", "").strip() not in ("", "0"):
    # debug mode: wrap every store lock created from here on in the
    # runtime lock-order witness (the witness-enabled tier-1 CI job runs
    # the whole suite this way, validating the static PD-L005 graph
    # against real executions)
    from repro.analysis.witness import install as _install_lock_witness

    _install_lock_witness()
