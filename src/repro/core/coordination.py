"""Coordination & control store — the framework's externalized state.

The paper (§4.2, "Distributed Coordination and Control Management") keeps the
*complete* state of the framework in a shared in-memory data store (Redis):
pilot/CU/DU descriptions and states, per-pilot and global work queues, and
resource information pushed by agents.  That externalization is what buys the
fault-tolerance story: managers and agents can disconnect and reconnect, the
store can be snapshotted/restarted, and clients survive transient store
failures.

This module is an embedded, thread-safe re-implementation of exactly that
protocol.  It is *not* a toy dict: it supports

  * namespaced key/value and hash records (``set/get/hset/hgetall``),
  * blocking FIFO queues (``push/pop``) — the global CU queue and the
    per-pilot queues of §4.2 map 1:1 onto these,
  * atomic compare-and-set on hash fields (used for exactly-once CU state
    transitions, e.g. straggler-duplicate "first finisher wins"),
  * durability via a JSON write-ahead log (replayable on restart),
  * fault injection (``fail_for``): operations raise
    :class:`CoordinationUnavailable` for a window, so client retry loops can
    be tested (the paper: "agent and manager are able to survive transient
    Redis failures"), and
  * keyspace notifications (``subscribe``/``unsubscribe``): mutating ops
    (``hset``/``hcas``/``push``) publish :class:`StoreEvent` records to
    registered callbacks — the Redis-keyspace-notification analogue that the
    event-driven scheduler reacts to instead of polling.  Events carry a
    store-wide monotonic sequence number, so a single consumer observes a
    total order over state transitions (the determinism anchor for the
    async scheduler's event log).  Notifications are transient (not WAL'd);
    replay reconstructs state, not the event stream.

The interface is deliberately Redis-shaped so a networked store could be
substituted without touching managers or agents.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import os
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple


class CoordinationUnavailable(RuntimeError):
    """Raised while the store is in an (injected or real) failure window."""


@dataclasses.dataclass(frozen=True)
class StoreEvent:
    """One published keyspace notification.

    ``op`` is "hset" (covers hcas winners too) or "push"; ``key`` is the
    hash key or queue name; ``field`` is the hash field (None for pushes);
    ``value`` the new value / pushed item.
    """

    seq: int
    op: str
    key: str
    field: Optional[str]
    value: Any


def _default(obj: Any) -> Any:
    if isinstance(obj, (set, frozenset)):
        return sorted(obj)
    raise TypeError(f"not JSON serializable: {type(obj)}")


class CoordinationStore:
    """Thread-safe, optionally durable, Redis-like coordination service."""

    def __init__(self, wal_path: Optional[str] = None, replay: bool = True):
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._kv: Dict[str, Any] = {}
        self._hashes: Dict[str, Dict[str, Any]] = collections.defaultdict(dict)
        self._queues: Dict[str, collections.deque] = collections.defaultdict(
            collections.deque
        )
        self._fail_until = 0.0
        self._wal_path = wal_path
        self._wal_file = None
        self._op_count = 0
        self._ops_total = 0
        self._seq = 0
        self._subs: Dict[int, Tuple[str, Callable[[StoreEvent], None]]] = {}
        self._sub_next = 0
        if wal_path:
            if replay and os.path.exists(wal_path):
                self._replay(wal_path)
            self._wal_file = open(wal_path, "a", encoding="utf-8")

    # ------------------------------------------------------------- failure
    def fail_for(self, seconds: float) -> None:
        """Inject a transient outage: all ops raise until the window ends."""
        with self._lock:
            self._fail_until = time.monotonic() + seconds

    def _check_up(self) -> None:
        self._ops_total += 1
        if time.monotonic() < self._fail_until:
            raise CoordinationUnavailable("coordination store unavailable")

    @property
    def ops_total(self) -> int:
        """Count of store operations issued so far (every public op checks
        liveness exactly once, so this is the op counter the O(changes)
        monitor micro-benchmarks read deltas from)."""
        with self._lock:
            return self._ops_total

    # ------------------------------------------------------------ durability
    def _log(self, op: str, *args: Any) -> None:
        self._op_count += 1
        if self._wal_file is not None:
            self._wal_file.write(json.dumps([op, *args], default=_default) + "\n")
            self._wal_file.flush()

    def _replay(self, path: str) -> None:
        with open(path, "r", encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                op, *args = json.loads(line)
                if op == "set":
                    self._kv[args[0]] = args[1]
                elif op == "delete":
                    self._kv.pop(args[0], None)
                elif op == "hset":
                    self._hashes[args[0]][args[1]] = args[2]
                elif op == "hdel":
                    self._hashes.get(args[0], {}).pop(args[1], None)
                elif op == "push":
                    self._queues[args[0]].append(args[1])
                elif op == "pop":
                    q = self._queues.get(args[0])
                    if q:
                        q.popleft()
                elif op == "qremove":
                    q = self._queues.get(args[0])
                    if q and args[1] in q:
                        q.remove(args[1])

    def close(self) -> None:
        if self._wal_file is not None:
            self._wal_file.close()
            self._wal_file = None

    # -------------------------------------------------------- notifications
    def subscribe(
        self, callback: Callable[[StoreEvent], None], prefix: str = ""
    ) -> int:
        """Register ``callback`` for mutations on keys starting with
        ``prefix``.  Callbacks run on the mutating thread while it still
        holds the store lock — that is what makes delivery match the
        sequence-number total order when writers race.  They must be fast
        and non-blocking (typically: enqueue into the consumer's own event
        queue); store re-entry from a callback is safe (RLock) but other
        locks must not be taken."""
        with self._lock:
            token = self._sub_next
            self._sub_next += 1
            self._subs[token] = (prefix, callback)
            return token

    def unsubscribe(self, token: int) -> None:
        with self._lock:
            self._subs.pop(token, None)

    def _collect(
        self, op: str, key: str, field: Optional[str], value: Any
    ) -> List[Tuple[Callable[[StoreEvent], None], StoreEvent]]:
        """Build the dispatch list for one mutation (called under the lock;
        dispatch also happens under the lock so subscribers observe events
        in exact sequence order even when writers race)."""
        if not self._subs:
            return []
        self._seq += 1
        ev = StoreEvent(seq=self._seq, op=op, key=key, field=field, value=value)
        return [
            (cb, ev) for prefix, cb in self._subs.values()
            if key.startswith(prefix)
        ]

    @staticmethod
    def _dispatch(
        pending: List[Tuple[Callable[[StoreEvent], None], StoreEvent]]
    ) -> None:
        for cb, ev in pending:
            try:
                cb(ev)
            except Exception:
                pass  # a broken subscriber must not poison writers

    def wait_field(
        self,
        key: str,
        field: str,
        predicate: Callable[[Any], bool],
        timeout: float = 30.0,
        default: Any = None,
        poll_s: float = 0.25,
    ) -> Any:
        """Block until ``predicate(hget(key, field))`` holds, event-driven.

        Subscribes to the key's keyspace notifications and sleeps on an
        Event, so waiters wake on the very mutation instead of burning a
        polling loop; ``poll_s`` bounds each sleep as a coarse fallback
        (covers a notification lost to subscriber races or store restore).
        Returns the field's final value (which may still fail the predicate
        if the timeout elapsed).
        """
        woke = threading.Event()

        def _cb(ev: StoreEvent) -> None:
            if ev.key == key and ev.field == field:
                woke.set()

        token = self.subscribe(_cb, prefix=key)
        try:
            deadline = time.monotonic() + timeout
            while True:
                # clear BEFORE reading: a mutation landing between the read
                # and the wait then re-sets the event and wakes us at once
                woke.clear()
                value = self.hget(key, field, default)
                if predicate(value):
                    return value
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return value
                woke.wait(min(remaining, poll_s))
        finally:
            self.unsubscribe(token)

    # -------------------------------------------------------------- kv ops
    def set(self, key: str, value: Any) -> None:
        with self._lock:
            self._check_up()
            self._kv[key] = value
            self._log("set", key, value)
            self._cond.notify_all()

    def get(self, key: str, default: Any = None) -> Any:
        with self._lock:
            self._check_up()
            return self._kv.get(key, default)

    def delete(self, key: str) -> None:
        with self._lock:
            self._check_up()
            self._kv.pop(key, None)
            self._log("delete", key)

    def keys(self, prefix: str = "") -> List[str]:
        with self._lock:
            self._check_up()
            return sorted(k for k in self._kv if k.startswith(prefix))

    # ------------------------------------------------------------ hash ops
    def hset(self, key: str, field: str, value: Any) -> None:
        with self._lock:
            self._check_up()
            self._hashes[key][field] = value
            self._log("hset", key, field, value)
            self._cond.notify_all()
            self._dispatch(self._collect("hset", key, field, value))

    def hget(self, key: str, field: str, default: Any = None) -> Any:
        with self._lock:
            self._check_up()
            return self._hashes.get(key, {}).get(field, default)

    def hgetall(self, key: str) -> Dict[str, Any]:
        with self._lock:
            self._check_up()
            return dict(self._hashes.get(key, {}))

    def hdel(self, key: str, field: str) -> None:
        with self._lock:
            self._check_up()
            self._hashes.get(key, {}).pop(field, None)
            self._log("hdel", key, field)

    def hcas(self, key: str, field: str, expect: Any, value: Any) -> bool:
        """Atomic compare-and-set on a hash field.

        Returns True iff the field currently equals ``expect`` (and was set).
        This is the primitive behind exactly-once CU completion when
        straggler duplicates race (§ fault tolerance).
        """
        with self._lock:
            self._check_up()
            cur = self._hashes.get(key, {}).get(field)
            if cur != expect:
                return False
            self._hashes[key][field] = value
            self._log("hset", key, field, value)
            self._cond.notify_all()
            self._dispatch(self._collect("hset", key, field, value))
            return True

    def hkeys(self, prefix: str = "") -> List[str]:
        with self._lock:
            self._check_up()
            return sorted(k for k in self._hashes if k.startswith(prefix))

    # ----------------------------------------------------------- queue ops
    def push(self, queue: str, item: Any) -> None:
        with self._lock:
            self._check_up()
            self._queues[queue].append(item)
            self._log("push", queue, item)
            self._cond.notify_all()
            self._dispatch(self._collect("push", queue, None, item))

    def pop(self, queue: str, timeout: float = 0.0) -> Optional[Any]:
        """Pop from one queue, blocking up to ``timeout`` seconds."""
        return self.pop_any([queue], timeout)

    def pop_any(self, queues: List[str], timeout: float = 0.0) -> Optional[Any]:
        """Pop the first available item from an ordered list of queues.

        An agent pulls from (its own pilot queue, the global queue) — §4.2:
        "Each Pilot-Agent generally pulls from two queues: its agent-specific
        queue and a global queue."
        """
        deadline = time.monotonic() + timeout
        with self._cond:
            while True:
                self._check_up()
                for q in queues:
                    dq = self._queues.get(q)
                    if dq:
                        item = dq.popleft()
                        self._log("pop", q)
                        return item
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return None
                self._cond.wait(min(remaining, 0.05))

    def qlen(self, queue: str) -> int:
        with self._lock:
            self._check_up()
            return len(self._queues.get(queue, ()))

    def qpeek(self, queue: str) -> List[Any]:
        with self._lock:
            self._check_up()
            return list(self._queues.get(queue, ()))

    def qremove(self, queue: str, item: Any) -> bool:
        with self._lock:
            self._check_up()
            dq = self._queues.get(queue)
            if dq and item in dq:
                dq.remove(item)
                self._log("qremove", queue, item)
                return True
            return False

    # ----------------------------------------------------------- snapshot
    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "kv": dict(self._kv),
                "hashes": {k: dict(v) for k, v in self._hashes.items()},
                "queues": {k: list(v) for k, v in self._queues.items()},
            }

    def restore(self, snap: Dict[str, Any]) -> None:
        with self._lock:
            self._kv = dict(snap["kv"])
            self._hashes = collections.defaultdict(dict)
            for k, v in snap["hashes"].items():
                self._hashes[k] = dict(v)
            self._queues = collections.defaultdict(collections.deque)
            for k, v in snap["queues"].items():
                self._queues[k] = collections.deque(v)
            self._cond.notify_all()


class StoreEventPump:
    """Subscribe → handoff queue → one daemon consumer thread.

    The subscriber contract (callbacks run on the mutating thread while it
    holds the store lock: be fast, non-blocking, take no foreign locks)
    makes this the canonical consumption pattern — the dependency gate and
    the future dispatcher both ride it.  ``accept`` filters on the
    mutating thread (cheap predicate only); ``handler`` runs accepted
    events on the pump thread, outside the store lock, and may block or
    re-enter the store freely.  ``inject`` enqueues a synthetic event,
    serializing caller-side re-checks with the live stream.
    """

    def __init__(
        self,
        store: "CoordinationStore",
        handler: Callable[[StoreEvent], None],
        prefix: str = "",
        accept: Optional[Callable[[StoreEvent], bool]] = None,
        name: str = "store-event-pump",
    ):
        self._store = store
        self._handler = handler
        self._accept = accept
        self._events: "queue.Queue[StoreEvent]" = queue.Queue()
        self._stop = threading.Event()
        self._token = store.subscribe(self._on_event, prefix=prefix)
        self._thread = threading.Thread(target=self._run, name=name, daemon=True)
        self._thread.start()

    def _on_event(self, ev: StoreEvent) -> None:
        if self._accept is None or self._accept(ev):
            self._events.put(ev)

    def inject(self, ev: StoreEvent) -> None:
        """Queue a synthetic event (bypasses ``accept``)."""
        self._events.put(ev)

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                ev = self._events.get(timeout=0.1)
            except queue.Empty:
                continue
            try:
                self._handler(ev)
            except Exception:
                pass  # a broken handler must not kill the pump

    def stop(self) -> None:
        self._stop.set()
        self._store.unsubscribe(self._token)
        self._thread.join(timeout=2.0)


def with_retry(
    fn: Callable[[], Any],
    retries: int = 50,
    base_delay: float = 0.02,
    max_delay: float = 0.5,
) -> Any:
    """Run ``fn`` retrying across transient :class:`CoordinationUnavailable`.

    Exponential backoff with a cap; this is the client-side half of the
    paper's "survive transient Redis failures" behaviour.
    """
    delay = base_delay
    for attempt in range(retries):
        try:
            return fn()
        except CoordinationUnavailable:
            if attempt == retries - 1:
                raise
            time.sleep(delay)
            delay = min(max_delay, delay * 2)
