"""The Pilot-API (§4.3): PilotComputeService, PilotDataService, and the
Compute-Data Service (the affinity-based workload manager of §5).

Multi-level scheduling, exactly as the paper separates it:
  * resource allocation — services that start Pilot-Computes / Pilot-Data
    ("the start of the Pilot") — and
  * workload management — the Compute-Data Service that late-binds CUs and
    DUs onto those pilots using the affinity model and the §6.1 calculus.

The CDS scheduler implements the paper's placement loop verbatim (§5):

  1. find the pilot that best fulfills the CU's requested affinity and the
     location of its input data;
  2. if a pilot with the same affinity exists and has an empty slot, place
     the CU in that pilot's queue;
  3. if delayed scheduling is active, wait n sec and re-check for a free
     slot;
  4. otherwise place the CU in the global queue, pulled by the first pilot
     with an available slot.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

from .agent import GLOBAL_QUEUE
from .compute_unit import ComputeUnit, ComputeUnitDescription, CUState
from .placement import PlacementEngine, PlacementStrategy, make_strategy
from .data_unit import DataUnit, DataUnitDescription
from .pilot import (
    PilotCompute,
    PilotComputeDescription,
    PilotData,
    PilotDataDescription,
    PilotState,
    RuntimeContext,
)
from .transfer import TransferService


class PilotComputeService:
    """Factory for Pilot-Computes (paper §4.3.1)."""

    def __init__(self, ctx: RuntimeContext):
        self.ctx = ctx
        if ctx.transfer_service is None:
            TransferService(ctx)
        self._pilots: List[PilotCompute] = []

    def create_pilot(self, desc: PilotComputeDescription) -> PilotCompute:
        pilot = PilotCompute(desc, self.ctx)
        self.ctx.register(pilot)
        self.ctx.register(pilot.sandbox)
        pilot.start()
        self._pilots.append(pilot)
        return pilot

    def list_pilots(self) -> List[PilotCompute]:
        return list(self._pilots)

    def cancel(self) -> None:
        for p in self._pilots:
            p.cancel()


class PilotDataService:
    """Factory for Pilot-Data (paper §4.3.1)."""

    def __init__(self, ctx: RuntimeContext):
        self.ctx = ctx
        if ctx.transfer_service is None:
            TransferService(ctx)
        self._pds: List[PilotData] = []

    def create_pilot_data(self, desc: PilotDataDescription) -> PilotData:
        pd = PilotData(desc, self.ctx)
        self.ctx.register(pd)
        self._pds.append(pd)
        return pd

    def list_pilot_data(self) -> List[PilotData]:
        return list(self._pds)


class ComputeDataService:
    """Workload manager: late-binds CUs/DUs to pilots by affinity (§5)."""

    def __init__(
        self,
        ctx: RuntimeContext,
        delayed_scheduling_s: float = 0.0,
        avg_cu_estimate_s: float = 0.05,
        strategy: str = "cost",
        start_loop: bool = True,
    ):
        self.ctx = ctx
        if ctx.transfer_service is None:
            TransferService(ctx)
        self.delayed_scheduling_s = delayed_scheduling_s
        self.avg_cu_estimate_s = avg_cu_estimate_s
        self.engine = PlacementEngine(ctx, avg_cu_estimate_s=avg_cu_estimate_s)
        self.strategy: PlacementStrategy = (
            strategy if isinstance(strategy, PlacementStrategy)
            else make_strategy(strategy)
        )
        self._pilots: List[PilotCompute] = []
        self._pds: List[PilotData] = []
        self._cus: List[ComputeUnit] = []
        self._dus: List[DataUnit] = []
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._delayed: List[Dict] = []  # {"cu":…, "deadline":…, "pilot":…}
        self._decisions: List[Dict] = []  # audit log of placement choices
        #: invoked with (cu, pilot) just before a CU lands on a pilot queue
        #: — the async scheduler hangs its prefetch pipeline here so the
        #: staging claim exists before any agent can see the CU
        self.pre_push_hook: Optional[Callable] = None
        self._thread: Optional[threading.Thread] = None
        if start_loop:
            # Legacy sync mode: a polling loop owns placement.  In async
            # mode the AsyncScheduler drains the incoming queue instead
            # (event-driven), so no thread is started here.
            self._thread = threading.Thread(
                target=self._scheduler_loop, name="cds-scheduler", daemon=True
            )
            self._thread.start()

    # --------------------------------------------------------- registration
    def add_pilot_compute(self, pilot: PilotCompute) -> None:
        with self._lock:
            self._pilots.append(pilot)

    def add_pilot_data(self, pd: PilotData) -> None:
        with self._lock:
            self._pds.append(pd)

    def pilots(self) -> List[PilotCompute]:
        with self._lock:
            return list(self._pilots)

    def pilot_data(self) -> List[PilotData]:
        with self._lock:
            return list(self._pds)

    # ----------------------------------------------------------- submission
    def submit_data_unit(
        self, desc: DataUnitDescription, target: Optional[PilotData] = None
    ) -> DataUnit:
        """Create a DU and stage it into an affinity-appropriate PD.

        The DU's physical representation is its chunk manifest
        (``desc.chunk_size``); the first ingest registers the target PD as
        a full replica in ``locations`` and further holdings — including
        partial, chunk-level ones — accumulate in the store's
        ``du:<id>:chunks`` hash."""
        du = DataUnit(desc, self.ctx.store)
        self.ctx.register(du)
        with self._lock:
            self._dus.append(du)
        pd = target or self._choose_pd(desc)
        if pd is not None and du.size > 0:
            from .data_unit import DUState

            self.ctx.store.hset(f"du:{du.id}", "state", DUState.PENDING)
            self.ctx.transfer_service.ingest(du, pd)
        return du

    def submit_compute_unit(self, desc: ComputeUnitDescription) -> ComputeUnit:
        cu = ComputeUnit(desc, self.ctx.store)
        self.ctx.register(cu)
        cu.timings.submitted = time.monotonic()
        cu._set_state(CUState.PENDING)
        with self._lock:
            self._cus.append(cu)
        # Asynchronous interface (§4.2): enqueue and return immediately.
        self.ctx.store.push("cds:incoming", cu.id)
        return cu

    def compute_units(self) -> List[ComputeUnit]:
        with self._lock:
            return list(self._cus)

    def data_units(self) -> List[DataUnit]:
        with self._lock:
            return list(self._dus)

    # ----------------------------------------------------------- scheduling
    def _choose_pd(self, desc: DataUnitDescription) -> Optional[PilotData]:
        """Affinity-aware PD selection for a new DU."""
        from .affinity import match_affinity

        with self._lock:
            pds = list(self._pds)
        need = max(desc.size_hint, sum(map(len, desc.files.values())))
        fits = [pd for pd in pds if pd.free_bytes >= need]
        candidates = [
            pd for pd in fits if match_affinity(desc.affinity, pd.affinity)
        ]
        if not candidates:
            candidates = fits  # affinity miss: any PD with space
        if not candidates:
            return None  # nowhere fits — DU stays in its local buffer
        # Prefer the emptiest (simple balance; the cost model handles the
        # rest at CU-placement time).
        return max(candidates, key=lambda pd: pd.free_bytes)

    def _has_free_slot(self, pilot: PilotCompute) -> bool:
        depth = self.ctx.store.qlen(pilot.queue_name)
        running = len(pilot.running_cus())
        return pilot.state == PilotState.ACTIVE and (
            running + depth < pilot.slots
        )

    def place(self, cu: ComputeUnit) -> Optional[PilotCompute]:
        """One pass of the §5 placement algorithm for one CU.

        Shared by both execution modes (the sync polling loop and the
        event-driven AsyncScheduler call exactly this), which is what keeps
        their placement decisions identical.  Returns the pilot whose queue
        received the CU, or None (global queue / delayed)."""
        desc = cu.description
        if desc.pilot is not None:
            # Application-level direct binding (§4.3.2 control level (i)).
            pilot: PilotCompute = self.ctx.lookup(desc.pilot)
            self._push_to_pilot(cu, pilot)
            return pilot
        with self._lock:
            pilots = list(self._pilots)
        ranked = self.strategy.rank(cu, self.engine.candidates(cu, pilots))
        if not ranked:
            self.ctx.store.push(GLOBAL_QUEUE, {"cu": cu.id, "dup": False})
            return None
        best = ranked[0]
        self._decisions.append(
            {
                "cu": cu.id,
                "pilot": best.pilot.id,
                "t_q": best.t_queue,
                "t_stage": best.t_stage,
                "strategy": best.strategy,
                "policy": self.strategy.name,
            }
        )
        # Step 2: same-affinity pilot with an empty slot → pilot queue.
        if self._has_free_slot(best.pilot):
            self._push_to_pilot(cu, best.pilot)
            return best.pilot
        # Steps 3/4 leave the CU off the winner's queue for now — but the
        # winner is still where it will most likely run, so the async
        # pipeline prefetches its inputs there speculatively (staging
        # overlaps the work the pilot is currently busy with; a sandbox
        # replica also helps any other pilot via cheapest-replica).
        if self.pre_push_hook is not None:
            try:
                self.pre_push_hook(cu, best.pilot)
            except Exception:
                pass
        # Step 3: delayed scheduling — wait n sec, recheck.
        if self.delayed_scheduling_s > 0:
            with self._lock:
                self._delayed.append(
                    {
                        "cu": cu,
                        "pilot": best.pilot,
                        "deadline": time.monotonic()
                        + self.delayed_scheduling_s,
                    }
                )
            return None
        # Step 4: global queue — first pilot with a slot pulls it.
        self.ctx.store.push(GLOBAL_QUEUE, {"cu": cu.id, "dup": False})
        return None

    def _push_to_pilot(self, cu: ComputeUnit, pilot: PilotCompute) -> None:
        if self.pre_push_hook is not None:
            try:
                self.pre_push_hook(cu, pilot)
            except Exception:
                pass
        if self.ctx.data_mode == "push":
            # Push-mode data management (§4.2): the manager pre-stages the
            # input DUs into the pilot sandbox before the CU is queued.
            for du_id in cu.description.input_data:
                du: DataUnit = self.ctx.lookup(du_id)
                self.ctx.transfer_service.stage_in(
                    du, pilot.sandbox, pilot.affinity
                )
        self.ctx.store.push(pilot.queue_name, {"cu": cu.id, "dup": False})

    def recheck_delayed(self) -> List[tuple]:
        """Re-check delayed CUs (step 3); returns [(cu, pilot)] placed onto
        a pilot queue this pass (the async scheduler prefetches those)."""
        store = self.ctx.store
        now = time.monotonic()
        placed: List[tuple] = []
        with self._lock:
            entries, self._delayed = self._delayed, []
        still: List[Dict] = []
        for entry in entries:
            cu, pilot = entry["cu"], entry["pilot"]
            if cu.state != CUState.PENDING:
                continue
            if self._has_free_slot(pilot):
                self._push_to_pilot(cu, pilot)
                placed.append((cu, pilot))
            elif now >= entry["deadline"]:
                store.push(GLOBAL_QUEUE, {"cu": cu.id, "dup": False})
            else:
                still.append(entry)
        with self._lock:
            self._delayed.extend(still)
        return placed

    def _scheduler_loop(self) -> None:
        store = self.ctx.store
        while not self._stop.is_set():
            try:
                cu_id = store.pop("cds:incoming", timeout=0.02)
            except Exception:
                time.sleep(0.05)
                continue
            if cu_id is not None:
                try:
                    cu = self.ctx.lookup(cu_id)
                    if cu.state == CUState.PENDING:
                        self.place(cu)
                except Exception:
                    pass
            self.recheck_delayed()

    # ------------------------------------------------------------- control
    def decisions(self) -> List[Dict]:
        return list(self._decisions)

    def wait(self, timeout: float = 120.0) -> bool:
        """Block until every submitted CU is terminal.  True on success."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                cus = list(self._cus)
            if all(c.state in CUState.TERMINAL for c in cus):
                return True
            time.sleep(0.01)
        return False

    def cancel(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
