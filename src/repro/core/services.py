"""The Pilot-API (§4.3): PilotComputeService, PilotDataService, and the
Compute-Data Service (the affinity-based workload manager of §5).

Multi-level scheduling, exactly as the paper separates it:
  * resource allocation — services that start Pilot-Computes / Pilot-Data
    ("the start of the Pilot") — and
  * workload management — the Compute-Data Service that late-binds CUs and
    DUs onto those pilots using the affinity model and the §6.1 calculus.

The CDS scheduler implements the paper's placement loop verbatim (§5):

  1. find the pilot that best fulfills the CU's requested affinity and the
     location of its input data;
  2. if a pilot with the same affinity exists and has an empty slot, place
     the CU in that pilot's queue;
  3. if delayed scheduling is active, wait n sec and re-check for a free
     slot;
  4. otherwise place the CU in the global queue, pulled by the first pilot
     with an available slot.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Deque, Dict, List, Optional, Set, Tuple

from .agent import GLOBAL_QUEUE
from .compute_unit import ComputeUnit, ComputeUnitDescription, CUState
from .coordination import StoreEvent, StoreEventPump
from .placement import PlacementEngine, PlacementStrategy, make_strategy
from .data_unit import DataUnit, DataUnitDescription, DUState
from .pilot import (
    PilotCompute,
    PilotComputeDescription,
    PilotData,
    PilotDataDescription,
    PilotState,
    RuntimeContext,
)
from .tenancy import DEFAULT_TENANT, TenantRegistry
from .transfer import TransferService


class PilotComputeService:
    """Factory for Pilot-Computes (paper §4.3.1)."""

    def __init__(self, ctx: RuntimeContext):
        self.ctx = ctx
        if ctx.transfer_service is None:
            TransferService(ctx)
        self._pilots: List[PilotCompute] = []

    def create_pilot(self, desc: PilotComputeDescription) -> PilotCompute:
        pilot = PilotCompute(desc, self.ctx)
        self.ctx.register(pilot)
        self.ctx.register(pilot.sandbox)
        pilot.start()
        self._pilots.append(pilot)
        return pilot

    def list_pilots(self) -> List[PilotCompute]:
        return list(self._pilots)

    def cancel(self) -> None:
        for p in self._pilots:
            p.cancel()


class PilotDataService:
    """Factory for Pilot-Data (paper §4.3.1)."""

    def __init__(self, ctx: RuntimeContext):
        self.ctx = ctx
        if ctx.transfer_service is None:
            TransferService(ctx)
        self._pds: List[PilotData] = []

    def create_pilot_data(self, desc: PilotDataDescription) -> PilotData:
        pd = PilotData(desc, self.ctx)
        self.ctx.register(pd)
        self._pds.append(pd)
        return pd

    def list_pilot_data(self) -> List[PilotData]:
        return list(self._pds)


class DependencyTracker:
    """DU-readiness gating for dataflow CUs (Pilot-API v2, paper Fig. 5).

    A CU whose input DUs are not all sealed/first-replicated is parked in
    ``Waiting`` instead of being released to placement; this tracker
    subscribes to the coordination store's keyspace notifications (the same
    StoreEvent machinery the async scheduler rides — no polling; events
    arrive via the store's out-of-lock dispatcher in ``seq`` order, so the
    readiness decisions below see seal/publish transitions in store order)
    and, when an awaited DU seals or turns READY, releases every CU whose
    dependency set just emptied by pushing it onto ``cds:incoming``.  Both execution
    modes drain that queue (the sync loop and the AsyncScheduler reactor),
    so release ordering — recorded in :attr:`release_log` — is identical
    across modes.

    A DU that turns FAILED (its producer CU exhausted retries, or was
    canceled) fails its waiters with a clear upstream error, and the
    waiters' own output DUs are failed in turn — the cascade walks the DAG
    transitively through the same event stream.
    """

    def __init__(self, cds: "ComputeDataService"):
        self.cds = cds
        self.ctx = cds.ctx
        self._lock = threading.Lock()
        #: cu_id -> input du_ids still unmet
        self._unmet: Dict[str, Set[str]] = {}
        #: du_id -> cu_ids waiting on it
        self._waiters: Dict[str, Set[str]] = {}
        #: cu ids in the order they were released to placement (the
        #: sync ≡ async ordering witness)
        self.release_log: List[str] = []
        self._pump = StoreEventPump(
            self.ctx.store,
            handler=self._process,
            prefix="du:",
            # "du:<id>" state/seal/publish transitions, not "du:<id>:chunks"
            accept=lambda ev: (
                ev.op == "hset"
                and ev.field in ("state", "sealed", "published")
                and ev.key.count(":") == 1
            ),
            name="du-readiness-gate",
        )

    def _process(self, ev: StoreEvent) -> None:
        du_id = ev.key.split(":", 1)[1]
        if ev.field == "sealed" and ev.value:
            self._du_ready(du_id)
        elif ev.field == "published":
            self._du_progress(du_id, int(ev.value or 0))
        elif ev.field == "state":
            if ev.value == DUState.READY:
                self._du_ready(du_id)
            elif ev.value == DUState.FAILED:
                self._du_failed(du_id)

    # ------------------------------------------------------------ transitions
    def _du_progress(self, du_id: str, published: int) -> None:
        """Streaming readiness mode (``first_k_chunks``): a chunk-prefix
        publish event satisfies waiters once the published count crosses
        the DU's ``ready_chunks`` threshold — consumers start on the
        prefix while the producer is still writing.  Release order still
        lands on ``cds:incoming`` like every other release, so the
        sync ≡ async ordering proof in :attr:`release_log` covers prefix
        releases too."""
        h = self.ctx.store.hgetall(f"du:{du_id}")
        if not h.get("streaming"):
            return
        threshold = int(h.get("ready_chunks") or 1)
        if published >= threshold:
            self._du_ready(du_id)

    def _du_ready(self, du_id: str) -> None:
        with self._lock:
            released = []
            for cu_id in self._waiters.pop(du_id, ()):  # noqa: B020
                unmet = self._unmet.get(cu_id)
                if unmet is None:
                    continue
                unmet.discard(du_id)
                if not unmet:
                    del self._unmet[cu_id]
                    released.append(cu_id)
        for cu_id in released:
            self._release(cu_id)

    def _release(self, cu_id: str) -> None:
        try:
            cu: ComputeUnit = self.ctx.lookup(cu_id)
        except KeyError:
            return
        # Canceled-while-waiting CUs lose the CAS and are dropped here.
        if cu._cas_state(CUState.WAITING, CUState.PENDING):
            with self._lock:
                self.release_log.append(cu_id)
            # release lands on cds:incoming via the tenant admission gate
            # (pass-through for the default tenant, so the release_log
            # ordering witness is unchanged in single-tenant runs)
            self.cds.admission.submit(cu)

    def _du_failed(self, du_id: str) -> None:
        with self._lock:
            waiters = sorted(self._waiters.pop(du_id, ()))
            for cu_id in waiters:
                self._unmet.pop(cu_id, None)
        store = self.ctx.store
        reason = store.hget(f"du:{du_id}", "error") or "producer failed"
        for cu_id in waiters:
            try:
                cu: ComputeUnit = self.ctx.lookup(cu_id)
            except KeyError:
                continue
            if cu._cas_state(CUState.WAITING, CUState.FAILED):
                msg = f"input du://{du_id} failed: {reason}"
                cu.error = msg
                store.hset(f"cu:{cu.id}", "error", msg)
                # transitive cascade: this CU will never produce its outputs
                cu._fail_outputs(f"producer {cu.url} failed: {msg}")
                if self.ctx.tier_manager is not None:
                    self.ctx.tier_manager.pins.unpin_owner(cu.id)

    # -------------------------------------------------------------- interface
    def add(self, cu: ComputeUnit, unmet: Set[str]) -> None:
        """Park ``cu`` until every DU in ``unmet`` is ready.

        Registration races against the DUs settling concurrently — a
        synthetic re-check event per DU closes the window on the tracker
        thread (where all release decisions are serialized).
        """
        tm = self.ctx.tier_manager
        if tm is not None:
            # a Waiting consumer's inputs (the already-ready ones
            # included) are pinned against quota eviction until the CU
            # settles — re-parks during lineage recovery re-pin too
            tm.pins.pin_inputs(cu)
        with self._lock:
            self._unmet[cu.id] = set(unmet)
            for du_id in unmet:
                self._waiters.setdefault(du_id, set()).add(cu.id)
        store = self.ctx.store
        for du_id in unmet:
            h = store.hgetall(f"du:{du_id}")
            state = h.get("state")
            published = int(h.get("published") or 0)
            if h.get("sealed"):
                field, value = "sealed", True
            elif state in (DUState.READY, DUState.FAILED):
                field, value = "state", state
            elif h.get("streaming") and published >= int(h.get("ready_chunks") or 1):
                # the producer already streamed past the threshold before
                # this consumer registered — close that race too
                field, value = "published", published
            else:
                continue
            self._pump.inject(
                StoreEvent(
                    seq=-1,
                    op="hset",
                    key=f"du:{du_id}",
                    field=field,
                    value=value,
                )
            )

    def waiting(self) -> List[str]:
        with self._lock:
            return sorted(self._unmet)

    def stop(self) -> None:
        self._pump.stop()


class AdmissionController:
    """Per-tenant QoS gate between CU release and placement.

    Every path that used to push a Pending CU straight onto
    ``cds:incoming`` — submission with met dependencies, a
    DependencyTracker release, the agent's sandbox-backpressure requeue —
    now routes through :meth:`submit`/:meth:`requeue`.  A tenant over its
    :class:`~repro.core.tenancy.ResourceQuota` (CU slots, resident
    sandbox bytes) has its CUs *parked*: state stays ``Pending``, no
    retry attempt or quota-wait is burned, and the CU re-enters placement
    — weighted-fair-share ordered across starved tenants — as the
    tenant's earlier CUs turn terminal (observed via the same
    StoreEventPump machinery the DependencyTracker rides).

    With only the bare default tenant (unlimited quota) the controller is
    a deterministic synchronous pass-through, so single-tenant callers
    observe the exact pre-QoS release order (the sync ≡ async decision
    witnesses stay valid).

    The controller also implements *queued-only preemption*: when a CU of
    a strictly higher-priority tenant would otherwise fall to the global
    queue, one queued (never running) CU of the lowest-priority tenant is
    atomically removed from its pilot queue (``qremove`` doubles as the
    did-any-agent-claim-it CAS) and parked at the front of its tenant's
    line; the high-priority CU takes the vacated queue position.
    """

    def __init__(self, cds: "ComputeDataService"):
        self.cds = cds
        self.ctx = cds.ctx
        if self.ctx.tenant_registry is None:
            self.ctx.tenant_registry = TenantRegistry(self.ctx)
        self.registry: TenantRegistry = self.ctx.tenant_registry
        self.ctx.admission = self
        self._lock = threading.Lock()
        #: tenant -> parked CU ids, oldest first (FIFO within a tenant)
        self._parked: Dict[str, Deque[str]] = {}
        #: CU ids admitted in order (observability / fairness tests)
        self.admission_log: List[str] = []
        self.parked_total = 0
        #: audit of queued-CU preemptions: {"cu", "tenant", "by",
        #: "by_tenant", "pilot"}
        self.preemptions: List[Dict] = []
        # capacity returns on terminal CU transitions: drain parked work
        # on the pump thread (same subscribe → queue → thread shape as the
        # DependencyTracker, so no store mutation runs on the dispatcher)
        self._pump = StoreEventPump(
            self.ctx.store,
            handler=self._on_cu_event,
            prefix="cu:",
            accept=lambda ev: (
                ev.op == "hset"
                and ev.field == "state"
                and ev.value in CUState.TERMINAL
            ),
            name="admission-gate",
        )

    # ------------------------------------------------------------ admission
    def _estimate(self, cu: ComputeUnit) -> float:
        d = cu.description
        return max(d.sim_compute_s, d.est_compute_s, self.cds.avg_cu_estimate_s)

    def _tenant_of(self, cu: ComputeUnit) -> str:
        return getattr(cu.description, "tenant", None) or DEFAULT_TENANT

    def _over_quota(self, tenant: str, resident: Optional[int]) -> bool:
        """Quota check for admitting ONE more CU of ``tenant``.  Callers
        compute ``resident`` outside the controller lock (it scans PDs and
        reads the store) and only when a byte quota is actually set."""
        quota = self.registry.get(tenant).quota
        if (
            quota.cu_slots is not None
            and self.registry.inflight(tenant) >= quota.cu_slots
        ):
            return True
        if (
            quota.sandbox_bytes is not None
            and resident is not None
            and resident >= quota.sandbox_bytes
        ):
            return True
        return False

    def _resident(self, tenant: str) -> Optional[int]:
        quota = self.registry.get(tenant).quota
        if quota.sandbox_bytes is None:
            return None
        return self.registry.resident_bytes(tenant)

    def submit(self, cu: ComputeUnit) -> bool:
        """Admit ``cu`` to placement or park it; True iff admitted now.

        Admission pushes onto ``cds:incoming`` exactly as the pre-QoS
        release paths did; parking leaves the CU ``Pending`` off every
        queue with a store-side ``admission: parked`` marker."""
        tenant = self._tenant_of(cu)
        resident = self._resident(tenant)
        with self._lock:
            queue = self._parked.get(tenant)
            if (queue and len(queue) > 0) or self._over_quota(tenant, resident):
                # earlier parked CUs keep FIFO precedence within a tenant
                self._parked.setdefault(
                    tenant, collections.deque()
                ).append(cu.id)
                self.parked_total += 1
                parked = True
            else:
                self.registry.note_admitted(tenant, cu.id, self._estimate(cu))
                self.admission_log.append(cu.id)
                parked = False
        if parked:
            self.ctx.store.hset(f"cu:{cu.id}", "admission", "parked")
            return False
        self.ctx.store.hset(f"cu:{cu.id}", "admission", "admitted")
        self.ctx.store.push("cds:incoming", cu.id)
        return True

    def requeue(self, cu: ComputeUnit) -> bool:
        """Backpressure re-entry from the agent claim path: the CU hit
        sandbox quota pressure mid-staging and went back to ``Pending``.
        Re-check its tenant's quota — if the tenant itself is now over (it
        caused the pressure), park instead of hot-looping through the
        global queue; otherwise hand it straight back to the global queue
        exactly as the pre-QoS path did."""
        tenant = self._tenant_of(cu)
        resident = self._resident(tenant)
        with self._lock:
            self.registry.note_removed(tenant, cu.id)
            if self._over_quota(tenant, resident):
                # oldest work re-admits first: park at the FRONT
                self._parked.setdefault(
                    tenant, collections.deque()
                ).appendleft(cu.id)
                self.parked_total += 1
                parked = True
            else:
                self.registry.note_admitted(tenant, cu.id, 0.0)
                parked = False
        if parked:
            self.ctx.store.hset(f"cu:{cu.id}", "admission", "parked")
            return False
        self.ctx.store.push(GLOBAL_QUEUE, {"cu": cu.id, "dup": False})
        return True

    # ---------------------------------------------------------------- drain
    def _on_cu_event(self, ev: StoreEvent) -> None:
        cu_id = ev.key.split(":", 1)[1]
        tenant = (
            self.ctx.store.hget(f"cu:{cu_id}", "tenant") or DEFAULT_TENANT
        )
        self.registry.note_removed(tenant, cu_id)
        self.poke()

    def poke(self) -> int:
        """Drain parked CUs that now fit their tenants' quotas; returns
        how many were admitted.  Starved tenants go first: candidates are
        ordered by (priority desc, weighted service received asc) — the
        deficit ordering that makes fair-share weights meaningful across
        competing backlogs.  Safe to call from any thread."""
        admitted = 0
        while True:
            released = self._release_one()
            if released is None:
                return admitted
            cu_id, state_ok = released
            if state_ok:
                self.ctx.store.hset(f"cu:{cu_id}", "admission", "admitted")
                self.ctx.store.push("cds:incoming", cu_id)
            admitted += 1

    def _release_one(self) -> Optional[Tuple[str, bool]]:
        """Pop the most deserving parked CU whose tenant has room.  The
        quota reads that touch the store (resident bytes) run before the
        lock is taken; the pick itself is an in-memory decision."""
        with self._lock:
            tenants = [t for t, q in self._parked.items() if q]
        residents = {t: self._resident(t) for t in tenants}
        order = sorted(
            tenants,
            key=lambda t: (
                -self.registry.get(t).priority,
                self.registry.deficit_key(t),
                t,
            ),
        )
        with self._lock:
            for tenant in order:
                queue = self._parked.get(tenant)
                if not queue:
                    continue
                if self._over_quota(tenant, residents.get(tenant)):
                    continue
                cu_id = queue.popleft()
                try:
                    cu = self.ctx.lookup(cu_id)
                except KeyError:
                    return cu_id, False
                self.registry.note_admitted(
                    tenant, cu_id, self._estimate(cu)
                )
                self.admission_log.append(cu_id)
                return cu_id, True
        return None

    # ----------------------------------------------------------- preemption
    def preemption_enabled(self, cu: ComputeUnit) -> bool:
        """Preemption is attempted only when this CU's tenant outranks
        SOME registered tenant — default single-tenant workloads never
        pay the queue scan (and keep their decision order bit-exact)."""
        if not self.registry.multi_tenant:
            return False
        my = self.registry.get(self._tenant_of(cu)).priority
        return my > self.registry.min_priority()

    def preempt_queued_for(self, cu: ComputeUnit, pilots) -> Optional[object]:
        """Evict one *queued* lower-priority CU to make room for ``cu``.

        Scans pilot queues (never running slots, never the global queue —
        removing a global entry frees no pilot capacity) for CUs of
        strictly lower-priority tenants, preferring the lowest-priority,
        most-recently-queued victim.  ``qremove`` returning True is the
        proof no agent claimed the victim; the victim parks at the front
        of its tenant's line (state still ``Pending``, nothing burned)
        and the caller pushes ``cu`` to the vacated pilot queue.
        Returns that pilot, or None when nothing was preemptible."""
        store = self.ctx.store
        my_tenant = self._tenant_of(cu)
        my_pri = self.registry.get(my_tenant).priority
        victims: List[Tuple[int, int, object, Dict, str]] = []
        for pilot in pilots:
            if pilot.state not in PilotState.PLACEABLE:
                continue
            for pos, item in enumerate(store.qpeek(pilot.queue_name)):
                vid = item["cu"] if isinstance(item, dict) else item
                vt = store.hget(f"cu:{vid}", "tenant") or DEFAULT_TENANT
                if vt == my_tenant:
                    continue
                vp = self.registry.get(vt).priority
                if vp < my_pri:
                    victims.append((vp, -pos, pilot, item, vt))
        # lowest priority first; within a queue, the most recently queued
        # (it has waited least — minimal disruption)
        victims.sort(key=lambda v: (v[0], v[1]))
        for vp, _negpos, pilot, item, vt in victims:
            if not store.qremove(pilot.queue_name, item):
                continue  # an agent won the race: victim is running
            vid = item["cu"] if isinstance(item, dict) else item
            with self._lock:
                self.registry.note_removed(vt, vid)
                self._parked.setdefault(
                    vt, collections.deque()
                ).appendleft(vid)
                self.parked_total += 1
                self.preemptions.append(
                    {
                        "cu": vid,
                        "tenant": vt,
                        "by": cu.id,
                        "by_tenant": my_tenant,
                        "pilot": pilot.id,
                    }
                )
            store.hset(f"cu:{vid}", "admission", "preempted")
            return pilot
        return None

    # -------------------------------------------------------------- control
    def parked(self) -> Dict[str, List[str]]:
        with self._lock:
            return {t: list(q) for t, q in self._parked.items() if q}

    def stop(self) -> None:
        self._pump.stop()


class ComputeDataService:
    """Workload manager: late-binds CUs/DUs to pilots by affinity (§5)."""

    def __init__(
        self,
        ctx: RuntimeContext,
        delayed_scheduling_s: float = 0.0,
        avg_cu_estimate_s: float = 0.05,
        strategy: str = "cost",
        start_loop: bool = True,
    ):
        self.ctx = ctx
        if ctx.transfer_service is None:
            TransferService(ctx)
        self.delayed_scheduling_s = delayed_scheduling_s
        self.avg_cu_estimate_s = avg_cu_estimate_s
        self.engine = PlacementEngine(ctx, avg_cu_estimate_s=avg_cu_estimate_s)
        self.strategy: PlacementStrategy = (
            strategy if isinstance(strategy, PlacementStrategy)
            else make_strategy(strategy)
        )
        # tenant-aware strategies read the registry/store through the ctx
        self.strategy.bind(ctx)
        self._pilots: List[PilotCompute] = []
        self._pds: List[PilotData] = []
        self._cus: List[ComputeUnit] = []
        self._dus: List[DataUnit] = []
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._delayed: List[Dict] = []  # {"cu":…, "deadline":…, "pilot":…}
        self._decisions: List[Dict] = []  # audit log of placement choices
        #: invoked with (cu, pilot) just before a CU lands on a pilot queue
        #: — the async scheduler hangs its prefetch pipeline here so the
        #: staging claim exists before any agent can see the CU
        self.pre_push_hook: Optional[Callable] = None
        #: invoked with (cu, unmet) when a CU parks in ``Waiting`` — the
        #: async scheduler speculatively prefetches the CU's already-ready
        #: inputs (e.g. the next training chunk's shard DU) toward the
        #: predicted placement winner while the unmet producers still run
        self.waiting_prefetch_hook: Optional[Callable] = None
        #: DU-readiness gate (dataflow semantics) — shared by both
        #: execution modes, so sync and async release CUs identically
        self.deps = DependencyTracker(self)
        #: per-tenant QoS gate — every release path (submission, dep
        #: release, backpressure requeue) funnels through it; with only
        #: the default tenant it is a deterministic pass-through
        self.admission = AdmissionController(self)
        self._thread: Optional[threading.Thread] = None
        if start_loop:
            # Legacy sync mode: a polling loop owns placement.  In async
            # mode the AsyncScheduler drains the incoming queue instead
            # (event-driven), so no thread is started here.
            self._thread = threading.Thread(
                target=self._scheduler_loop, name="cds-scheduler", daemon=True
            )
            self._thread.start()

    # --------------------------------------------------------- registration
    def add_pilot_compute(self, pilot: PilotCompute) -> None:
        with self._lock:
            self._pilots.append(pilot)

    def add_pilot_data(self, pd: PilotData) -> None:
        with self._lock:
            self._pds.append(pd)

    def pilots(self) -> List[PilotCompute]:
        with self._lock:
            return list(self._pilots)

    def pilot_data(self) -> List[PilotData]:
        with self._lock:
            return list(self._pds)

    # ----------------------------------------------------------- submission
    def submit_data_unit(
        self, desc: DataUnitDescription, target: Optional[PilotData] = None
    ) -> DataUnit:
        """Create a DU and stage it into an affinity-appropriate PD.

        The DU's physical representation is its chunk manifest
        (``desc.chunk_size``); the first ingest registers the target PD as
        a full replica in ``locations`` and further holdings — including
        partial, chunk-level ones — accumulate in the store's
        ``du:<id>:chunks`` hash."""
        du = DataUnit(desc, self.ctx.store)
        self.ctx.register(du)
        with self._lock:
            self._dus.append(du)
        pd = target or self._choose_pd(desc)
        if pd is not None and du.size > 0:
            self.ctx.store.hset(f"du:{du.id}", "state", DUState.PENDING)
            self.ctx.transfer_service.ingest(du, pd)
        return du

    def create_data_unit(self, desc: DataUnitDescription) -> DataUnit:
        """Create a DU *without* staging it anywhere: a dataflow
        placeholder whose content a producer CU will materialize (the
        Session auto-creates output DUs through this).  The store-side
        ``placeholder`` marker is what gates consumers — an empty DU made
        via ``submit_data_unit`` is vacuously complete instead."""
        du = DataUnit(desc, self.ctx.store)
        self.ctx.store.hset(f"du:{du.id}", "placeholder", True)
        self.ctx.register(du)
        with self._lock:
            self._dus.append(du)
        return du

    def _unmet_inputs(self, cu: ComputeUnit) -> Set[str]:
        """Input DUs that must materialize before ``cu`` may be placed.

        A DU gates its consumers while it is unsealed AND is either some
        CU's declared output (``producer`` set) or an explicit dataflow
        placeholder (``create_data_unit``) awaiting a producer not yet
        submitted.  Source DUs made through ``submit_data_unit`` never
        gate — with or without content they are consumable immediately,
        which preserves the v1 submit-then-consume flow.
        """
        store = self.ctx.store
        unmet: Set[str] = set()
        for du_id in cu.description.input_data:
            h = store.hgetall(f"du:{du_id}")
            if not h:
                raise KeyError(f"{cu.url}: unknown input DU du://{du_id}")
            state = h.get("state")
            if state == DUState.FAILED:
                raise ValueError(
                    f"{cu.url}: input du://{du_id} already failed: "
                    f"{h.get('error') or 'producer failed'}"
                )
            if h.get("sealed") or state == DUState.READY:
                continue
            if h.get("streaming") and int(h.get("published") or 0) >= int(
                h.get("ready_chunks") or 1
            ):
                # streaming readiness: enough of a chunk prefix is already
                # published for this consumer to start
                continue
            if h.get("producer") or h.get("placeholder"):
                unmet.add(du_id)
        return unmet

    def _validate_data_refs(self, desc: ComputeUnitDescription) -> None:
        """Reject bad data references BEFORE any side effects: a CU must
        not be created/tracked (and no producer claims stamped) if its
        declared DUs don't exist or its outputs are already immutable —
        otherwise a zombie non-terminal CU poisons ``wait()`` forever."""
        store = self.ctx.store
        for du_id in desc.input_data:
            if not store.hgetall(f"du:{du_id}"):
                raise KeyError(f"unknown input DU du://{du_id}")
        for du_id in desc.output_data:
            h = store.hgetall(f"du:{du_id}")
            if not h:
                raise KeyError(f"unknown output DU du://{du_id}")
            if h.get("sealed"):
                raise ValueError(
                    f"output du://{du_id} is sealed (immutable); "
                    f"declare a fresh DU instead"
                )

    def _claim_outputs(self, cu: ComputeUnit) -> None:
        """Atomically claim each output DU for ``cu`` (CAS on the
        ``producer`` field); on a lost race every claim this CU did win is
        unwound and the CU is failed, so nothing is left half-stamped."""
        store = self.ctx.store
        claimed: List[str] = []
        for du_id in cu.description.output_data:
            if not store.hcas(f"du:{du_id}", "producer", None, cu.id):
                prior = store.hget(f"du:{du_id}", "producer")
                for oid in claimed:
                    store.hdel(f"du:{oid}", "producer")
                msg = (
                    f"{cu.url}: du://{du_id} already has producer "
                    f"cu://{prior}; DUs are single-writer"
                )
                cu.error = msg
                store.hset(f"cu:{cu.id}", "error", msg)
                cu._set_state(CUState.FAILED)
                raise ValueError(msg)
            claimed.append(du_id)

    def submit_compute_unit(self, desc: ComputeUnitDescription) -> ComputeUnit:
        self._validate_data_refs(desc)
        cu = ComputeUnit(desc, self.ctx.store)
        self.ctx.register(cu)
        cu.timings.submitted = time.monotonic()
        self._claim_outputs(cu)
        if self.ctx.tier_manager is not None:
            # pin declared inputs from submission until the CU settles:
            # Waiting/Pending/Running consumers' inputs are never eviction
            # victims (the registry drops pins of terminal CUs lazily)
            self.ctx.tier_manager.pins.pin_inputs(cu)
        with self._lock:
            self._cus.append(cu)
        try:
            unmet = self._unmet_inputs(cu)
        except ValueError as exc:
            # an input already failed: the CU fails at submit, terminally,
            # and the failure cascades through its own outputs
            msg = str(exc)
            cu.error = msg
            self.ctx.store.hset(f"cu:{cu.id}", "error", msg)
            cu._set_state(CUState.FAILED)
            cu._fail_outputs(f"producer {cu.url} failed: {msg}")
            return cu
        if unmet:
            # Dataflow gate: park until every input DU is sealed/replicated.
            cu._set_state(CUState.WAITING)
            self.deps.add(cu, unmet)
            if self.waiting_prefetch_hook is not None:
                try:
                    self.waiting_prefetch_hook(cu, unmet)
                except Exception:
                    pass  # speculative staging must never fail a submit
        else:
            cu._set_state(CUState.PENDING)
            # Asynchronous interface (§4.2): enqueue and return
            # immediately — through the tenant admission gate, which
            # parks over-quota tenants instead of failing them.
            self.admission.submit(cu)
        return cu

    def compute_units(self) -> List[ComputeUnit]:
        with self._lock:
            return list(self._cus)

    def data_units(self) -> List[DataUnit]:
        with self._lock:
            return list(self._dus)

    # ----------------------------------------------------------- scheduling
    def _choose_pd(self, desc: DataUnitDescription) -> Optional[PilotData]:
        """Affinity-aware PD selection for a new DU."""
        from .affinity import match_affinity

        with self._lock:
            pds = list(self._pds)
        need = max(desc.size_hint, sum(map(len, desc.files.values())))
        fits = [pd for pd in pds if pd.free_bytes >= need]
        candidates = [pd for pd in fits if match_affinity(desc.affinity, pd.affinity)]
        if not candidates:
            candidates = fits  # affinity miss: any PD with space
        if not candidates:
            return None  # nowhere fits — DU stays in its local buffer
        # Prefer the emptiest (simple balance; the cost model handles the
        # rest at CU-placement time).
        return max(candidates, key=lambda pd: pd.free_bytes)

    def choose_pilot_data(self, desc: DataUnitDescription) -> Optional[PilotData]:
        """Public affinity-aware PD selection (same ranking the DU submit
        path uses) — lets layers that stage DUs on their own threads (e.g.
        the checkpointer's async commit) pick a home without re-implementing
        the affinity/space policy."""
        return self._choose_pd(desc)

    def predict_pilot(self, cu: ComputeUnit) -> Optional[PilotCompute]:
        """Best placement candidate for ``cu`` *without* placing it: the
        same strategy ranking :meth:`place` uses, but nothing is queued and
        no decision is logged (so the sync ≡ async decision-parity witness
        is untouched).  The async scheduler uses this to aim speculative
        prefetch for CUs still parked ``Waiting``."""
        desc = cu.description
        if desc.pilot is not None:
            try:
                pilot: PilotCompute = self.ctx.lookup(desc.pilot)
            except KeyError:
                return None
            return pilot if pilot.state in PilotState.PLACEABLE else None
        with self._lock:
            pilots = list(self._pilots)
        ranked = self.strategy.rank(
            cu,
            self.engine.candidates(cu, pilots, tier_bw=self.strategy.uses_tier_bw),
        )
        return ranked[0].pilot if ranked else None

    def _has_free_slot(self, pilot: PilotCompute) -> bool:
        depth = self.ctx.store.qlen(pilot.queue_name)
        running = len(pilot.running_cus())
        return pilot.state == PilotState.ACTIVE and (running + depth < pilot.slots)

    def place(self, cu: ComputeUnit) -> Optional[PilotCompute]:
        """One pass of the §5 placement algorithm for one CU.

        Shared by both execution modes (the sync polling loop and the
        event-driven AsyncScheduler call exactly this), which is what keeps
        their placement decisions identical.  Returns the pilot whose queue
        received the CU, or None (global queue / delayed)."""
        desc = cu.description
        if desc.pilot is not None:
            # Application-level direct binding (§4.3.2 control level (i)).
            pilot: PilotCompute = self.ctx.lookup(desc.pilot)
            if pilot.state not in PilotState.PLACEABLE:
                # Pinned to a dead/suspect pilot (it may be the very pilot
                # whose failure re-queued this CU): fall back to the global
                # queue so any live pilot can pull it.
                self.ctx.store.push(GLOBAL_QUEUE, {"cu": cu.id, "dup": False})
                return None
            self._push_to_pilot(cu, pilot)
            return pilot
        with self._lock:
            pilots = list(self._pilots)
        ranked = self.strategy.rank(
            cu,
            self.engine.candidates(cu, pilots, tier_bw=self.strategy.uses_tier_bw),
        )
        if not ranked:
            self.ctx.store.push(GLOBAL_QUEUE, {"cu": cu.id, "dup": False})
            return None
        best = ranked[0]
        self._decisions.append(
            {
                "cu": cu.id,
                "pilot": best.pilot.id,
                "t_q": best.t_queue,
                "t_stage": best.t_stage,
                "strategy": best.strategy,
                "policy": self.strategy.name,
            }
        )
        # Step 2: same-affinity pilot with an empty slot → pilot queue.
        if self._has_free_slot(best.pilot):
            self._push_to_pilot(cu, best.pilot)
            return best.pilot
        # Steps 3/4 leave the CU off the winner's queue for now — but the
        # winner is still where it will most likely run, so the async
        # pipeline prefetches its inputs there speculatively (staging
        # overlaps the work the pilot is currently busy with; a sandbox
        # replica also helps any other pilot via cheapest-replica).
        if self.pre_push_hook is not None:
            try:
                self.pre_push_hook(cu, best.pilot)
            except Exception:
                pass
        # Step 3: delayed scheduling — wait n sec, recheck.
        if self.delayed_scheduling_s > 0:
            with self._lock:
                self._delayed.append(
                    {
                        "cu": cu,
                        "pilot": best.pilot,
                        "deadline": time.monotonic()
                        + self.delayed_scheduling_s,
                    }
                )
            return None
        # Step 4 QoS refinement: before falling to the global queue, a
        # higher-priority tenant may displace one *queued* (never
        # running) CU of a lower-priority tenant and take its slot in
        # line.  Default single-tenant workloads never enter this branch.
        if self.admission.preemption_enabled(cu):
            target = self.admission.preempt_queued_for(cu, pilots)
            if target is not None:
                self._push_to_pilot(cu, target)
                return target
        # Step 4: global queue — first pilot with a slot pulls it.
        self.ctx.store.push(GLOBAL_QUEUE, {"cu": cu.id, "dup": False})
        return None

    def _push_to_pilot(self, cu: ComputeUnit, pilot: PilotCompute) -> None:
        if self.pre_push_hook is not None:
            try:
                self.pre_push_hook(cu, pilot)
            except Exception:
                pass
        if self.ctx.data_mode == "push":
            # Push-mode data management (§4.2): the manager pre-stages the
            # input DUs into the pilot sandbox before the CU is queued.
            for du_id in cu.description.input_data:
                du: DataUnit = self.ctx.lookup(du_id)
                self.ctx.transfer_service.stage_in(du, pilot.sandbox, pilot.affinity)
        item = {"cu": cu.id, "dup": False}
        self.ctx.store.push(pilot.queue_name, item)
        # Close the check-then-push race against pilot death: fault
        # recovery drains a dead pilot's queue exactly once, so a push
        # landing AFTER that drain would strand the CU forever.  The
        # monitor sets FAILED before the drain runs; re-checking here
        # guarantees either the drain sees our item or we see FAILED.
        if pilot.state not in PilotState.PLACEABLE:
            if self.ctx.store.qremove(pilot.queue_name, item):
                self.ctx.store.push(GLOBAL_QUEUE, item)

    def recheck_delayed(self) -> List[tuple]:
        """Re-check delayed CUs (step 3); returns [(cu, pilot)] placed onto
        a pilot queue this pass (the async scheduler prefetches those)."""
        store = self.ctx.store
        now = time.monotonic()
        placed: List[tuple] = []
        with self._lock:
            entries, self._delayed = self._delayed, []
        still: List[Dict] = []
        for entry in entries:
            cu, pilot = entry["cu"], entry["pilot"]
            if cu.state != CUState.PENDING:
                continue
            if self._has_free_slot(pilot):
                self._push_to_pilot(cu, pilot)
                placed.append((cu, pilot))
            elif now >= entry["deadline"]:
                store.push(GLOBAL_QUEUE, {"cu": cu.id, "dup": False})
            else:
                still.append(entry)
        with self._lock:
            self._delayed.extend(still)
        return placed

    def _scheduler_loop(self) -> None:
        store = self.ctx.store
        while not self._stop.is_set():
            try:
                cu_id = store.pop("cds:incoming", timeout=0.02)
            except Exception:
                time.sleep(0.05)
                continue
            if cu_id is not None:
                try:
                    cu = self.ctx.lookup(cu_id)
                    if cu.state == CUState.PENDING:
                        self.place(cu)
                except Exception:
                    pass
            self.recheck_delayed()

    # ------------------------------------------------------------- control
    def decisions(self) -> List[Dict]:
        return list(self._decisions)

    def wait(self, timeout: float = 120.0) -> bool:
        """Block until every submitted CU is terminal.  True on success.

        Event-driven: a keyspace subscription on ``cu:`` state transitions
        wakes the waiter on the very mutation (new submissions also write a
        state field, so a workload growing mid-wait re-checks too); the
        coarse in-wait poll only guards against lost notifications.
        """
        woke = threading.Event()

        def _cb(ev: StoreEvent) -> None:
            if ev.field == "state":
                woke.set()

        token = self.ctx.store.subscribe(_cb, prefix="cu:")
        try:
            deadline = time.monotonic() + timeout
            while True:
                woke.clear()
                with self._lock:
                    cus = list(self._cus)
                if all(c.state in CUState.TERMINAL for c in cus):
                    return True
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                woke.wait(min(remaining, 0.25))
        finally:
            self.ctx.store.unsubscribe(token)

    def cancel(self) -> None:
        self._stop.set()
        self.deps.stop()
        self.admission.stop()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
