"""The Pilot-API (§4.3): PilotComputeService, PilotDataService, and the
Compute-Data Service (the affinity-based workload manager of §5).

Multi-level scheduling, exactly as the paper separates it:
  * resource allocation — services that start Pilot-Computes / Pilot-Data
    ("the start of the Pilot") — and
  * workload management — the Compute-Data Service that late-binds CUs and
    DUs onto those pilots using the affinity model and the §6.1 calculus.

The CDS scheduler implements the paper's placement loop verbatim (§5):

  1. find the pilot that best fulfills the CU's requested affinity and the
     location of its input data;
  2. if a pilot with the same affinity exists and has an empty slot, place
     the CU in that pilot's queue;
  3. if delayed scheduling is active, wait n sec and re-check for a free
     slot;
  4. otherwise place the CU in the global queue, pulled by the first pilot
     with an available slot.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence

from .agent import GLOBAL_QUEUE
from .compute_unit import ComputeUnit, ComputeUnitDescription, CUState
from .cost_model import decide_placement
from .data_unit import DataUnit, DataUnitDescription
from .pilot import (
    PilotCompute,
    PilotComputeDescription,
    PilotData,
    PilotDataDescription,
    PilotState,
    RuntimeContext,
)
from .transfer import TransferService


class PilotComputeService:
    """Factory for Pilot-Computes (paper §4.3.1)."""

    def __init__(self, ctx: RuntimeContext):
        self.ctx = ctx
        if ctx.transfer_service is None:
            TransferService(ctx)
        self._pilots: List[PilotCompute] = []

    def create_pilot(self, desc: PilotComputeDescription) -> PilotCompute:
        pilot = PilotCompute(desc, self.ctx)
        self.ctx.register(pilot)
        self.ctx.register(pilot.sandbox)
        pilot.start()
        self._pilots.append(pilot)
        return pilot

    def list_pilots(self) -> List[PilotCompute]:
        return list(self._pilots)

    def cancel(self) -> None:
        for p in self._pilots:
            p.cancel()


class PilotDataService:
    """Factory for Pilot-Data (paper §4.3.1)."""

    def __init__(self, ctx: RuntimeContext):
        self.ctx = ctx
        if ctx.transfer_service is None:
            TransferService(ctx)
        self._pds: List[PilotData] = []

    def create_pilot_data(self, desc: PilotDataDescription) -> PilotData:
        pd = PilotData(desc, self.ctx)
        self.ctx.register(pd)
        self._pds.append(pd)
        return pd

    def list_pilot_data(self) -> List[PilotData]:
        return list(self._pds)


class ComputeDataService:
    """Workload manager: late-binds CUs/DUs to pilots by affinity (§5)."""

    def __init__(
        self,
        ctx: RuntimeContext,
        delayed_scheduling_s: float = 0.0,
        avg_cu_estimate_s: float = 0.05,
    ):
        self.ctx = ctx
        if ctx.transfer_service is None:
            TransferService(ctx)
        self.delayed_scheduling_s = delayed_scheduling_s
        self.avg_cu_estimate_s = avg_cu_estimate_s
        self._pilots: List[PilotCompute] = []
        self._pds: List[PilotData] = []
        self._cus: List[ComputeUnit] = []
        self._dus: List[DataUnit] = []
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._delayed: List[Dict] = []  # {"cu":…, "deadline":…, "pilot":…}
        self._decisions: List[Dict] = []  # audit log of placement choices
        self._thread = threading.Thread(
            target=self._scheduler_loop, name="cds-scheduler", daemon=True
        )
        self._thread.start()

    # --------------------------------------------------------- registration
    def add_pilot_compute(self, pilot: PilotCompute) -> None:
        with self._lock:
            self._pilots.append(pilot)

    def add_pilot_data(self, pd: PilotData) -> None:
        with self._lock:
            self._pds.append(pd)

    def pilots(self) -> List[PilotCompute]:
        with self._lock:
            return list(self._pilots)

    def pilot_data(self) -> List[PilotData]:
        with self._lock:
            return list(self._pds)

    # ----------------------------------------------------------- submission
    def submit_data_unit(
        self, desc: DataUnitDescription, target: Optional[PilotData] = None
    ) -> DataUnit:
        """Create a DU and stage it into an affinity-appropriate PD."""
        du = DataUnit(desc, self.ctx.store)
        self.ctx.register(du)
        with self._lock:
            self._dus.append(du)
        pd = target or self._choose_pd(desc)
        if pd is not None and du.size > 0:
            from .data_unit import DUState

            self.ctx.store.hset(f"du:{du.id}", "state", DUState.PENDING)
            self.ctx.transfer_service.ingest(du, pd)
        return du

    def submit_compute_unit(self, desc: ComputeUnitDescription) -> ComputeUnit:
        cu = ComputeUnit(desc, self.ctx.store)
        self.ctx.register(cu)
        cu.timings.submitted = time.monotonic()
        cu._set_state(CUState.PENDING)
        with self._lock:
            self._cus.append(cu)
        # Asynchronous interface (§4.2): enqueue and return immediately.
        self.ctx.store.push("cds:incoming", cu.id)
        return cu

    def compute_units(self) -> List[ComputeUnit]:
        with self._lock:
            return list(self._cus)

    def data_units(self) -> List[DataUnit]:
        with self._lock:
            return list(self._dus)

    # ----------------------------------------------------------- scheduling
    def _choose_pd(self, desc: DataUnitDescription) -> Optional[PilotData]:
        """Affinity-aware PD selection for a new DU."""
        from .affinity import match_affinity

        with self._lock:
            pds = list(self._pds)
        need = max(desc.size_hint, sum(map(len, desc.files.values())))
        fits = [pd for pd in pds if pd.free_bytes >= need]
        candidates = [
            pd for pd in fits if match_affinity(desc.affinity, pd.affinity)
        ]
        if not candidates:
            candidates = fits  # affinity miss: any PD with space
        if not candidates:
            return None  # nowhere fits — DU stays in its local buffer
        # Prefer the emptiest (simple balance; the cost model handles the
        # rest at CU-placement time).
        return max(candidates, key=lambda pd: pd.free_bytes)

    def _pilot_tq_estimate(self, pilot: PilotCompute) -> float:
        """Expected wait before this pilot could start one more CU.

        Uses the DECLARED per-CU simulated/estimated compute seconds of the
        work already bound to the pilot (queued + running), so long tasks
        spread out instead of piling onto the data-local pilot — the T_Q
        side of the §6.1 trade-off."""
        st = pilot.state
        if st in PilotState.TERMINAL:
            return float("inf")
        tq = 0.0
        if st == PilotState.PROVISIONING:
            tq += pilot.description.queue_time_s

        def cu_cost(cu_id: str) -> float:
            try:
                d = self.ctx.lookup(cu_id).description
                return max(d.sim_compute_s, d.est_compute_s, self.avg_cu_estimate_s)
            except KeyError:
                return self.avg_cu_estimate_s

        pending = [
            item["cu"] if isinstance(item, dict) else item
            for item in self.ctx.store.qpeek(pilot.queue_name)
        ]
        running = pilot.running_cus()
        total = sum(cu_cost(c) for c in (*pending, *running))
        free = pilot.slots - len(running) - len(pending)
        if free <= 0:
            tq += total / max(1, pilot.slots)
        return max(tq, 0.0)

    def _input_bytes_by_location(self, cu: ComputeUnit) -> Dict[str, int]:
        """Cheapest-replica input footprint per location label."""
        out: Dict[str, int] = {}
        for du_id in cu.description.input_data:
            du: DataUnit = self.ctx.lookup(du_id)
            locs = du.locations
            if not locs:
                # not yet staged anywhere: counts as at the submission host
                out["submission"] = out.get("submission", 0) + du.size
                continue
            # a replicated DU contributes at EACH replica location; the
            # estimator in decide_placement sums cheapest per pilot — so we
            # pre-reduce here: each DU contributes only its cheapest replica
            # for each candidate pilot.  We keep per-location totals and let
            # decide_placement handle the sum; to keep that exact we expose
            # every replica location annotated with the DU size, and the
            # pilot-wise reduction happens in _rank_pilots below.
            for pd_id in locs:
                pd: PilotData = self.ctx.lookup(pd_id)
                out.setdefault(pd.affinity, 0)
        return out

    def _rank_pilots(self, cu: ComputeUnit):
        """Rank pilots by T_Q + Σ_DU cheapest-replica T_X (the §6.1 score)."""
        from .cost_model import cheapest_replica, estimate_tx

        with self._lock:
            pilots = [
                p for p in self._pilots if p.state not in PilotState.TERMINAL
            ]
        from .affinity import match_affinity

        constraint = cu.description.affinity
        ranked = []
        for p in pilots:
            if constraint and not match_affinity(constraint, p.affinity):
                continue
            t_q = self._pilot_tq_estimate(p)
            t_stage = 0.0
            for du_id in cu.description.input_data:
                du: DataUnit = self.ctx.lookup(du_id)
                if p.sandbox.has_du(du.id):
                    continue  # pilot-level cache hit
                replica_labels = []
                linked = False
                for pd_id in du.locations:
                    pd: PilotData = self.ctx.lookup(pd_id)
                    if self.ctx.transfer_service.is_linkable(pd, p.affinity):
                        linked = True
                        break
                    replica_labels.append(pd.affinity)
                if linked:
                    continue
                if replica_labels:
                    _, t = cheapest_replica(
                        du.size, replica_labels, p.affinity, self.ctx.topology
                    )
                    t_stage += t
                else:
                    # ingest from submission host: backend-profile cost
                    t_stage += self.ctx.transfer_service.simulated_ingest_time(
                        du.size, p.sandbox
                    )
            strategy = (
                "data-to-compute" if t_q >= t_stage else "compute-to-data"
            )
            ranked.append((t_q + t_stage, t_q, t_stage, strategy, p))
        ranked.sort(key=lambda r: (r[0], r[4].id))
        return ranked

    def _has_free_slot(self, pilot: PilotCompute) -> bool:
        depth = self.ctx.store.qlen(pilot.queue_name)
        running = len(pilot.running_cus())
        return pilot.state == PilotState.ACTIVE and (
            running + depth < pilot.slots
        )

    def _place(self, cu: ComputeUnit) -> None:
        """One pass of the §5 placement algorithm for one CU."""
        desc = cu.description
        if desc.pilot is not None:
            # Application-level direct binding (§4.3.2 control level (i)).
            pilot: PilotCompute = self.ctx.lookup(desc.pilot)
            self._push_to_pilot(cu, pilot)
            return
        ranked = self._rank_pilots(cu)
        if not ranked:
            self.ctx.store.push(GLOBAL_QUEUE, {"cu": cu.id, "dup": False})
            return
        score, t_q, t_stage, strategy, best = ranked[0]
        self._decisions.append(
            {
                "cu": cu.id,
                "pilot": best.id,
                "t_q": t_q,
                "t_stage": t_stage,
                "strategy": strategy,
            }
        )
        # Step 2: same-affinity pilot with an empty slot → pilot queue.
        if self._has_free_slot(best):
            self._push_to_pilot(cu, best)
            return
        # Step 3: delayed scheduling — wait n sec, recheck.
        if self.delayed_scheduling_s > 0:
            self._delayed.append(
                {
                    "cu": cu,
                    "pilot": best,
                    "deadline": time.monotonic() + self.delayed_scheduling_s,
                }
            )
            return
        # Step 4: global queue — first pilot with a slot pulls it.
        self.ctx.store.push(GLOBAL_QUEUE, {"cu": cu.id, "dup": False})

    def _push_to_pilot(self, cu: ComputeUnit, pilot: PilotCompute) -> None:
        if self.ctx.data_mode == "push":
            # Push-mode data management (§4.2): the manager pre-stages the
            # input DUs into the pilot sandbox before the CU is queued.
            for du_id in cu.description.input_data:
                du: DataUnit = self.ctx.lookup(du_id)
                self.ctx.transfer_service.stage_in(
                    du, pilot.sandbox, pilot.affinity
                )
        self.ctx.store.push(pilot.queue_name, {"cu": cu.id, "dup": False})

    def _scheduler_loop(self) -> None:
        store = self.ctx.store
        while not self._stop.is_set():
            try:
                cu_id = store.pop("cds:incoming", timeout=0.02)
            except Exception:
                time.sleep(0.05)
                continue
            if cu_id is not None:
                try:
                    cu = self.ctx.lookup(cu_id)
                    if cu.state == CUState.PENDING:
                        self._place(cu)
                except Exception:
                    pass
            # Re-check delayed CUs (step 3).
            now = time.monotonic()
            still: List[Dict] = []
            for entry in self._delayed:
                cu, pilot = entry["cu"], entry["pilot"]
                if cu.state != CUState.PENDING:
                    continue
                if self._has_free_slot(pilot):
                    self._push_to_pilot(cu, pilot)
                elif now >= entry["deadline"]:
                    store.push(GLOBAL_QUEUE, {"cu": cu.id, "dup": False})
                else:
                    still.append(entry)
            self._delayed = still

    # ------------------------------------------------------------- control
    def decisions(self) -> List[Dict]:
        return list(self._decisions)

    def wait(self, timeout: float = 120.0) -> bool:
        """Block until every submitted CU is terminal.  True on success."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                cus = list(self._cus)
            if all(c.state in CUState.TERMINAL for c in cus):
                return True
            time.sleep(0.01)
        return False

    def cancel(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2.0)
