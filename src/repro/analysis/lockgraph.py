"""PD-L005 — static cross-module lock-order graph with cycle detection.

Nodes are canonical lock names (``Class.attr`` / ``module.var``); a
directed edge A→B means "somewhere, B is acquired while A is held" —
either a lexically nested ``with``, or a call made under A to a function
whose transitive acquisition closure contains B.  Two synthetic edge
families model the runtime that nesting can't show lexically:

  * ``CoordinationStore._inline_lock`` → every lock a subscriber callback
    acquires (inline dispatch runs callbacks under the drain lock), and
  * caller lock → the full closure of any store op it calls (mutators
    reach the shard/event/WAL locks and, in inline mode, the drain lock).

A cycle is a potential lock-order inversion; a same-name self edge
(N locks of one class acquired while a sibling is held, e.g. striped
``_lock_all`` loops) is reported too, because index-ordering is the only
thing making it safe and the analyzer cannot prove it.

:func:`build_lock_graph` is also the witness's ground truth: the runtime
lock-order witness (``analysis/witness.py``) checks the edges it observes
against this graph.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from .model import Finding, Project
from .rules import LintRule, register_rule


@dataclasses.dataclass(frozen=True)
class EdgeSite:
    path: str
    line: int
    col: int
    desc: str


class LockGraph:
    def __init__(self) -> None:
        #: (a, b) -> first witnessed site for the edge
        self.edges: Dict[Tuple[str, str], EdgeSite] = {}
        self.succ: Dict[str, Set[str]] = {}
        #: same-name nested acquisitions (reported separately)
        self.self_edges: List[Tuple[str, EdgeSite]] = []

    def add(self, a: str, b: str, site: EdgeSite) -> None:
        if a == b:
            self.self_edges.append((a, site))
            return
        if (a, b) not in self.edges:
            self.edges[(a, b)] = site
            self.succ.setdefault(a, set()).add(b)

    def find_cycles(self) -> List[List[str]]:
        """Minimal-ish cycles, one per strongly-connected component."""
        index: Dict[str, int] = {}
        low: Dict[str, int] = {}
        on_stack: Set[str] = set()
        stack: List[str] = []
        sccs: List[List[str]] = []
        counter = [0]

        def strongconnect(v: str) -> None:
            index[v] = low[v] = counter[0]
            counter[0] += 1
            stack.append(v)
            on_stack.add(v)
            for w in self.succ.get(v, ()):
                if w not in index:
                    strongconnect(w)
                    low[v] = min(low[v], low[w])
                elif w in on_stack:
                    low[v] = min(low[v], index[w])
            if low[v] == index[v]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    comp.append(w)
                    if w == v:
                        break
                if len(comp) > 1:
                    sccs.append(comp)

        for v in list(self.succ):
            if v not in index:
                strongconnect(v)
        return [self._cycle_path(scc) for scc in sccs]

    def _cycle_path(self, scc: List[str]) -> List[str]:
        """An explicit cycle inside an SCC, as [a, b, ..., a]."""
        members = set(scc)
        start = scc[0]
        path = [start]
        seen = {start}
        cur = start
        while True:
            nxts = [w for w in self.succ.get(cur, ()) if w in members]
            if not nxts:
                return path  # defensive: SCC guarantees a successor
            nxt = min(nxts)
            if nxt in seen:
                return path[path.index(nxt) :] + [nxt]
            path.append(nxt)
            seen.add(nxt)
            cur = nxt


def build_lock_graph(project: Project) -> LockGraph:
    graph = LockGraph()
    for fn in project.all_functions():
        for acq in fn.acquires:
            if acq.held:
                top = acq.held[-1]
                if (
                    top.name == acq.lock.name
                    and top.text == acq.lock.text
                    and top.tag == "rlock"
                ):
                    # re-entering the same RLock instance: safe by design
                    continue
                graph.add(
                    acq.held[-1].name,
                    acq.lock.name,
                    EdgeSite(
                        str(fn.module.path),
                        acq.line,
                        acq.col,
                        f"nested acquisition in {fn.qualname}()",
                    ),
                )
        for acq in fn.loop_acquires:
            graph.self_edges.append(
                (
                    acq.lock.name,
                    EdgeSite(
                        str(fn.module.path),
                        acq.line,
                        acq.col,
                        f"loop acquisition without release in {fn.qualname}()",
                    ),
                )
            )
        for fact in fn.calls:
            if not fact.held:
                continue
            callee = project.resolve_call(fact, fn)
            if callee is None or not callee.acq_closure:
                continue
            top = fact.held[-1]
            for target in sorted(callee.acq_closure):
                if (
                    target == top.name
                    and top.tag == "rlock"
                    and fact.recv_text == "self"
                ):
                    # self-call re-entering our own RLock: safe by design
                    continue
                graph.add(
                    fact.held[-1].name,
                    target,
                    EdgeSite(
                        str(fn.module.path),
                        fact.line,
                        fact.col,
                        f"{fn.qualname}() calls {callee.qualname}() "
                        f"while holding {fact.held[-1].name}",
                    ),
                )
    # inline dispatch: callbacks run under the store's drain lock
    for store_name in sorted(project.store_classes):
        cls = project.class_index[store_name]
        if "_inline_lock" not in cls.attr_tags:
            continue
        drain = f"{store_name}._inline_lock"
        for fn in project.all_functions():
            if not fn.is_subscriber_cb:
                continue
            for target in sorted(fn.acq_closure):
                graph.add(
                    drain,
                    target,
                    EdgeSite(
                        str(fn.module.path),
                        getattr(fn.node, "lineno", 0),
                        0,
                        f"inline dispatch into subscriber {fn.qualname}()",
                    ),
                )
    return graph


@register_rule("PD-L005")
class LockOrderInversion(LintRule):
    """The whole-project lock graph must stay acyclic (and same-class
    striped locks must not nest without a provable order)."""

    title = "lock-order inversion (cycle in the static lock graph)"
    scope = "project"

    def check_project(self, project):
        graph = build_lock_graph(project)
        for name, site in graph.self_edges:
            yield Finding(
                rule=self.rule_id,
                path=site.path,
                line=site.line,
                col=site.col,
                message=(
                    f"multiple '{name}' instances acquired while one is "
                    f"already held ({site.desc}) — ordering unprovable "
                    f"statically"
                ),
                hint=(
                    "acquire in a fixed total order (e.g. shard index) and "
                    "suppress with a justification, or restructure to hold "
                    "one at a time"
                ),
            )
        for cycle in graph.find_cycles():
            ring = " → ".join(cycle)
            sites = []
            for a, b in zip(cycle, cycle[1:]):
                site = graph.edges.get((a, b))
                if site is not None:
                    sites.append(f"{a}→{b} at {site.path}:{site.line} ({site.desc})")
            anchor: Optional[EdgeSite] = (
                graph.edges.get((cycle[0], cycle[1])) if len(cycle) > 1 else None
            )
            yield Finding(
                rule=self.rule_id,
                path=anchor.path if anchor else "<project>",
                line=anchor.line if anchor else 0,
                col=anchor.col if anchor else 0,
                message=f"lock-order inversion: {ring}",
                hint="; ".join(sites)
                or "pick one global order for these locks and stick to it",
            )
