"""pdlint rules — the concurrency contracts of the coordination plane.

Each rule is a registered :class:`LintRule` (registry styled after
``core/placement.py``'s PlacementStrategy registry).  Rule ids map 1:1
onto the numbered invariants in the README "Concurrency contracts"
section:

  PD-L001  no store op while a store-internal lock is held
  PD-L002  no unbounded blocking call under any held lock
  PD-L003  subscriber callbacks must not mutate the store directly
  PD-L004  mutate-then-read of event-derived state needs flush_events()
  PD-L005  the cross-module lock graph must stay acyclic (see lockgraph)
  PD-L006  no scan materialization (sort/extend) under a shard stripe
"""

from __future__ import annotations

import abc
import threading
from typing import Callable, Dict, Iterable, Iterator, List, Optional

from .model import (
    CallFact,
    Finding,
    FunctionFacts,
    ModuleModel,
    Project,
    STORE_BLOCKING,
    STORE_MUTATORS,
    STORE_PUBLISHING,
    STORE_READS,
    is_store_recv,
    leaf_blocking,
)


class LintRule(abc.ABC):
    """One checkable contract; subclasses register via @register_rule."""

    rule_id: str = "?"
    title: str = ""
    #: "module" rules run once per file; "project" rules once per run
    scope: str = "module"

    def check_module(
        self, project: Project, module: ModuleModel
    ) -> Iterator[Finding]:
        return iter(())

    def check_project(self, project: Project) -> Iterator[Finding]:
        return iter(())


_REGISTRY: Dict[str, Callable[[], LintRule]] = {}
_registry_lock = threading.Lock()


def register_rule(rule_id: str):
    """Class decorator: ``@register_rule("PD-L001")`` (placement-registry
    idiom — the id doubles as the suppression token)."""

    def deco(cls):
        cls.rule_id = rule_id
        with _registry_lock:
            _REGISTRY[rule_id] = cls
        return cls

    return deco


def make_rules(select: Optional[Iterable[str]] = None) -> List[LintRule]:
    with _registry_lock:
        ids = sorted(_REGISTRY) if select is None else list(select)
        missing = [i for i in ids if i not in _REGISTRY]
        if missing:
            raise KeyError(
                f"unknown rule(s) {missing}; known: {sorted(_REGISTRY)}"
            )
        return [_REGISTRY[i]() for i in ids]


def list_rules() -> List[str]:
    with _registry_lock:
        return sorted(_REGISTRY)


# ------------------------------------------------------------------ rules


def _held_desc(fact: CallFact) -> str:
    return ", ".join(h.name for h in fact.held)


@register_rule("PD-L001")
class StoreOpUnderStoreLock(LintRule):
    """A store-API call issued while a lock of the store itself is held:
    re-entering a shard/WAL/event lock from inside its own critical
    section is a self-deadlock (or holds a stripe across dispatch)."""

    title = "store op under a store-internal lock"

    def check_module(self, project, module):
        ops = STORE_MUTATORS | STORE_READS | STORE_BLOCKING
        for cls_name in project.store_classes & set(module.classes):
            cls = module.classes[cls_name]
            for fn in cls.methods.values():
                for fact in fn.calls:
                    if not fact.held or fact.recv_text != "self":
                        continue
                    if fact.func_name not in ops:
                        continue
                    yield Finding(
                        rule=self.rule_id,
                        path=str(module.path),
                        line=fact.line,
                        col=fact.col,
                        message=(
                            f"store op self.{fact.func_name}() called inside "
                            f"a critical section (held: {_held_desc(fact)})"
                        ),
                        hint=(
                            "collect under the lock, call the store op after "
                            "release — see hset()'s flush-after-release shape"
                        ),
                    )


@register_rule("PD-L002")
class BlockingUnderLock(LintRule):
    """An unbounded blocking call (sleep, join, Event/Condition wait,
    queue.get, file I/O, transfers, flush_events barriers) while any lock
    is held stalls every thread contending on that lock."""

    title = "blocking call under a held lock"

    def check_module(self, project, module):
        for fn in module.functions.values():
            seen = set()
            for fact in fn.calls:
                if not fact.held:
                    continue
                reason = None
                leaf = leaf_blocking(project, fact)
                if leaf is not None:
                    blocked, exempt = leaf
                    if exempt:
                        continue
                    reason = blocked
                else:
                    callee = project.resolve_call(fact, fn)
                    if (
                        callee is not None
                        and callee.blocking_reason
                        and not (
                            is_store_recv(project, fact)
                            and fact.func_name
                            in (STORE_MUTATORS | STORE_READS)
                        )
                    ):
                        reason = f"{callee.qualname}() → {callee.blocking_reason}"
                if reason is None:
                    continue
                key = (fact.line, fact.func_name)
                if key in seen:
                    continue
                seen.add(key)
                yield Finding(
                    rule=self.rule_id,
                    path=str(module.path),
                    line=fact.line,
                    col=fact.col,
                    message=(
                        f"blocking call ({reason}) while holding "
                        f"{_held_desc(fact)}"
                    ),
                    hint=(
                        "move the wait outside the critical section, or "
                        "snapshot state under the lock and block after "
                        "release"
                    ),
                )


@register_rule("PD-L003")
class MutatingSubscriberCallback(LintRule):
    """A ``store.subscribe`` callback that mutates the store directly.

    Callbacks run on the dispatcher thread; a mutation re-enters the
    event plane from inside delivery (and, in inline dispatch mode, runs
    under the caller's locks).  The sanctioned re-entrant path is a
    handoff: queue.put to your own thread or a StoreEventPump."""

    title = "subscriber callback mutates the store"
    scope = "project"

    def check_project(self, project):
        for fn in project.all_functions():
            if not fn.is_subscriber_cb:
                continue
            for fact in fn.calls:
                chain = None
                if is_store_recv(project, fact) and fact.func_name in STORE_MUTATORS:
                    chain = f"store.{fact.func_name}"
                else:
                    callee = project.resolve_call(fact, fn)
                    if (
                        callee is not None
                        and callee.publishes
                        and not (
                            is_store_recv(project, fact)
                            and fact.func_name not in STORE_MUTATORS
                        )
                    ):
                        chain = f"{callee.qualname}() → {callee.mutate_chain}"
                if chain is None:
                    continue
                yield Finding(
                    rule=self.rule_id,
                    path=str(fn.module.path),
                    line=fact.line,
                    col=fact.col,
                    message=(
                        f"subscriber callback {fn.qualname}() mutates the "
                        f"store ({chain})"
                    ),
                    hint=(
                        "hand the event to your own queue/StoreEventPump and "
                        "mutate from that thread (subscribe() docstring)"
                    ),
                )


@register_rule("PD-L004")
class MutateThenReadWithoutBarrier(LintRule):
    """Publish a mutation, then read state a subscriber callback derives
    from it, with no ``flush_events()`` barrier in between: the dispatcher
    delivers asynchronously, so the read can see the pre-mutation value."""

    title = "mutate-then-read of derived state without flush_events()"
    scope = "project"

    def check_project(self, project):
        for fn in project.all_functions():
            yield from self._check_fn(project, fn)

    def _check_fn(self, project: Project, fn: FunctionFacts):
        if fn.is_subscriber_cb:
            return  # callbacks run ON the dispatcher: nothing to barrier
        derived = set()
        if fn.cls:
            cls = fn.module.classes.get(fn.cls)
            if cls is not None:
                derived = cls.derived_attrs
        dirty: Optional[str] = None
        reported = set()
        for ev in fn.events:
            if ev[0] == "call":
                fact = ev[1]
                if fact.func_name == "flush_events" and (
                    is_store_recv(project, fact) or fact.recv_text == "self"
                ):
                    dirty = None
                    continue
                if is_store_recv(project, fact) and (
                    fact.func_name in STORE_PUBLISHING
                ):
                    dirty = f"store.{fact.func_name} (line {fact.line})"
                    continue
                callee = project.resolve_call(fact, fn)
                if callee is None:
                    continue
                if dirty is not None:
                    for attr in sorted(callee.exposed_reads):
                        yield from self._report(fn, fact, attr, dirty, reported)
                if callee.publishes and not (
                    is_store_recv(project, fact)
                    and fact.func_name not in STORE_PUBLISHING
                ):
                    dirty = (
                        f"{callee.qualname}() → {callee.mutate_chain} "
                        f"(line {fact.line})"
                    )
            elif ev[0] == "read" and dirty is not None and ev[1] in derived:
                attr, line = ev[1], ev[2]
                fake = CallFact(line, 0, "", None, None, (), None, False)
                yield from self._report(fn, fake, attr, dirty, reported)

    def _report(self, fn, fact, attr, dirty, reported):
        if attr in reported:
            return
        reported.add(attr)
        yield Finding(
            rule=self.rule_id,
            path=str(fn.module.path),
            line=fact.line,
            col=fact.col,
            message=(
                f"{fn.qualname}() reads event-derived '{attr}' after "
                f"{dirty} with no flush_events() barrier"
            ),
            hint=(
                "call store.flush_events() between the mutation and the "
                "read, or accept staleness with a reviewed disable"
            ),
        )


@register_rule("PD-L006")
class ScanMaterializationUnderStripe(LintRule):
    """Allocation-heavy result materialization (sort/extend across
    shards) under a stripe lock: per-shard critical sections must stay
    O(log n + slice); merging belongs outside the lock."""

    title = "scan materialization under a shard stripe lock"

    def check_module(self, project, module):
        for cls_name in project.store_classes & set(module.classes):
            cls = module.classes[cls_name]
            for fn in cls.methods.values():
                for fact in fn.calls:
                    if not fact.held:
                        continue
                    if fact.func_name == "sorted" or (
                        fact.func_name in ("sort", "extend")
                        and fact.recv_text is not None
                    ):
                        yield Finding(
                            rule=self.rule_id,
                            path=str(module.path),
                            line=fact.line,
                            col=fact.col,
                            message=(
                                f"{fact.func_name}() materializes results "
                                f"under {_held_desc(fact)}"
                            ),
                            hint=(
                                "copy the per-shard slice under the lock, "
                                "merge/sort the slices after release "
                                "(heapq.merge over sorted slices)"
                            ),
                        )


# PD-L005 lives in lockgraph.py (it needs the whole-project lock graph);
# importing it here registers the rule alongside the others.
from . import lockgraph as _lockgraph  # noqa: E402,F401
