"""Runtime lock-order witness for the coordination plane.

With ``REPRO_LOCK_WITNESS=1`` in the environment, ``coordination.py``
creates every store lock through :func:`install`'s factory; each
:class:`WitnessedLock` records a per-thread held-lock stack and, on every
*nested* acquisition, inserts an order edge into a global graph.  The
first edge that closes a cycle raises :class:`LockOrderViolation` with
the acquisition sites of every edge on the cycle — a deadlock caught the
first time the inverted order is *executed*, not the first time two
threads actually collide.

Edges are keyed per lock *instance*, so index-ordered striped
acquisition (``_lock_all``) and multi-store tests cannot alias into
false cycles; :meth:`Witness.observed_class_edges` collapses instances
back to class-level names for cross-checking against the static PD-L005
graph (``analysis/lockgraph.py``).

The wrapper is ``threading.Condition``-compatible: ``Condition(lock)``
only needs ``acquire``/``release`` (its ``_is_owned`` fallback probes
with a non-blocking acquire, which the wrapper forwards faithfully).
"""

from __future__ import annotations

import os
import sys
import threading
from typing import Dict, List, Optional, Set, Tuple


class LockOrderViolation(AssertionError):
    """A lock acquisition closed a cycle in the observed order graph."""


def _call_site() -> str:
    """First stack frame outside this module / threading internals."""
    frame = sys._getframe(2)
    while frame is not None:
        base = os.path.basename(frame.f_code.co_filename)
        if base not in ("witness.py", "threading.py"):
            return f"{base}:{frame.f_lineno}"
        frame = frame.f_back
    return "<unknown>"


class Witness:
    """The order graph plus per-thread held stacks."""

    def __init__(self) -> None:
        self._mu = threading.Lock()
        self._tls = threading.local()
        #: serial -> class-level lock name
        self._names: Dict[int, str] = {}
        #: instance-level edges: a_serial -> {b_serial}
        self._succ: Dict[int, Set[int]] = {}
        #: (a_serial, b_serial) -> acquisition site of the first witness
        self._sites: Dict[Tuple[int, int], str] = {}
        self.violations: List[str] = []

    # ------------------------------------------------------------ stacks
    def _stack(self) -> List[list]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = []
            self._tls.stack = stack
        return stack

    def held_names(self) -> List[str]:
        return [entry[0].name for entry in self._stack()]

    # ------------------------------------------------------------- edges
    def on_acquire(self, lock: "WitnessedLock") -> None:
        stack = self._stack()
        for entry in stack:
            if entry[0] is lock:  # re-entrant: no new edge
                entry[1] += 1
                return
        if stack:
            self._record_edge(stack[-1][0], lock, _call_site())
        stack.append([lock, 1])

    def on_release(self, lock: "WitnessedLock") -> None:
        stack = self._stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i][0] is lock:
                stack[i][1] -= 1
                if stack[i][1] == 0:
                    del stack[i]
                return
        # released by a thread that never recorded the acquire: ignore

    def _record_edge(self, a: "WitnessedLock", b: "WitnessedLock", site: str) -> None:
        if a is b:
            return
        with self._mu:
            self._names[a.serial] = a.name
            self._names[b.serial] = b.name
            succ = self._succ.setdefault(a.serial, set())
            if b.serial in succ:
                return
            back_path = self._find_path(b.serial, a.serial)
            succ.add(b.serial)
            self._sites[(a.serial, b.serial)] = site
            if back_path is None:
                return
            trace = self._format_cycle(a, b, site, back_path)
            self.violations.append(trace)
        raise LockOrderViolation(trace)

    def _find_path(self, src: int, dst: int) -> Optional[List[int]]:
        """DFS path src → dst over instance edges (None if unreachable)."""
        stack = [(src, [src])]
        seen = {src}
        while stack:
            node, path = stack.pop()
            if node == dst:
                return path
            for nxt in self._succ.get(node, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append((nxt, path + [nxt]))
        return None

    def _format_cycle(
        self, a: "WitnessedLock", b: "WitnessedLock", site: str, back_path: List[int]
    ) -> str:
        lines = [
            "lock-order inversion:",
            f"  new edge {a.name}#{a.serial} → {b.name}#{b.serial} "
            f"acquired at {site}",
            "  conflicts with the previously observed order:",
        ]
        for x, y in zip(back_path, back_path[1:]):
            xs = self._names.get(x, "?")
            ys = self._names.get(y, "?")
            at = self._sites.get((x, y), "?")
            lines.append(f"    {xs}#{x} → {ys}#{y} at {at}")
        lines.append(f"  held by this thread: {self.held_names()}")
        return "\n".join(lines)

    # ---------------------------------------------------------- reporting
    def observed_class_edges(self) -> Set[Tuple[str, str]]:
        """Instance edges collapsed to class-level names; same-name edges
        (index-ordered striping) are dropped."""
        with self._mu:
            out = set()
            for (a, b), _ in self._sites.items():
                an, bn = self._names.get(a, "?"), self._names.get(b, "?")
                if an != bn:
                    out.add((an, bn))
            return out

    def unexplained_edges(
        self, static_edges: Set[Tuple[str, str]]
    ) -> Set[Tuple[str, str]]:
        """Observed class-level edges absent from the static PD-L005
        graph — each one is a hole in the static model."""
        return {e for e in self.observed_class_edges() if e not in static_edges}


class WitnessedLock:
    """Drop-in Lock/RLock wrapper that reports to a :class:`Witness`."""

    _serial_mu = threading.Lock()
    _next_serial = 0

    __slots__ = ("_inner", "name", "reentrant", "serial", "_witness")

    def __init__(self, name: str, reentrant: bool, witness: Witness):
        self._inner = threading.RLock() if reentrant else threading.Lock()
        self.name = name
        self.reentrant = reentrant
        self._witness = witness
        with WitnessedLock._serial_mu:
            WitnessedLock._next_serial += 1
            self.serial = WitnessedLock._next_serial

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        if timeout == -1:
            got = self._inner.acquire(blocking)
        else:
            got = self._inner.acquire(blocking, timeout)
        if got:
            self._witness.on_acquire(self)
        return got

    def release(self) -> None:
        self._witness.on_release(self)
        self._inner.release()

    def __enter__(self) -> "WitnessedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<WitnessedLock {self.name}#{self.serial}>"


def witness_factory(witness: Witness):
    """A ``coordination.set_lock_factory``-shaped factory bound to
    ``witness``."""

    def factory(name: str, reentrant: bool = False) -> WitnessedLock:
        return WitnessedLock(name, reentrant, witness)

    return factory


_installed: Optional[Witness] = None


def install(witness: Optional[Witness] = None) -> Witness:
    """Route every subsequently created coordination lock through a
    witness (idempotent; returns the active witness)."""
    global _installed
    import repro.core.coordination as coordination

    if witness is None:
        witness = _installed or Witness()
    coordination.set_lock_factory(witness_factory(witness))
    _installed = witness
    return witness


def uninstall() -> None:
    """Restore plain ``threading`` locks for new stores."""
    global _installed
    import repro.core.coordination as coordination

    coordination.set_lock_factory(None)
    _installed = None


def active_witness() -> Optional[Witness]:
    return _installed


def enabled_by_env() -> bool:
    return os.environ.get("REPRO_LOCK_WITNESS", "").strip() not in ("", "0")
