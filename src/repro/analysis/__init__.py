"""Static + runtime analysis for the coordination plane.

``pdlint`` (:mod:`repro.analysis.pdlint`) statically enforces the
concurrency contracts PR 7's sharded store introduced; the lock-order
witness (:mod:`repro.analysis.witness`) validates the static lock graph
against real executions when ``REPRO_LOCK_WITNESS=1``.
"""

from .model import Finding, build_project

__all__ = ["Finding", "build_project"]
