"""pdlint CLI — run the concurrency-contract rules over source trees.

Usage::

    python -m repro.analysis.pdlint src/repro/core [more paths...]
    python -m repro.analysis.pdlint --list-rules
    python -m repro.analysis.pdlint --select PD-L002,PD-L005 src/repro/core

Exit codes: 0 clean, 1 unsuppressed findings, 2 usage/parse error.
``--markdown FILE`` appends a findings table (GitHub step-summary shape).
Suppress a finding with a ``# pdlint: disable=PD-Lxxx`` comment on (or
immediately above) the flagged line.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

from .model import Finding, Project, build_project
from .rules import list_rules, make_rules

EXIT_CLEAN = 0
EXIT_FINDINGS = 1
EXIT_ERROR = 2


def run(
    paths: Sequence[Path], select: Optional[Sequence[str]] = None
) -> Tuple[List[Finding], Project]:
    """Analyze ``paths``; returns (unsuppressed findings, project)."""
    project = build_project([Path(p) for p in paths])
    findings: List[Finding] = []
    for rule in make_rules(select):
        if rule.scope == "project":
            findings.extend(rule.check_project(project))
        else:
            for module in project.modules:
                findings.extend(rule.check_module(project, module))
    kept = []
    for f in findings:
        module = project.module_for(f.path)
        if module is not None and module.suppressed(f.line, f.rule):
            continue
        kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return kept, project


def _markdown_table(findings: Sequence[Finding], errors: Sequence[str]) -> str:
    lines = ["## pdlint — concurrency contracts", ""]
    if not findings and not errors:
        lines.append("No findings: every contract holds.")
        return "\n".join(lines) + "\n"
    if findings:
        lines += [
            f"{len(findings)} finding(s):",
            "",
            "| rule | location | message | hint |",
            "| --- | --- | --- | --- |",
        ]
        for f in findings:
            msg = f.message.replace("|", "\\|")
            hint = f.hint.replace("|", "\\|")
            lines.append(f"| {f.rule} | `{f.path}:{f.line}` | {msg} | {hint} |")
    for err in errors:
        lines.append(f"- parse error: `{err}`")
    return "\n".join(lines) + "\n"


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="pdlint",
        description="concurrency-contract static analyzer for the "
        "coordination plane",
    )
    parser.add_argument("paths", nargs="*", help="files or directories")
    parser.add_argument(
        "--select",
        help="comma-separated rule ids to run (default: all)",
    )
    parser.add_argument(
        "--markdown",
        metavar="FILE",
        help="append a findings table to FILE (CI step summary)",
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="print rule ids and exit"
    )
    args = parser.parse_args(argv)
    if args.list_rules:
        for rule_id in list_rules():
            print(rule_id)
        return EXIT_CLEAN
    if not args.paths:
        print("pdlint: no paths given (try src/repro/core)", file=sys.stderr)
        return EXIT_ERROR
    paths = [Path(p) for p in args.paths]
    for p in paths:
        if not p.exists():
            print(f"pdlint: path does not exist: {p}", file=sys.stderr)
            return EXIT_ERROR
    select = None
    if args.select:
        select = [s.strip() for s in args.select.split(",") if s.strip()]
    try:
        findings, project = run(paths, select)
    except KeyError as exc:
        print(f"pdlint: {exc}", file=sys.stderr)
        return EXIT_ERROR
    for f in findings:
        print(f.format())
    for err in project.errors:
        print(f"pdlint: parse error: {err}", file=sys.stderr)
    if args.markdown:
        with open(args.markdown, "a", encoding="utf-8") as fh:
            fh.write(_markdown_table(findings, project.errors))
    if project.errors:
        return EXIT_ERROR
    if findings:
        print(
            f"pdlint: {len(findings)} finding(s) "
            f"(suppress with '# pdlint: disable=<rule>')",
            file=sys.stderr,
        )
        return EXIT_FINDINGS
    return EXIT_CLEAN


if __name__ == "__main__":
    sys.exit(main())
