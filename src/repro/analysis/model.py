"""Semantic model for pdlint — AST facts the concurrency rules consume.

pdlint is a *project-specific* analyzer: it does not try to type-check
arbitrary Python, it encodes the conventions of this repository (the
``CoordinationStore`` API, the ``self._lock`` naming idiom, well-known
attribute names like ``ctx.store``) and extracts, per function:

  * which locks are held at every call site (``with`` nesting plus bare
    ``.acquire()``/``.release()`` pairs),
  * every call with a best-effort receiver type (assignment inference,
    parameter annotations, well-known-name hints),
  * the ordered stream of store mutations, ``flush_events`` barriers and
    ``self.<attr>`` reads/writes that PD-L004 replays,
  * subscriber callbacks registered via ``store.subscribe``.

Everything here is pure stdlib ``ast`` — no imports of the analyzed code.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# --------------------------------------------------------------- findings


@dataclasses.dataclass(frozen=True)
class Finding:
    """One structured lint finding (``file:line:col`` + rule id + hint)."""

    rule: str
    path: str
    line: int
    col: int
    message: str
    hint: str = ""

    def format(self) -> str:
        out = f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out


_DIRECTIVE_RE = re.compile(r"#\s*pdlint:\s*disable=([A-Za-z0-9_\-, ]+)")


def parse_suppressions(lines: Sequence[str]) -> Dict[int, Set[str]]:
    """``# pdlint: disable=PD-Lxxx[,PD-Lyyy]`` directives by line number.

    A trailing directive suppresses its own line; a directive on a line
    that is *only* a comment also suppresses the next source line."""
    out: Dict[int, Set[str]] = {}
    pending: Set[str] = set()
    for lineno, raw in enumerate(lines, start=1):
        stripped = raw.strip()
        rules: Set[str] = set()
        m = _DIRECTIVE_RE.search(raw)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        if pending and not stripped.startswith("#"):
            out.setdefault(lineno, set()).update(pending)
            pending = set()
        if rules:
            out.setdefault(lineno, set()).update(rules)
            if stripped.startswith("#"):
                pending |= rules
    return out


# ----------------------------------------------------------- type tagging
#
# Tags are either primitive ("lock", "rlock", "condition", "event",
# "queue", "semaphore", "thread", "file", "deque") or a project class
# name.  LOCK_TAGS are the mutex-like ones that participate in held-lock
# tracking and the PD-L005 graph.

LOCK_TAGS = {"lock", "rlock", "condition"}
NONLOCK_TAGS = {"event", "queue", "semaphore", "thread", "file", "deque"}

_FACTORY_TAGS: Dict[Tuple[str, str], str] = {
    ("threading", "Lock"): "lock",
    ("threading", "RLock"): "rlock",
    ("threading", "Condition"): "condition",
    ("threading", "Event"): "event",
    ("threading", "Semaphore"): "semaphore",
    ("threading", "BoundedSemaphore"): "semaphore",
    ("threading", "Thread"): "thread",
    ("queue", "Queue"): "queue",
    ("queue", "SimpleQueue"): "queue",
    ("queue", "LifoQueue"): "queue",
    ("queue", "PriorityQueue"): "queue",
    ("collections", "deque"): "deque",
}

_BARE_FACTORY_TAGS = {
    "Lock": "lock",
    "RLock": "rlock",
    "Condition": "condition",
    "Event": "event",
    "open": "file",
}

#: well-known attribute / variable names → project class, used when
#: assignment inference fails (repo convention, cf. RuntimeContext wiring)
TYPE_HINTS: Dict[str, str] = {
    "store": "CoordinationStore",
    "_store": "CoordinationStore",
    "ctx": "RuntimeContext",
    "_ctx": "RuntimeContext",
    "transfer_service": "TransferService",
    "tier_manager": "TierManager",
    "sh": "_Shard",
    "du": "DataUnit",
    "cu": "ComputeUnit",
    "pd": "PilotData",
    "sandbox": "PilotData",
    "pins": "PinRegistry",
}

#: name fragments that mark an *untyped* receiver as probably-a-mutex
_LOCKISH_NAME_RE = re.compile(r"(^|_)(lock|mutex|mu)$|_cond$|^cond$")


# ----------------------------------------------------------------- facts


@dataclasses.dataclass(frozen=True)
class LockRef:
    """A canonical lock identity: ``Class.attr`` / ``module.var``."""

    name: str
    text: str
    tag: Optional[str]
    line: int


@dataclasses.dataclass
class CallFact:
    line: int
    col: int
    func_name: str
    recv_text: Optional[str]
    recv_tag: Optional[str]
    held: Tuple[LockRef, ...]
    node: ast.Call
    in_loop: bool


@dataclasses.dataclass
class AcqFact:
    lock: LockRef
    line: int
    col: int
    held: Tuple[LockRef, ...]
    manual: bool
    in_loop: bool


class FunctionFacts:
    """Everything the rules need to know about one function/method."""

    def __init__(
        self,
        qualname: str,
        name: str,
        cls: Optional[str],
        node: ast.AST,
        module: "ModuleModel",
    ):
        self.qualname = qualname
        self.name = name
        self.cls = cls
        self.node = node
        self.module = module
        self.calls: List[CallFact] = []
        self.acquires: List[AcqFact] = []
        #: ordered stream: ("call", CallFact) | ("read"|"write", attr, line)
        self.events: List[tuple] = []
        self.attr_writes: Set[str] = set()
        self.local_funcs: Dict[str, "FunctionFacts"] = {}
        #: names acquired in a loop without a paired release in that loop
        self.loop_acquires: List[AcqFact] = []
        # ---- project-phase results
        self.is_subscriber_cb = False
        self.blocking_reason: Optional[str] = None
        self.publishes = False
        self.mutate_chain: Optional[str] = None
        #: derived attrs this function reads before any flush barrier
        self.exposed_reads: Set[str] = set()
        self.acq_closure: Set[str] = set()


class ClassModel:
    def __init__(self, name: str, node: ast.ClassDef, module: "ModuleModel"):
        self.name = name
        self.node = node
        self.module = module
        self.attr_tags: Dict[str, str] = {}
        #: condition attr -> underlying lock attr (Condition(self._x))
        self.cond_underlying: Dict[str, str] = {}
        self.methods: Dict[str, FunctionFacts] = {}
        self.derived_attrs: Set[str] = set()


class ModuleModel:
    def __init__(self, path: Path, source: str):
        self.path = path
        self.stem = path.stem
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        self.suppress = parse_suppressions(self.lines)
        self.classes: Dict[str, ClassModel] = {}
        self.functions: Dict[str, FunctionFacts] = {}
        self.var_tags: Dict[str, str] = {}

    def suppressed(self, line: int, rule: str) -> bool:
        return rule in self.suppress.get(line, ())


# ------------------------------------------------------- expression utils


def _attr_chain(expr: ast.AST) -> Optional[List[str]]:
    """``self.ctx.store`` -> ["self", "ctx", "store"]; None if not a pure
    name/attribute chain."""
    parts: List[str] = []
    while isinstance(expr, ast.Attribute):
        parts.append(expr.attr)
        expr = expr.value
    if isinstance(expr, ast.Name):
        parts.append(expr.id)
        return list(reversed(parts))
    return None


def _annotation_class(ann: Optional[ast.AST], classes: Set[str]) -> Optional[str]:
    """First project-class name mentioned in an annotation."""
    if ann is None:
        return None
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        for name in classes:
            if re.search(rf"\b{re.escape(name)}\b", ann.value):
                return name
        return None
    for node in ast.walk(ann):
        if isinstance(node, ast.Name) and node.id in classes:
            return node.id
    return None


def _is_literal_zero(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and node.value in (0, 0.0)


def call_kwarg(node: ast.Call, name: str, pos: Optional[int] = None):
    for kw in node.keywords:
        if kw.arg == name:
            return kw.value
    if pos is not None and len(node.args) > pos:
        return node.args[pos]
    return None


# ----------------------------------------------------------- the project


class Project:
    """All analyzed modules plus the cross-module indexes."""

    def __init__(self, modules: List[ModuleModel]):
        self.modules = modules
        self.class_index: Dict[str, ClassModel] = {}
        for mod in modules:
            for cls in mod.classes.values():
                self.class_index.setdefault(cls.name, cls)
        #: classes that implement the store API (hset + push + pop_any)
        self.store_classes: Set[str] = set()
        self.errors: List[str] = []

    @property
    def store_names(self) -> Set[str]:
        return self.store_classes | {"CoordinationStore"}

    def module_for(self, path: str) -> Optional[ModuleModel]:
        for mod in self.modules:
            if str(mod.path) == path:
                return mod
        return None

    def all_functions(self) -> Iterable[FunctionFacts]:
        for mod in self.modules:
            yield from mod.functions.values()

    def resolve_call(
        self, fact: CallFact, caller: FunctionFacts
    ) -> Optional[FunctionFacts]:
        """Best-effort static call target, or None."""
        if fact.recv_text is None:
            fn = caller.local_funcs.get(fact.func_name)
            if fn is not None:
                return fn
            return caller.module.functions.get(fact.func_name)
        if fact.recv_text == "self" and caller.cls:
            cls = caller.module.classes.get(caller.cls)
            if cls is not None:
                return cls.methods.get(fact.func_name)
            return None
        if fact.recv_tag and fact.recv_tag in self.class_index:
            return self.class_index[fact.recv_tag].methods.get(fact.func_name)
        return None


def _collect_class_attrs(mod: ModuleModel, classes: Set[str]) -> None:
    """Sweep B: per-class ``self.X = <factory>()`` attribute tags and
    module-level lock variables."""
    for node in mod.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name):
                tag = _value_tag(node.value, classes)
                if tag:
                    mod.var_tags[tgt.id] = tag
    for cls in mod.classes.values():
        for sub in ast.walk(cls.node):
            if isinstance(sub, ast.ClassDef) and sub is not cls.node:
                continue
            if not isinstance(sub, (ast.Assign, ast.AnnAssign)):
                continue
            targets = sub.targets if isinstance(sub, ast.Assign) else [sub.target]
            value = sub.value
            if value is None:
                continue
            for tgt in targets:
                chain = _attr_chain(tgt)
                if chain is None or len(chain) != 2 or chain[0] != "self":
                    continue
                attr = chain[1]
                tag = _value_tag(value, classes)
                if tag and attr not in cls.attr_tags:
                    cls.attr_tags[attr] = tag
                if tag == "condition" and isinstance(value, ast.Call) and value.args:
                    inner = _attr_chain(value.args[0])
                    if inner and len(inner) == 2 and inner[0] == "self":
                        cls.cond_underlying[attr] = inner[1]


def _value_tag(value: ast.AST, classes: Set[str]) -> Optional[str]:
    """Tag for an assigned value: factory call, project-class ctor, file."""
    if not isinstance(value, ast.Call):
        return None
    func = value.func
    if isinstance(func, ast.Name):
        if func.id in classes:
            return func.id
        if func.id == "_make_lock":
            kw = call_kwarg(value, "reentrant")
            if kw is not None and isinstance(kw, ast.Constant) and kw.value:
                return "rlock"
            return "lock"
        return _BARE_FACTORY_TAGS.get(func.id)
    if isinstance(func, ast.Attribute) and isinstance(func.value, ast.Name):
        if func.attr in classes:
            return func.attr
        return _FACTORY_TAGS.get((func.value.id, func.attr))
    return None


#: container-mutation method names: ``self.x.pop(...)`` is a write, not a
#: read, for PD-L004 purposes
_MUTATING_METHODS = {
    "pop",
    "popleft",
    "append",
    "appendleft",
    "add",
    "discard",
    "remove",
    "update",
    "clear",
    "setdefault",
    "extend",
    "insert",
}


class _FnScanner:
    """One pass over a function body, source order, tracking held locks."""

    def __init__(
        self,
        project_classes: Set[str],
        mod: ModuleModel,
        cls: Optional[str],
        facts: FunctionFacts,
        pending: List[Tuple[ast.AST, Optional[str], str]],
    ):
        self.classes = project_classes
        self.mod = mod
        self.cls = cls
        self.facts = facts
        self.pending = pending
        self.locals: Dict[str, str] = {}
        self.held: List[LockRef] = []
        self.manual: List[LockRef] = []
        self.loop_depth = 0
        self.loop_acq: List[List[AcqFact]] = []
        self.loop_rel: List[Set[str]] = []
        node = facts.node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = list(node.args.posonlyargs) + list(node.args.args)
            for a in args:
                t = _annotation_class(a.annotation, project_classes)
                if t:
                    self.locals[a.arg] = t

    # ------------------------------------------------------------- typing
    def _expr_tag(self, expr: ast.AST) -> Optional[str]:
        chain = _attr_chain(expr)
        if chain is not None:
            return self._chain_tag(chain)
        if isinstance(expr, ast.Call):
            tag = _value_tag(expr, self.classes)
            if tag:
                return tag
            func = expr.func
            if isinstance(func, ast.Attribute):
                base = self._expr_tag(func.value)
                target = None
                if base and base in self.classes:
                    cls = self._class_model(base)
                    if cls is not None:
                        target = cls.methods.get(func.attr)
                if target is not None and isinstance(
                    target.node, (ast.FunctionDef, ast.AsyncFunctionDef)
                ):
                    return _annotation_class(target.node.returns, self.classes)
        return None

    def _class_model(self, name: str) -> Optional[ClassModel]:
        cls = self.mod.classes.get(name)
        if cls is not None:
            return cls
        return _PROJECT_CLASS_INDEX.get(name)

    def _chain_tag(self, chain: List[str]) -> Optional[str]:
        head, rest = chain[0], chain[1:]
        if head == "self" and self.cls:
            cur: Optional[str] = self.cls
        else:
            cur = (
                self.locals.get(head)
                or self.mod.var_tags.get(head)
                or (head if head in self.classes else None)
                or TYPE_HINTS.get(head)
            )
        for attr in rest:
            nxt: Optional[str] = None
            if cur and cur in self.classes:
                cls = self._class_model(cur)
                if cls is not None:
                    nxt = cls.attr_tags.get(attr)
            if nxt is None:
                nxt = TYPE_HINTS.get(attr)
            cur = nxt
            if cur is None:
                return TYPE_HINTS.get(chain[-1]) if attr != chain[-1] else None
        return cur

    def _lock_ref(self, expr: ast.AST) -> Optional[LockRef]:
        chain = _attr_chain(expr)
        if chain is None:
            return None
        tag = self._chain_tag(chain)
        if tag in NONLOCK_TAGS:
            return None
        lockish = tag in LOCK_TAGS or (
            tag is None and _LOCKISH_NAME_RE.search(chain[-1]) is not None
        )
        if not lockish:
            return None
        name = self._canonical(chain)
        text = ".".join(chain)
        return LockRef(name=name, text=text, tag=tag, line=getattr(expr, "lineno", 0))

    def _canonical(self, chain: List[str]) -> str:
        attr = chain[-1]
        if len(chain) == 1:
            return f"{self.mod.stem}.{attr}"
        owner: Optional[str] = None
        if chain[0] == "self" and len(chain) == 2 and self.cls:
            owner = self.cls
        else:
            owner_chain = chain[:-1]
            owner = self._chain_tag(owner_chain)
        if owner and owner in self.classes:
            cls = self._class_model(owner)
            if cls is not None:
                attr = cls.cond_underlying.get(attr, attr)
            return f"{owner}.{attr}"
        return f"{self.mod.stem}:{'.'.join(chain)}"

    # ------------------------------------------------------------ walking
    def scan(self) -> None:
        for stmt in self.facts.node.body:
            self._stmt(stmt)

    def _stmts(self, stmts: Sequence[ast.stmt]) -> None:
        for s in stmts:
            self._stmt(s)

    def _stmt(self, s: ast.stmt) -> None:
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.pending.append((s, self.cls, f"{self.facts.qualname}.<locals>"))
            self.facts.local_funcs[s.name] = None  # patched by builder
            return
        if isinstance(s, ast.ClassDef):
            return
        if isinstance(s, (ast.With, ast.AsyncWith)):
            self._with(s)
            return
        if isinstance(s, ast.Assign):
            self._expr(s.value)
            self._infer_assign(s)
            for tgt in s.targets:
                self._target(tgt)
            return
        if isinstance(s, ast.AnnAssign):
            if s.value is not None:
                self._expr(s.value)
                if isinstance(s.target, ast.Name):
                    tag = self._expr_tag(s.value) or _annotation_class(
                        s.annotation, self.classes
                    )
                    if tag:
                        self.locals[s.target.id] = tag
            elif isinstance(s.target, ast.Name):
                tag = _annotation_class(s.annotation, self.classes)
                if tag:
                    self.locals[s.target.id] = tag
            self._target(s.target)
            return
        if isinstance(s, ast.AugAssign):
            self._expr(s.value)
            self._target(s.target, aug=True)
            return
        if isinstance(s, ast.Delete):
            for tgt in s.targets:
                self._target(tgt)
            return
        if isinstance(s, (ast.For, ast.AsyncFor)):
            self._expr(s.iter)
            self._loop(s.body)
            self._stmts(s.orelse)
            return
        if isinstance(s, ast.While):
            self._expr(s.test)
            self._loop(s.body)
            self._stmts(s.orelse)
            return
        if isinstance(s, ast.If):
            self._expr(s.test)
            self._stmts(s.body)
            self._stmts(s.orelse)
            return
        if isinstance(s, ast.Try):
            self._stmts(s.body)
            for h in s.handlers:
                self._stmts(h.body)
            self._stmts(s.orelse)
            self._stmts(s.finalbody)
            return
        for child in ast.iter_child_nodes(s):
            if isinstance(child, ast.expr):
                self._expr(child)

    def _loop(self, body: Sequence[ast.stmt]) -> None:
        self.loop_depth += 1
        self.loop_acq.append([])
        self.loop_rel.append(set())
        self._stmts(body)
        acqs = self.loop_acq.pop()
        rels = self.loop_rel.pop()
        self.loop_depth -= 1
        for acq in acqs:
            if acq.lock.name not in rels:
                self.facts.loop_acquires.append(acq)

    def _with(self, s: ast.With) -> None:
        pushed = 0
        for item in s.items:
            ref = self._lock_ref(item.context_expr)
            if ref is not None:
                self.facts.acquires.append(
                    AcqFact(
                        lock=ref,
                        line=item.context_expr.lineno,
                        col=item.context_expr.col_offset,
                        held=tuple(self.held + self.manual),
                        manual=False,
                        in_loop=self.loop_depth > 0,
                    )
                )
                self.held.append(ref)
                pushed += 1
            else:
                self._expr(item.context_expr)
                if (
                    isinstance(item.context_expr, ast.Call)
                    and isinstance(item.context_expr.func, ast.Name)
                    and item.context_expr.func.id == "open"
                    and isinstance(item.optional_vars, ast.Name)
                ):
                    self.locals[item.optional_vars.id] = "file"
        self._stmts(s.body)
        for _ in range(pushed):
            self.held.pop()

    def _infer_assign(self, s: ast.Assign) -> None:
        if len(s.targets) != 1 or not isinstance(s.targets[0], ast.Name):
            return
        tag = self._expr_tag(s.value)
        if tag:
            self.locals[s.targets[0].id] = tag

    def _target(self, tgt: ast.AST, aug: bool = False) -> None:
        """Record ``self.<attr>`` writes in assignment targets."""
        if isinstance(tgt, (ast.Tuple, ast.List)):
            for el in tgt.elts:
                self._target(el, aug=aug)
            return
        base = tgt
        while isinstance(base, ast.Subscript):
            self._expr(base.slice)
            base = base.value
        chain = _attr_chain(base)
        if chain and len(chain) == 2 and chain[0] == "self":
            self.facts.attr_writes.add(chain[1])
            self.facts.events.append(("write", chain[1], tgt.lineno))
            if aug:
                self.facts.events.append(("read", chain[1], tgt.lineno))
        elif not isinstance(tgt, ast.Name):
            self._expr_children(base)

    def _expr(self, e: ast.AST) -> None:
        if isinstance(e, ast.Call):
            self._call(e)
            return
        if isinstance(e, ast.Attribute):
            chain = _attr_chain(e)
            if chain and len(chain) == 2 and chain[0] == "self":
                self.facts.events.append(("read", chain[1], e.lineno))
                return
            self._expr_children(e)
            return
        if isinstance(e, ast.Lambda):
            return
        self._expr_children(e)

    def _expr_children(self, e: ast.AST) -> None:
        for child in ast.iter_child_nodes(e):
            if isinstance(child, ast.expr):
                self._expr(child)
            elif isinstance(child, ast.comprehension):
                self._expr(child.iter)
                for cond in child.ifs:
                    self._expr(cond)

    def _call(self, e: ast.Call) -> None:
        func = e.func
        recv_text: Optional[str] = None
        recv_tag: Optional[str] = None
        if isinstance(func, ast.Attribute):
            name = func.attr
            chain = _attr_chain(func.value)
            recv_text = ".".join(chain) if chain else "<expr>"
            recv_tag = self._expr_tag(func.value) if chain else None
            # acquire()/release() on a mutex: held-set bookkeeping, and the
            # PD-L005 self-edge check for loops (e.g. _lock_all)
            lock = (
                self._lock_ref(func.value)
                if name in ("acquire", "release")
                else None
            )
            if lock is not None:
                if name == "acquire":
                    acq = AcqFact(
                        lock=lock,
                        line=e.lineno,
                        col=e.col_offset,
                        held=tuple(self.held + self.manual),
                        manual=True,
                        in_loop=self.loop_depth > 0,
                    )
                    self.facts.acquires.append(acq)
                    if self.loop_depth:
                        self.loop_acq[-1].append(acq)
                    if all(r.name != lock.name for r in self.manual):
                        self.manual.append(lock)
                else:
                    if self.loop_depth:
                        self.loop_rel[-1].add(lock.name)
                    self.manual = [r for r in self.manual if r.name != lock.name]
                for arg in e.args:
                    self._expr(arg)
                return
            # receiver subtree: count self-attr loads unless this call
            # mutates the container (then it is a write for PD-L004)
            if chain and len(chain) == 2 and chain[0] == "self":
                if name in _MUTATING_METHODS:
                    self.facts.attr_writes.add(chain[1])
                    self.facts.events.append(("write", chain[1], e.lineno))
                else:
                    self.facts.events.append(("read", chain[1], e.lineno))
            else:
                self._expr(func.value)
        elif isinstance(func, ast.Name):
            name = func.id
        else:
            self._expr(func)
            name = "<dynamic>"
        fact = CallFact(
            line=e.lineno,
            col=e.col_offset,
            func_name=name,
            recv_text=recv_text,
            recv_tag=recv_tag,
            held=tuple(self.held + self.manual),
            node=e,
            in_loop=self.loop_depth > 0,
        )
        self.facts.calls.append(fact)
        self.facts.events.append(("call", fact))
        for arg in e.args:
            self._expr(arg)
        for kw in e.keywords:
            self._expr(kw.value)


# a scanner-visible mirror of Project.class_index (set during build so
# cross-module attr tags resolve without threading the project everywhere)
_PROJECT_CLASS_INDEX: Dict[str, ClassModel] = {}


def build_project(paths: Sequence[Path]) -> Project:
    """Parse every ``.py`` under ``paths`` and build the full fact base."""
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    modules: List[ModuleModel] = []
    errors: List[str] = []
    for f in files:
        try:
            modules.append(ModuleModel(f, f.read_text(encoding="utf-8")))
        except (OSError, SyntaxError) as exc:
            errors.append(f"{f}: {exc}")
    # sweep A: class registry
    for mod in modules:
        for node in mod.tree.body:
            if isinstance(node, ast.ClassDef):
                mod.classes[node.name] = ClassModel(node.name, node, mod)
    project = Project(modules)
    project.errors = errors
    _PROJECT_CLASS_INDEX.clear()
    _PROJECT_CLASS_INDEX.update(project.class_index)
    class_names = set(project.class_index)
    # sweep B: attribute tags
    for mod in modules:
        _collect_class_attrs(mod, class_names)
    # sweep C: function facts (methods, module functions, nested closures)
    for mod in modules:
        pending: List[Tuple[ast.AST, Optional[str], str]] = []
        for node in mod.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                pending.append((node, None, ""))
            elif isinstance(node, ast.ClassDef):
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        pending.append((sub, node.name, node.name))
        while pending:
            node, cls, prefix = pending.pop(0)
            qual = f"{prefix}.{node.name}" if prefix else node.name
            facts = FunctionFacts(qual, node.name, cls, node, mod)
            scanner = _FnScanner(class_names, mod, cls, facts, pending)
            scanner.scan()
            mod.functions[qual] = facts
            if cls and prefix == cls:
                mod.classes[cls].methods[node.name] = facts
        # patch local_funcs placeholders with the built facts
        for facts in mod.functions.values():
            for lname in list(facts.local_funcs):
                child = mod.functions.get(f"{facts.qualname}.<locals>.{lname}")
                if child is not None:
                    facts.local_funcs[lname] = child
                else:
                    del facts.local_funcs[lname]
    # store-API classes
    for name, cls in project.class_index.items():
        if {"hset", "push", "pop_any"} <= set(cls.methods):
            project.store_classes.add(name)
    _mark_subscribers(project)
    _fixpoint_phases(project)
    return project


# ----------------------------------------------------- project-wide phases

#: CoordinationStore public ops
STORE_MUTATORS = {
    "set",
    "delete",
    "hset",
    "hdel",
    "hcas",
    "push",
    "pop",
    "pop_any",
    "qremove",
    "restore",
}
STORE_PUBLISHING = {"hset", "hcas", "push"}
STORE_READS = {"get", "keys", "hget", "hgetall", "hkeys", "qlen", "qpeek", "snapshot"}
STORE_BLOCKING = {"flush_events", "wait_field", "flush_wal", "close"}
#: store ops that never propagate a blocking taint to callers: they are
#: bounded (group-commit amortizes WAL flushes); the PD-L002 contract
#: tracks *unbounded* waits (sleeps, joins, transfers, barriers)
STORE_SAFE = STORE_MUTATORS | STORE_READS | {"subscribe", "unsubscribe", "fail_for"}
TRANSFER_BLOCKING = {
    "stage_in",
    "stage_in_bulk",
    "heal_replica",
    "replicate",
    "replicate_chunks",
    "ingest",
    "prefetch_inputs",
}


def is_store_recv(project: Project, fact: CallFact) -> bool:
    if fact.recv_tag in project.store_names:
        return True
    return fact.recv_text is not None and (
        fact.recv_text == "store"
        or fact.recv_text.endswith(".store")
        or fact.recv_text.endswith("._store")
    )


def leaf_blocking(project: Project, fact: CallFact) -> Optional[Tuple[str, bool]]:
    """(reason, idiom_exempt) when the call itself blocks, else None.

    ``idiom_exempt`` marks ``cond.wait()`` under ``with cond`` — correct
    usage at the site, but the enclosing function still blocks."""
    name, tag, recv = fact.func_name, fact.recv_tag, fact.recv_text
    if name == "sleep" and recv in (None, "time"):
        return ("time.sleep", False)
    if name == "sleep_sim":
        return ("ctx.sleep_sim (simulated wait)", False)
    if name == "open" and recv is None:
        return ("file open", False)
    if name == "with_retry" and recv is None:
        return ("with_retry backoff sleeps", False)
    if tag == "thread" and name == "join":
        return ("Thread.join", False)
    if tag == "event" and name == "wait":
        return ("Event.wait", False)
    if tag == "condition" and name in ("wait", "wait_for"):
        exempt = any(h.text == recv or h.name.endswith(recv or "") for h in fact.held)
        return ("Condition.wait", exempt)
    if tag == "queue" and name == "get":
        block = call_kwarg(fact.node, "block", 0)
        if block is not None and isinstance(block, ast.Constant) and not block.value:
            return None
        return ("queue.get", False)
    if tag == "semaphore" and name == "acquire":
        return ("Semaphore.acquire", False)
    if tag == "file" and name in ("write", "flush", "read", "readline"):
        return ("file I/O", False)
    if is_store_recv(project, fact):
        if name in ("pop", "pop_any"):
            timeout = call_kwarg(fact.node, "timeout", 1)
            if timeout is not None and not _is_literal_zero(timeout):
                return (f"store.{name} with a timeout", False)
            return None
        if name in STORE_BLOCKING:
            return (f"store.{name}", False)
    if name in TRANSFER_BLOCKING and (
        fact.recv_tag == "TransferService"
        or (recv is not None and recv.endswith("transfer_service"))
    ):
        return (f"transfer_service.{name} (striped transfer)", False)
    return None


def _mark_subscribers(project: Project) -> None:
    for fn in list(project.all_functions()):
        for fact in fn.calls:
            if fact.func_name != "subscribe" or not fact.node.args:
                continue
            if not (is_store_recv(project, fact) or fact.recv_text == "self"):
                continue
            cb = fact.node.args[0]
            target: Optional[FunctionFacts] = None
            chain = _attr_chain(cb)
            if chain and len(chain) == 2 and chain[0] == "self" and fn.cls:
                cls = fn.module.classes.get(fn.cls)
                if cls is not None:
                    target = cls.methods.get(chain[1])
            elif isinstance(cb, ast.Name):
                target = fn.local_funcs.get(cb.id) or fn.module.functions.get(cb.id)
            if target is not None:
                target.is_subscriber_cb = True


def _fixpoint_phases(project: Project) -> None:
    """Iterate blocking / publishes / exposed-reads / lock closures to a
    fixpoint over the resolvable call graph."""
    # derived attrs: written by subscriber callbacks, minus handoff
    # primitives (queues) and synchronization objects
    for mod in project.modules:
        for cls in mod.classes.values():
            derived: Set[str] = set()
            for m in cls.methods.values():
                if m.is_subscriber_cb:
                    derived |= m.attr_writes
            cls.derived_attrs = {
                a
                for a in derived
                if cls.attr_tags.get(a) not in (NONLOCK_TAGS | LOCK_TAGS)
            }
    fns = list(project.all_functions())
    for fn in fns:
        for fact in fn.calls:
            leaf = leaf_blocking(project, fact)
            if leaf and fn.blocking_reason is None:
                fn.blocking_reason = leaf[0]
            if (
                is_store_recv(project, fact)
                and fact.func_name in STORE_PUBLISHING
                and not fn.publishes
            ):
                fn.publishes = True
                fn.mutate_chain = f"store.{fact.func_name}"
        for acq in fn.acquires:
            fn.acq_closure.add(acq.lock.name)
    changed = True
    while changed:
        changed = False
        for fn in fns:
            for fact in fn.calls:
                safe_store = (
                    is_store_recv(project, fact) and fact.func_name in STORE_SAFE
                )
                callee = project.resolve_call(fact, fn)
                if callee is None:
                    continue
                if (
                    not safe_store
                    and callee.blocking_reason
                    and fn.blocking_reason is None
                ):
                    fn.blocking_reason = (
                        f"{callee.qualname}() → {callee.blocking_reason}"
                    )
                    changed = True
                if callee.publishes and not fn.publishes and not safe_store:
                    fn.publishes = True
                    fn.mutate_chain = f"{callee.qualname}() → {callee.mutate_chain}"
                    changed = True
                if not callee.acq_closure <= fn.acq_closure:
                    fn.acq_closure |= callee.acq_closure
                    changed = True
        # exposed derived reads (before any flush barrier, in call order)
        for fn in fns:
            exposed = _exposed_reads(project, fn)
            if exposed != fn.exposed_reads:
                fn.exposed_reads = exposed
                changed = True


def _is_flush_call(project: Project, fact: CallFact) -> bool:
    return fact.func_name == "flush_events" and (
        is_store_recv(project, fact) or fact.recv_text == "self"
    )


def _exposed_reads(project: Project, fn: FunctionFacts) -> Set[str]:
    derived: Set[str] = set()
    if fn.cls:
        cls = fn.module.classes.get(fn.cls)
        if cls is not None:
            derived = cls.derived_attrs
    out: Set[str] = set()
    for ev in fn.events:
        if ev[0] == "read" and ev[1] in derived:
            out.add(ev[1])
        elif ev[0] == "call":
            fact = ev[1]
            if _is_flush_call(project, fact):
                break
            callee = project.resolve_call(fact, fn)
            if callee is not None and callee is not fn:
                out |= callee.exposed_reads
    return out
