"""Fused RMSNorm (+ optional residual add) Pallas TPU kernel.

Two HBM-bound passes (norm stats + scale) fused into one row-blocked VMEM
pass; the optional residual add removes a third pass.  Rows are tiled
(block_rows × d_model) to fit VMEM; statistics in fp32.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (normed * (1.0 + w_ref[...].astype(jnp.float32))).astype(
        o_ref.dtype
    )


def _rmsnorm_residual_kernel(x_ref, r_ref, w_ref, o_ref, res_ref, *, eps: float):
    s = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    res_ref[...] = s.astype(res_ref.dtype)
    var = jnp.mean(jnp.square(s), axis=-1, keepdims=True)
    normed = s * jax.lax.rsqrt(var + eps)
    o_ref[...] = (normed * (1.0 + w_ref[...].astype(jnp.float32))).astype(
        o_ref.dtype
    )


def rmsnorm_fwd(
    x: jnp.ndarray,  # [R, D]
    w: jnp.ndarray,  # [D]
    *,
    eps: float = 1e-6,
    residual: Optional[jnp.ndarray] = None,
    block_rows: int = 256,
    interpret: bool = False,
):
    r, d = x.shape
    assert r % block_rows == 0, (r, block_rows)
    grid = (r // block_rows,)
    w2 = w.reshape(1, d)
    if residual is None:
        return pl.pallas_call(
            functools.partial(_rmsnorm_kernel, eps=eps),
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                pl.BlockSpec((1, d), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
            interpret=interpret,
        )(x, w2)
    return pl.pallas_call(
        functools.partial(_rmsnorm_residual_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, d), x.dtype),
            jax.ShapeDtypeStruct((r, d), x.dtype),
        ],
        interpret=interpret,
    )(x, residual, w2)
