"""Oracle: the model zoo's rms_norm is the reference."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rmsnorm_ref(
    x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6
) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))).astype(
        x.dtype
    )


def rmsnorm_residual_ref(
    x: jnp.ndarray, residual: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    s = (x.astype(jnp.float32) + residual.astype(jnp.float32)).astype(x.dtype)
    return rmsnorm_ref(s, w, eps), s
