"""Jit'd wrapper for the fused RMSNorm kernel."""

from __future__ import annotations

import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from .rmsnorm import rmsnorm_fwd


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jnp.ndarray,  # [..., D]
    w: jnp.ndarray,  # [D]
    *,
    eps: float = 1e-6,
    residual: Optional[jnp.ndarray] = None,
    block_rows: int = 256,
    interpret: Optional[bool] = None,
) -> Union[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    shape = x.shape
    d = shape[-1]
    r = 1
    for s in shape[:-1]:
        r *= s
    br = min(block_rows, r)
    while r % br:
        br //= 2
    br = max(br, 1)
    x2 = x.reshape(r, d)
    if residual is None:
        out = rmsnorm_fwd(
            x2, w, eps=eps, block_rows=br, interpret=interpret
        )
        return out.reshape(shape)
    r2 = residual.reshape(r, d)
    out, res = rmsnorm_fwd(
        x2, w, eps=eps, residual=r2, block_rows=br, interpret=interpret
    )
    return out.reshape(shape), res.reshape(shape)
