from . import ops, ref
from .ops import rmsnorm
from .rmsnorm import rmsnorm_fwd

__all__ = ["rmsnorm", "rmsnorm_fwd", "ops", "ref"]
