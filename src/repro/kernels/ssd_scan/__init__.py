from . import ops, ref
from .ops import ssd
from .ssd_scan import ssd_scan_fwd

__all__ = ["ssd", "ssd_scan_fwd", "ops", "ref"]
