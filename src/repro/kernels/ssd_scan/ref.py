"""Oracle: the pure-jnp chunked SSD from the model zoo is the reference."""

from repro.models.mamba2 import segsum, ssd_chunked  # noqa: F401

__all__ = ["ssd_chunked", "segsum"]
