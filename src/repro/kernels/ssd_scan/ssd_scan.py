"""Mamba2 SSD (state-space duality) Pallas TPU kernel.

The SSD computation per (batch, head) is: within a chunk of length Q, a
decay-masked quadratic form (MXU-friendly — this is the "duality" with
attention); across chunks, a linear state recurrence.

TPU mapping:
  * grid = (B, H, n_chunks) with the chunk dimension innermost; TPU Pallas
    executes the grid sequentially per core, so the running state [P, N]
    lives in VMEM scratch and is carried across chunk steps — the
    recurrence costs no HBM traffic at all (on GPU this is a separate
    inter-block scan kernel);
  * each chunk step loads x[Q,P], dA[Q], B[Q,N], C[Q,N] into VMEM, runs
    three MXU matmuls (C·Bᵀ, (L∘S)·X, B̃ᵀ·X) and one state update;
  * everything accumulates in fp32.

The wrapper in ops.py reshapes the model's [B, S, H, ...] layout into the
kernel's head-major chunked layout and pads N/P to lane multiples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,  # [1, 1, 1, Q, P]
    da_ref,  # [1, 1, 1, Q]
    b_ref,  # [1, 1, 1, Q, N]
    c_ref,  # [1, 1, 1, Q, N]
    y_ref,  # [1, 1, 1, Q, P]
    state_out_ref,  # [1, 1, P, N] — final state per (b, h)
    state_scr,  # VMEM [P, N] fp32
    *,
    chunk: int,
    num_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0, 0].astype(jnp.float32)  # [Q, P]
    da = da_ref[0, 0, 0].astype(jnp.float32)  # [Q]
    b = b_ref[0, 0, 0].astype(jnp.float32)  # [Q, N]
    c = c_ref[0, 0, 0].astype(jnp.float32)  # [Q, N]

    cum = jnp.cumsum(da)  # [Q]
    # decay matrix L[i,j] = exp(cum_i - cum_j) for i >= j else 0
    li = cum[:, None] - cum[None, :]
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    L = jnp.where(tri, jnp.exp(li), 0.0)
    # intra-chunk: y_diag = (C Bᵀ ∘ L) X
    scores = (
        jax.lax.dot_general(
            c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * L
    )  # [Q, Q]
    y = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, P]
    # carried-in state: y_off = (C state^T) ∘ exp(cum)
    state = state_scr[...]  # [P, N]
    y_off = jax.lax.dot_general(
        c, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, P]
    y = y + y_off * jnp.exp(cum)[:, None]
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    # state update: state' = state * exp(cum_last) + Σ_q exp(cum_last-cum_q) x_q ⊗ b_q
    decay_states = jnp.exp(cum[-1] - cum)  # [Q]
    xw = x * decay_states[:, None]  # [Q, P]
    chunk_state = jax.lax.dot_general(
        xw, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [P, N]
    new_state = state * jnp.exp(cum[-1]) + chunk_state
    state_scr[...] = new_state

    @pl.when(ic == num_chunks - 1)
    def _finish():
        state_out_ref[0, 0] = new_state


def ssd_scan_fwd(
    x: jnp.ndarray,  # [B, H, C, Q, P] (dt-weighted inputs)
    da: jnp.ndarray,  # [B, H, C, Q]   (dt * A)
    b: jnp.ndarray,  # [B, H, C, Q, N]
    c: jnp.ndarray,  # [B, H, C, Q, N]
    *,
    interpret: bool = False,
):
    bsz, h, nc, q, p = x.shape
    n = b.shape[-1]
    grid = (bsz, h, nc)
    kernel = functools.partial(_ssd_kernel, chunk=q, num_chunks=nc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda i, j, k_: (i, j, k_, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda i, j, k_: (i, j, k_, 0)),
            pl.BlockSpec((1, 1, 1, q, n), lambda i, j, k_: (i, j, k_, 0, 0)),
            pl.BlockSpec((1, 1, 1, q, n), lambda i, j, k_: (i, j, k_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda i, j, k_: (i, j, k_, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda i, j, k_: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, nc, q, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, da, b, c)
