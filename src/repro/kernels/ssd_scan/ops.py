"""Jit'd wrapper: model layout [B, S, H, ...] → kernel chunk layout."""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .ssd_scan import ssd_scan_fwd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: jnp.ndarray,  # [B, S, H, P] (dt-weighted)
    da: jnp.ndarray,  # [B, S, H]
    b: jnp.ndarray,  # [B, S, H, N]
    c: jnp.ndarray,  # [B, S, H, N]
    *,
    chunk: int = 256,
    initial_state: Optional[jnp.ndarray] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if initial_state is not None:
        # kernel assumes zero init; fold a nonzero initial state in by
        # treating it as a virtual chunk via the reference path
        from .ref import ssd_chunked

        return ssd_chunked(x, da, b, c, chunk, initial_state=initial_state)
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    def to_kernel(t, feat):
        # [B, S, H, F] -> [B, H, C, Q, F]
        return t.reshape(bsz, nc, q, h, feat).transpose(0, 3, 1, 2, 4)

    xk = to_kernel(x, p)
    dak = da.reshape(bsz, nc, q, h).transpose(0, 3, 1, 2)
    bk = to_kernel(b, n)
    ck = to_kernel(c, n)
    y, final_state = ssd_scan_fwd(xk, dak, bk, ck, interpret=interpret)
    y = y.transpose(0, 2, 3, 1, 4).reshape(bsz, s, h, p)
    return y, final_state
