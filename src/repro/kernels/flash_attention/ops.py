"""Jit'd public wrapper: layout adaptation, padding, backend dispatch.

Model code passes [B, S, H, D] activations; the kernel wants [B, H, S, D]
with D padded to a 128 multiple and S padded to block multiples (masked via
seq_q/seq_k).  On CPU the kernel body runs in interpret mode (correctness
validation); on TPU it compiles to Mosaic.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_fwd


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # [B, Sq, Hq, D] (model layout)
    k: jnp.ndarray,  # [B, Sk, Hkv, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, sq, hq, d = q.shape
    sk = k.shape[1]
    sm_scale = d**-0.5
    qt = _pad_to(_pad_to(q.transpose(0, 2, 1, 3), 3, 128), 2, block_q)
    kt = _pad_to(_pad_to(k.transpose(0, 2, 1, 3), 3, 128), 2, block_k)
    vt = _pad_to(_pad_to(v.transpose(0, 2, 1, 3), 3, 128), 2, block_k)
    out = flash_attention_fwd(
        qt,
        kt,
        vt,
        causal=causal,
        window=window,
        seq_q=sq,
        seq_k=sk,
        sm_scale=sm_scale,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
    )
    return out[:, :, :sq, :d].transpose(0, 2, 1, 3)
