"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0**30


def attention_ref(
    q: jnp.ndarray,  # [B, Hq, Sq, D]
    k: jnp.ndarray,  # [B, Hkv, Sk, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    scale = sm_scale if sm_scale is not None else d**-0.5
    qf = q.astype(jnp.float32).reshape(b, hkv, g, sq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)
