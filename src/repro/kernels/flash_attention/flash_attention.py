"""Flash-attention Pallas TPU kernel (prefill): blocked online-softmax GQA
attention with causal and sliding-window masking.

TPU adaptation notes (vs. the CUDA flash-attention formulation):
  * blocks are sized for VMEM and MXU alignment — (block_q × head_dim) and
    (block_k × head_dim) tiles with head_dim padded to a multiple of 128 by
    the wrapper, block sizes multiples of the 8×128 VPU lane layout;
  * the grid is (batch, q_heads, q_blocks, k_blocks) with the K dimension
    innermost: TPU Pallas iterates the grid sequentially per core, so the
    online-softmax running state (m, l, acc) lives in VMEM scratch that
    persists across the k_block loop — no atomics, no shared-memory
    reductions as on GPU;
  * GQA is expressed through the BlockSpec index_map (q head h reads kv head
    h // group), so no materialized head replication.

Numerics: fp32 running max/denominator/accumulator regardless of input
dtype; output cast back to the query dtype.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30


def _attn_kernel(
    q_ref,  # [1, 1, bq, D]
    k_ref,  # [1, 1, bk, D]
    v_ref,  # [1, 1, bk, D]
    o_ref,  # [1, 1, bq, D]
    m_scr,  # VMEM [bq, 1] fp32
    l_scr,  # VMEM [bq, 1] fp32
    acc_scr,  # VMEM [bq, D] fp32
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    block_q: int,
    block_k: int,
    seq_q: int,
    seq_k: int,
    num_k_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)  # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [bq, bk]

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = (q_pos < seq_q) & (k_pos < seq_k)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)  # [bq, bk]
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)  # fully-masked rows stay 0
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,  # [B, Hq, Sq, D]  (D multiple of 128, S multiples of blocks)
    k: jnp.ndarray,  # [B, Hkv, Sk, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    seq_q: Optional[int] = None,
    seq_k: Optional[int] = None,
    sm_scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    seq_q = seq_q if seq_q is not None else sq
    seq_k = seq_k if seq_k is not None else sk
    nq, nk = sq // block_q, sk // block_k
    grid = (b, hq, nq, nk)

    kernel = functools.partial(
        _attn_kernel,
        scale=sm_scale if sm_scale is not None else d**-0.5,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        seq_q=seq_q,
        seq_k=seq_k,
        num_k_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda b_, h, iq, ik, g_=g: (b_, h // g_, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda b_, h, iq, ik, g_=g: (b_, h // g_, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
