"""Pallas TPU kernels for the workload's compute hot-spots.

Each kernel package ships three layers:
  * ``<name>.py``   — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling,
  * ``ops.py``      — jit'd public wrapper (layout adaptation, padding,
                      interpret-mode dispatch on CPU),
  * ``ref.py``      — pure-jnp oracle; tests sweep shapes/dtypes and assert
                      allclose.

The paper itself (Pilot-Data) has no kernel-level contribution — these
kernels make the *workload being scheduled* production-grade (DESIGN.md §2).
"""

from .decode_attention import decode_attention
from .flash_attention import flash_attention
from .rmsnorm import rmsnorm
from .ssd_scan import ssd

__all__ = ["decode_attention", "flash_attention", "rmsnorm", "ssd"]
