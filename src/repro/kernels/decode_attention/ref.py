"""Pure-jnp oracle for decode attention."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0**30


def decode_attention_ref(
    q: jnp.ndarray,  # [B, Hq, D]
    k: jnp.ndarray,  # [B, Hkv, Sk, D]
    v: jnp.ndarray,
    positions: jnp.ndarray,  # [B] int32
    *,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
) -> jnp.ndarray:
    b, hq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    scale = sm_scale if sm_scale is not None else d**-0.5
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, k.astype(jnp.float32)) * scale
    k_pos = jnp.arange(sk)[None, :]
    mask = k_pos <= positions[:, None]
    if window is not None:
        mask &= k_pos > positions[:, None] - window
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, d).astype(q.dtype)
