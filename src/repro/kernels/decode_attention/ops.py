"""Jit'd wrapper for decode attention (model layout adaptation + padding)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_fwd


@functools.partial(
    jax.jit, static_argnames=("window", "block_k", "interpret")
)
def decode_attention(
    q: jnp.ndarray,  # [B, 1, Hq, D] (model layout, single step)
    k_cache: jnp.ndarray,  # [B, Sk, Hkv, D]
    v_cache: jnp.ndarray,
    positions: jnp.ndarray,  # [B] int32 current positions
    *,
    window: Optional[int] = None,
    block_k: int = 256,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, one, hq, d = q.shape
    assert one == 1
    sk = k_cache.shape[1]
    sm_scale = d**-0.5
    dpad = (-d) % 128
    spad = (-sk) % block_k

    def pad(x, dp, sp, s_axis):
        widths = [(0, 0)] * x.ndim
        widths[-1] = (0, dp)
        widths[s_axis] = widths[s_axis][0], widths[s_axis][1] + 0
        if sp:
            w = list(widths)
            w[s_axis] = (0, sp)
            w[-1] = (0, dp)
            return jnp.pad(x, w)
        return jnp.pad(x, widths) if dp else x

    qt = pad(q[:, 0].astype(q.dtype), dpad, 0, 1)  # [B, Hq, D+]
    kt = pad(k_cache.transpose(0, 2, 1, 3), dpad, spad, 2)  # [B,Hkv,Sk+,D+]
    vt = pad(v_cache.transpose(0, 2, 1, 3), dpad, spad, 2)
    out = decode_attention_fwd(
        qt,
        kt,
        vt,
        positions,
        window=window,
        sm_scale=sm_scale,
        block_k=block_k,
        interpret=interpret,
    )
    return out[:, None, :, :d]
