"""Single-token decode attention Pallas kernel.

Decode attention is memory-bound: one query vector per (batch, head) streams
the whole KV cache from HBM.  The kernel tiles the KV sequence into VMEM
blocks (grid innermost dim) and keeps the online-softmax state in VMEM
scratch, so each KV byte is read exactly once — the roofline-optimal
schedule for this op.

Masking supports the decode cases the model zoo needs:
  * validity: only cache positions ≤ current position contribute,
  * sliding window: positions < pos-window+1 are masked (SWA decode).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30


def _decode_kernel(
    pos_ref,  # SMEM [B] int32 — current position per batch row (prefetched)
    q_ref,  # [1, 1, D]
    k_ref,  # [1, bk, D]
    v_ref,  # [1, bk, D]
    o_ref,  # [1, 1, D]
    m_scr,  # VMEM [1, 1]
    l_scr,  # VMEM [1, 1]
    acc_scr,  # VMEM [1, D]
    *,
    scale: float,
    window: Optional[int],
    block_k: int,
    num_k_blocks: int,
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[pl.program_id(0)]
    q = q_ref[0].astype(jnp.float32)  # [1, D]
    k = k_ref[0].astype(jnp.float32)  # [bk, D]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [1, bk]
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    mask = k_pos <= pos
    if window is not None:
        mask &= k_pos > pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


def decode_attention_fwd(
    q: jnp.ndarray,  # [B, Hq, D]
    k: jnp.ndarray,  # [B, Hkv, Sk, D]
    v: jnp.ndarray,
    positions: jnp.ndarray,  # [B] int32 current positions
    *,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    assert sk % block_k == 0, (sk, block_k)
    nk = sk // block_k
    grid = (b, hq, nk)
    kernel = functools.partial(
        _decode_kernel,
        scale=sm_scale if sm_scale is not None else d**-0.5,
        window=window,
        block_k=block_k,
        num_k_blocks=nk,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b_, h, ik, pos: (b_, h, 0)),
            pl.BlockSpec(
                (1, block_k, d), lambda b_, h, ik, pos, g_=g: (b_ * hkv + h // g_, ik, 0)
            ),
            pl.BlockSpec(
                (1, block_k, d), lambda b_, h, ik, pos, g_=g: (b_ * hkv + h // g_, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b_, h, ik, pos: (b_, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    kf = k.reshape(b * hkv, sk, d)
    vf = v.reshape(b * hkv, sk, d)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, d), q.dtype),
        interpret=interpret,
    )(positions.astype(jnp.int32), q, kf, vf)
