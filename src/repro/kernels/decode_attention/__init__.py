from . import ops, ref
from .decode_attention import decode_attention_fwd
from .ops import decode_attention

__all__ = ["decode_attention", "decode_attention_fwd", "ops", "ref"]
