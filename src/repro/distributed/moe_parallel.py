"""Expert-parallel MoE bridge: wraps the shard_map EP path around the local
MoE body when a distribution context is active.

Train/prefill (S divisible by the model axis) → explicit shard_map with
all-to-all over the model axis (collective bytes visible in the dry-run
HLO).  Decode (S == 1) or no mesh → the local gather/scatter path; GSPMD
partitions it automatically (the tensors are tiny at decode).
"""

from __future__ import annotations


import jax
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..models.moe import moe_mlp_ep, moe_mlp_local
from .compat import shard_map
from .context import current


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def moe_maybe_parallel(moe_params, x, cfg: ModelConfig):
    ctx = current()
    b, s, d = x.shape
    if ctx is None or not ctx.ep or not _div(s, ctx.model_size) or s == 1:
        return moe_mlp_local(moe_params, x, cfg)
    m = ctx.model_axis
    batch = ctx.batch_axes if _div(b, ctx.batch_size_total) else None
    # the aux pmean may only reduce over axes the value actually varies on:
    # tokens vary over the model axis (seq sharding) always, and over the
    # DP axes only when the batch dim is sharded there.
    reduce_axes = (tuple(ctx.batch_axes) + (m,)) if batch is not None else (m,)

    def pspec(path, leaf):
        names = [str(k.key) for k in path if isinstance(k, jax.tree_util.DictKey)]
        if "router" in names:
            return P(*([None] * len(leaf.shape)))
        return P(m, *([None] * (len(leaf.shape) - 1)))

    param_specs = jax.tree_util.tree_map_with_path(pspec, moe_params)
    x_spec = P(batch, m, None)

    def body(p, xl):
        return moe_mlp_ep(
            p, xl, cfg, model_axis=m, reduce_axes=reduce_axes
        )

    return shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(param_specs, x_spec),
        out_specs=(x_spec, P()),
    )(moe_params, x)
