"""Pipeline parallelism over the pod axis (GPipe-style, shard_map).

At 2+ pods, the DCN between pods is the slow link — instead of extending
data parallelism across it (all-reducing full gradients over DCN every
step), the pod axis can carry PIPELINE stages: each pod owns a contiguous
slice of layers, activations flow pod→pod via ``collective_permute``
(activation tensors are microbatch-sized — orders of magnitude smaller
than gradients), and microbatches keep every pod busy outside the fill /
drain bubbles.

Mechanics (classic shard_map GPipe schedule):
  * stage parameters are stacked on a leading ``n_stages`` dim and sharded
    over the pipeline axis — inside shard_map each device holds its own
    stage's slice;
  * the loop runs ``n_micro + n_stages − 1`` ticks; on each tick a device
    runs its stage on the activation it holds, then the ring rotates
    (``ppermute`` stage i → i+1);
  * stage 0 injects a fresh microbatch each tick (while any remain); the
    last stage's outputs are collected on the final ticks;
  * bubble fraction = (n_stages − 1) / (n_micro + n_stages − 1).

This is the substrate; wiring a full arch through it is a config choice
(the default multi-pod layout keeps the pod axis in DP — see DESIGN.md §4).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .compat import shard_map


def pipeline_apply(
    stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    stage_params: Any,  # leaves stacked [n_stages, ...]
    x: jnp.ndarray,  # [n_micro, mb, ...] microbatched input
    mesh: jax.sharding.Mesh,
    axis: str = "pod",
) -> jnp.ndarray:
    """Run ``x``'s microbatches through the stage pipeline; returns
    [n_micro, mb, ...] outputs (as produced by the LAST stage)."""
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    assert n_micro >= n_stages, "need ≥ n_stages microbatches to fill"

    def body(params, xs):
        # params: this device's stage slice — shard_map keeps the sharded
        # leading dim at size 1; strip it
        params = jax.tree.map(lambda p: p[0], params)
        # xs: full microbatch stream, replicated
        idx = jax.lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1
        mb_shape = xs.shape[1:]
        state = jnp.zeros(mb_shape, xs.dtype)  # activation held by this stage
        outs = jnp.zeros((n_micro, *mb_shape), xs.dtype)

        def tick(carry, t):
            state, outs = carry
            # stage 0 injects microbatch t (while any remain)
            inject = jnp.where(t < n_micro, t, n_micro - 1)
            state = jnp.where(idx == 0, xs[inject], state)
            # every stage applies its slice
            y = stage_fn(params, state)
            # last stage emits microbatch (t - (n_stages-1)) when valid
            emit_t = t - (n_stages - 1)
            valid = (emit_t >= 0) & (idx == n_stages - 1)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_slice_in_dim(
                    o, y[None], jnp.maximum(emit_t, 0), axis=0
                ),
                lambda o: o,
                outs,
            )
            # rotate the ring: stage i → i+1 (last wraps to 0, ignored)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            state = jax.lax.ppermute(y, axis, perm)
            return (state, outs), None

        (state, outs), _ = jax.lax.scan(
            tick, (state, outs), jnp.arange(n_ticks)
        )
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.psum(
            jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs)), axis
        )
        return outs

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    in_x = P()  # microbatch stream replicated across the pipeline axis
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(pspec, in_x),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
