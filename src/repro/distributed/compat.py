"""JAX version-compatibility shims.

The codebase targets the modern top-level APIs (``jax.shard_map``,
``jax.make_mesh`` with ``axis_types``); older 0.4.x releases ship the same
functionality under ``jax.experimental.shard_map`` / without ``AxisType``.
Everything mesh- or shard_map-shaped goes through this module so the rest
of the tree stays version-agnostic.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax


def make_mesh(shape: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    shape = tuple(shape)
    axis_names = tuple(axis_names)
    kw = {}
    if hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axis_names)
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axis_names, **kw)
    import math

    import numpy as np

    devices = np.array(jax.devices()[: math.prod(shape)]).reshape(shape)
    return jax.sharding.Mesh(devices, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: Optional[bool] = None):
    """``jax.shard_map``, falling back to the experimental module.

    ``check_vma`` maps onto the old API's ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
    )
