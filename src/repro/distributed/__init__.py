from .context import DistContext, constrain, current, distribution
from .sharding_rules import batch_specs, cache_specs, opt_specs, param_specs

__all__ = [
    "DistContext", "constrain", "current", "distribution",
    "batch_specs", "cache_specs", "opt_specs", "param_specs",
]
