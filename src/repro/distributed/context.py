"""Distribution context: how model code learns about the mesh.

Model code stays mesh-agnostic; launchers activate a :class:`DistContext`
(mesh + axis roles) around tracing.  Inside model code:

  * ``constrain(x, "residual")`` — applies a named activation sharding
    constraint if a context is active (no-op otherwise, so CPU smoke tests
    and single-device runs are untouched);
  * ``current()`` — lets the MoE layer pick the expert-parallel shard_map
    path when a mesh with a model axis is active.

The context is a *trace-time* construct (contextvar) — nothing here touches
devices.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: jax.sharding.Mesh
    batch_axes: Tuple[str, ...]  # e.g. ("data",) or ("pod", "data")
    model_axis: str = "model"
    #: use the explicit expert-parallel shard_map path for MoE layers
    ep: bool = True
    #: shard the residual stream's sequence dim over the model axis (SP)
    sequence_parallel: bool = True

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def batch_size_total(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return tuple(self.mesh.axis_names)

    def sharding(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))


_CTX: contextvars.ContextVar[Optional[DistContext]] = contextvars.ContextVar(
    "repro_dist_ctx", default=None
)


def current() -> Optional[DistContext]:
    return _CTX.get()


@contextlib.contextmanager
def distribution(ctx: Optional[DistContext]):
    token = _CTX.set(ctx)
    try:
        yield ctx
    finally:
        _CTX.reset(token)


def _divides(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def constrain(x, name: str):
    """Apply a named activation-sharding constraint (no-op without ctx)."""
    ctx = current()
    if ctx is None:
        return x
    spec = _activation_spec(name, x.shape, ctx)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.sharding(*spec))


def _activation_spec(name: str, shape, ctx: DistContext):
    bt = ctx.batch_size_total
    ms = ctx.model_size
    batch = ctx.batch_axes if _divides(shape[0], bt) else None
    if name == "residual":
        # [B, S, d]: batch over DP axes; seq over model (SP) when it divides
        seq = (
            ctx.model_axis
            if ctx.sequence_parallel and len(shape) >= 2 and _divides(shape[1], ms)
            else None
        )
        return (batch, seq, None)
    if name == "logits":
        # [B, S, V]: vocab over model
        v = ctx.model_axis if _divides(shape[-1], ms) else None
        return (batch,) + (None,) * (len(shape) - 2) + (v,)
    if name == "batch":
        return (batch,) + (None,) * (len(shape) - 1)
    return None
