"""Parameter / optimizer / batch / cache sharding rules.

The layout (per DESIGN.md §4):
  * TP over ``model``: Megatron column/row split of attention + MLP,
    vocab-sharded embeddings, head-sharded Mamba projections, EP for MoE
    experts;
  * DP over ``data`` (and ``pod``): batch dims; ZeRO-1 — optimizer moments
    and fp32 masters additionally sharded over the DP axes;
  * SP: residual-stream sequence dim over ``model`` between blocks (applied
    via ``distributed.context.constrain``);
  * anything that does not divide evenly is replicated (never errors —
    whisper's 20 heads on a 16-way axis simply stay unsharded and SP
    carries the parallelism).

Rules are *path-based* over pytrees of ShapeDtypeStructs, so they apply
identically to live arrays and to dry-run eval_shape trees.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .context import DistContext

#: tree prefixes that stack per-layer params with one leading dim
_STACKED_KEYS = ("groups", "encoder", "decoder")


def _names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            out.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):  # pragma: no cover
            out.append(k.name)
    return tuple(out)


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _dp(axes) -> Any:
    """PartitionSpec element for the DP axes: a single axis stays a bare
    name (P('data', …), not P(('data',), …) — the tuple form denotes
    multi-axis sharding and confuses spec comparisons downstream)."""
    axes = tuple(axes)
    return axes[0] if len(axes) == 1 else axes


def _param_rule(
    names: Tuple[str, ...], shape: Tuple[int, ...], cfg: ModelConfig, ms: int
) -> Tuple[Optional[Any], ...]:
    """Spec for the UNSTACKED shape; returns a tuple of P elements."""
    m = "model"
    last = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    gparent = names[-3] if len(names) >= 3 else ""

    if parent == "embed" or (last in ("table", "lm_head")):
        if last == "table":
            return (m, None) if _div(shape[0], ms) else (None, None)
        if last == "lm_head":
            return (None, m) if _div(shape[1], ms) else (None, None)

    if gparent in ("attn", "self_attn", "cross_attn") and last == "w":
        if parent == "q":
            ok = _div(cfg.n_heads, ms)
            return (None, m) if ok else (None, None)
        if parent in ("k", "v"):
            ok = _div(cfg.n_kv_heads, ms)
            return (None, m) if ok else (None, None)
        if parent == "o":
            ok = _div(cfg.n_heads, ms)
            return (m, None) if ok else (None, None)

    if gparent == "mlp" and last == "w":
        if parent in ("gate", "up"):
            return (None, m) if _div(shape[1], ms) else (None, None)
        if parent == "down":
            return (m, None) if _div(shape[0], ms) else (None, None)

    if parent == "moe" and last in ("gate", "up", "down"):
        # [E_pad, d_in, d_out] — expert parallelism (E_pad divides by design)
        return (m, None, None) if _div(shape[0], ms) else (None, None, None)
    if gparent == "moe" and parent == "router":
        return tuple(None for _ in shape)

    if gparent == "mamba" and last == "w":
        if parent in ("z_proj", "x_proj"):
            return (None, m) if _div(shape[1], ms) else (None, None)
        if parent == "dt_proj":
            return (None, m) if _div(shape[1], ms) else (None, None)
        if parent == "out_proj":
            return (m, None) if _div(shape[0], ms) else (None, None)
        if parent == "bc_proj":
            return (None, None)
    if parent == "mamba":
        if last == "conv_x_w":
            return (None, m) if _div(shape[1], ms) else (None, None)
        if last == "conv_x_b":
            return (m,) if _div(shape[0], ms) else (None,)
        if last in ("conv_bc_w", "conv_bc_b"):
            return tuple(None for _ in shape)
        if last in ("A_log", "D", "dt_bias"):
            return (m,) if _div(shape[0], ms) else (None,)
    if parent == "gate_norm" and last == "scale":
        return (m,) if _div(shape[0], ms) else (None,)

    # norms and anything unmatched: replicated
    return tuple(None for _ in shape)


def param_specs(params_shapes: Any, cfg: ModelConfig, ctx: DistContext) -> Any:
    ms = ctx.model_size

    def rule(path, leaf):
        names = _names(path)
        nlead = 1 if any(n in _STACKED_KEYS for n in names) else 0
        base = _param_rule(names, tuple(leaf.shape[nlead:]), cfg, ms)
        return P(*((None,) * nlead + tuple(base)))

    return jax.tree_util.tree_map_with_path(rule, params_shapes)


def opt_specs(
    opt_shapes: Any, p_specs: Any, cfg: ModelConfig, ctx: DistContext
) -> Any:
    """ZeRO-1: moments/masters get the param spec plus DP sharding on the
    first still-unsharded divisible dim."""
    bt = ctx.batch_size_total
    dp = ctx.batch_axes if len(ctx.batch_axes) > 1 else ctx.batch_axes[0]

    def zero(spec: P, leaf) -> P:
        elems = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, (e, dim) in enumerate(zip(elems, leaf.shape)):
            if e is None and _div(dim, bt):
                elems[i] = dp
                break
        return P(*elems)

    out = {"step": P()}
    for key in ("m", "v", "master"):
        if key in opt_shapes:
            out[key] = jax.tree.map(zero, p_specs, opt_shapes[key])
    return out


def batch_specs(
    spec_dict: Dict[str, Tuple[Tuple[int, ...], Any]], ctx: DistContext
) -> Dict[str, P]:
    bt = ctx.batch_size_total
    out = {}
    for name, (shape, _) in spec_dict.items():
        batch = _dp(ctx.batch_axes) if _div(shape[0], bt) else None
        out[name] = P(batch, *([None] * (len(shape) - 1)))
    return out


def cache_specs(cache_shapes: Any, cfg: ModelConfig, ctx: DistContext) -> Any:
    """KV/SSM cache sharding for decode.

    KV: [(L,)? B, S, Hkv, D] — batch over DP; the S dim over model (and
    over DP too when the batch doesn't divide, e.g. long_500k's B=1).
    SSM state: [(L,)? B, H, P, N] — batch over DP, heads over model.
    """
    bt = ctx.batch_size_total
    ms = ctx.model_size
    m = ctx.model_axis

    def rule(path, leaf):
        names = _names(path)
        last = names[-1]
        shape = leaf.shape
        if last in ("k_scale", "v_scale"):
            # [(L,)? B, S, Hkv] — shard like the cache minus the head dim
            lead = (None,) * (len(shape) - 3)
            b_dim, s_dim = shape[-3], shape[-2]
            batch = _dp(ctx.batch_axes) if _div(b_dim, bt) else None
            if batch is None and _div(s_dim, bt * ms):
                seq = tuple(ctx.batch_axes) + (m,)
            elif _div(s_dim, ms):
                seq = m
            else:
                seq = None
            return P(*lead, batch, seq, None)
        if last in ("k", "v"):
            lead = (None,) * (len(shape) - 4)
            b_dim, s_dim = shape[-4], shape[-3]
            batch = _dp(ctx.batch_axes) if _div(b_dim, bt) else None
            if batch is None and _div(s_dim, bt * ms):
                seq = tuple(ctx.batch_axes) + (m,)
            elif _div(s_dim, ms):
                seq = m
            else:
                seq = None
            return P(*lead, batch, seq, None, None)
        if last == "ssm":
            lead = (None,) * (len(shape) - 4)
            batch = _dp(ctx.batch_axes) if _div(shape[-4], bt) else None
            heads = m if _div(shape[-3], ms) else None
            return P(*lead, batch, heads, None, None)
        if last in ("conv_x", "conv_bc"):
            lead = (None,) * (len(shape) - 3)
            batch = _dp(ctx.batch_axes) if _div(shape[-3], bt) else None
            ch = m if _div(shape[-1], ms) else None
            return P(*lead, batch, None, ch)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)
