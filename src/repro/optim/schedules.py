"""Learning-rate schedules (pure functions of the step)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(
    step: jnp.ndarray,
    peak_lr: float,
    warmup_steps: int,
    total_steps: int,
    final_frac: float = 0.1,
) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = peak_lr * step / jnp.maximum(1.0, warmup_steps)
    progress = jnp.clip(
        (step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps),
        0.0,
        1.0,
    )
    cos = peak_lr * (
        final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
    )
    return jnp.where(step < warmup_steps, warm, cos)


def constant(step: jnp.ndarray, lr: float) -> jnp.ndarray:
    return jnp.full_like(step, lr, dtype=jnp.float32)


def linear_decay(
    step: jnp.ndarray, peak_lr: float, warmup_steps: int, total_steps: int
) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = peak_lr * step / jnp.maximum(1.0, warmup_steps)
    decay = peak_lr * jnp.clip(
        (total_steps - step) / jnp.maximum(1.0, total_steps - warmup_steps),
        0.0,
        1.0,
    )
    return jnp.where(step < warmup_steps, warm, decay)
