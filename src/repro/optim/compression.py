"""Gradient compression for slow (cross-pod / DCN) links.

Int8 uniform quantization with error feedback: each participant quantizes
its local gradient shard to int8 with a per-tensor scale, the all-reduce
runs on int32 accumulators (4× less DCN traffic than fp32, 2× less than
bf16 at equal participant count), and the quantization residual is carried
into the next step (error feedback keeps the scheme unbiased over time).

These helpers run inside ``shard_map`` bodies (the compressed collective is
explicit — the whole point is controlling bytes on the wire).  The trainer
enables them per-axis: ICI (intra-pod) gradients reduce in bf16/fp32, only
the "pod" axis pays the quantize/dequantize.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization; returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(
    x: jnp.ndarray,
    axis_name: str,
    error: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantized all-reduce over ``axis_name`` with error feedback.

    Returns (mean-reduced fp32 tensor, new error-feedback residual).
    Must be called inside shard_map with ``axis_name`` bound.
    """
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    q, scale = quantize_int8(xf)
    new_error = xf - dequantize_int8(q, scale)
    # int32 accumulate avoids overflow up to ~16M participants
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    # scales differ per participant: reduce them too (sum of per-shard
    # dequantized tensors = sum_i q_i * s_i; with per-tensor scales we
    # approximate with the max scale — error feedback absorbs the residual)
    scale_max = jax.lax.pmax(scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    out = total.astype(jnp.float32) * scale_max / n
    return out, new_error


def compress_tree_psum(
    grads: Any, axis_name: str, errors: Optional[Any] = None
) -> Tuple[Any, Any]:
    """Tree-mapped :func:`compressed_psum`."""
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = (
        treedef.flatten_up_to(errors)
        if errors is not None
        else [None] * len(leaves)
    )
    outs, new_errs = [], []
    for g, e in zip(leaves, err_leaves):
        o, ne = compressed_psum(g, axis_name, e)
        outs.append(o)
        new_errs.append(ne)
    return treedef.unflatten(outs), treedef.unflatten(new_errs)
