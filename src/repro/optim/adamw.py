"""AdamW from scratch (no optax), mixed-precision aware.

Design for scale:
  * bf16 model params + fp32 master copies and fp32 (m, v) moments held in
    the optimizer state;
  * the optimizer state is what gets ZeRO-sharded over the data axis (see
    ``repro.distributed.sharding_rules``): each data shard owns 1/DP of the
    master/m/v, updates it, and the bf16 params are re-formed from the
    masters (GSPMD renders this as reduce-scatter(grads) → local update →
    all-gather(params) — the ZeRO-1 schedule);
  * everything is a pure function over pytrees: ``init`` is
    eval_shape-safe, so dry-runs get the full optimizer memory picture with
    zero allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    #: keep fp32 master copies when params are lower precision
    mixed_precision: bool = True


def init_adamw(params: Any, cfg: AdamWConfig = AdamWConfig()) -> Dict:
    def zeros_f32(p):
        return jnp.zeros(p.shape, dtype=jnp.float32)

    state = {
        "step": jnp.zeros((), dtype=jnp.int32),
        "m": jax.tree.map(zeros_f32, params),
        "v": jax.tree.map(zeros_f32, params),
    }
    if cfg.mixed_precision:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params
        )
    return state


def adamw_update(
    grads: Any,
    state: Dict,
    params: Any,
    lr: jnp.ndarray,
    cfg: AdamWConfig = AdamWConfig(),
) -> Tuple[Any, Dict]:
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    masters = state.get("master", params)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        new_master = master.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps)
            + cfg.weight_decay * master.astype(jnp.float32)
        )
        return m, v, new_master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_ma = treedef.flatten_up_to(masters)
    out = [upd(g, m, v, ma) for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(
        lambda ma, p: ma.astype(p.dtype), new_master, params
    )
    new_state = {"step": step, "m": new_m, "v": new_v}
    if "master" in state:
        new_state["master"] = new_master
    return new_params, new_state


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm
