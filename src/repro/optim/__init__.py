from .adamw import (
    AdamWConfig,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_adamw,
)
from .compression import (
    compress_tree_psum,
    compressed_psum,
    dequantize_int8,
    quantize_int8,
)
from .schedules import constant, linear_decay, warmup_cosine

__all__ = [
    "AdamWConfig",
    "adamw_update",
    "clip_by_global_norm",
    "global_norm",
    "init_adamw",
    "compress_tree_psum",
    "compressed_psum",
    "dequantize_int8",
    "quantize_int8",
    "constant",
    "linear_decay",
    "warmup_cosine",
]
