"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax
import; tests see the real single device).
"""

from __future__ import annotations

from typing import Tuple


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 (512 chips, 2 pods)."""
    from ..distributed.compat import make_mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def mesh_batch_axes(mesh) -> Tuple[str, ...]:
    """DP axes for a production mesh ('pod' participates in DP)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for unit tests (requires >= data*model fake devices)."""
    from ..distributed.compat import make_mesh

    return make_mesh((data, model), ("data", "model"))
