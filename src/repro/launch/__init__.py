"""Launchers: mesh construction, multi-pod dry-run, roofline, train/serve
drivers.  NOTE: dryrun must be run as a module entry point (it sets
XLA_FLAGS before importing jax); importing it from an already-initialized
process will not re-seat the device count.
"""

from .mesh import make_debug_mesh, make_production_mesh, mesh_batch_axes

__all__ = ["make_debug_mesh", "make_production_mesh", "mesh_batch_axes"]
