"""Kernel substitution for the roofline memory term.

The dry-run lowers the pure-JAX blocked attention / SSD scan (the Pallas
kernels cannot lower on the CPU host platform).  The op-level HBM traffic
model then charges the scan carries (softmax accumulators, SSD states) a
full HBM round trip per tile step — but on TPU these regions run as the
``repro.kernels`` Pallas kernels, whose carries live in VMEM scratch: their
true HBM traffic is "stream q/k/v once, write out once" (attention) and
"stream x/dA/B/C once, write y once" (SSD).

This module quantifies the gap per cell:

  * the scan implementation is lowered STANDALONE at the cell's per-device
    shard shapes and passed through the same trip-count-aware analyzer —
    so the subtracted traffic is measured by the same model that produced
    the cell totals, not hand-estimated;
  * the kernel's analytic traffic replaces it (fwd: Σ operand+result bytes
    once; train: ×3 for the flash/SSD recompute backward);
  * FLOPs are substituted the same way (the kernel does the same dots, so
    the delta is ≈0 — kept for consistency).

The roofline reports both the raw (XLA-path) and kernel-substituted memory
terms; EXPERIMENTS.md §Perf logs this as iteration I7.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from .hlo_analysis import analyze_hlo

BF16 = 2
F32 = 4


def _tp_split(hq: int, hkv: int, sq: int, tp: int) -> Tuple[int, int, int]:
    """How the model axis divides one attention layer's work per device:
    heads when they divide (Megatron TP), otherwise q rows (SP — GSPMD
    shards tokens and gathers K/V)."""
    if hq % tp == 0:
        hq_l = hq // tp
        # each device's q-head group only touches its own kv heads
        hkv_l = hkv // tp if hkv % tp == 0 else max(1, min(hkv, hq_l))
        return hq_l, hkv_l, sq
    return hq, hkv, max(1, sq // tp)


def _attention_sites(
    cfg: ModelConfig, shape: ShapeConfig, dp: int, tp: int, mb: int
) -> List[Dict]:
    """Per-device attention workloads in this cell (one entry per distinct
    layer geometry; 'count' = how many layers share it)."""
    if shape.kind == "decode":
        return []  # decode attention streams the cache once: model is fair
    b_l = max(1, shape.global_batch // dp) // (mb if shape.kind == "train" else 1)
    b_l = max(1, b_l)
    hd = cfg.head_dim_
    sites = []

    def site(count, sq, skv, causal, window):
        hq_l, hkv_l, sq_l = _tp_split(cfg.n_heads, cfg.n_kv_heads, sq, tp)
        return dict(count=count, b=b_l, sq=sq_l, skv=skv, hq=hq_l,
                    hkv=hkv_l, hd=hd, causal=causal, window=window)

    if cfg.family == "encdec":
        e = cfg.encdec
        sites.append(site(e.n_enc_layers, e.n_frames, e.n_frames, False, None))
        sites.append(site(cfg.n_layers, shape.seq_len, shape.seq_len, True, None))
        sites.append(site(cfg.n_layers, shape.seq_len, e.n_frames, False, None))
        return sites
    kinds = cfg.layer_kinds()
    n_full = sum(1 for k in kinds if k in ("attn", "global", "moe", "shared_attn"))
    n_swa = sum(1 for k in kinds if k in ("swa", "swa_moe"))
    s = shape.seq_len
    if n_full:
        sites.append(site(n_full, s, s, True, None))
    if n_swa:
        sites.append(site(n_swa, s, s, True, cfg.sliding_window))
    return sites


def _ssd_sites(
    cfg: ModelConfig, shape: ShapeConfig, dp: int, mb: int, tp: int = 16
) -> List[Dict]:
    if cfg.ssm is None or shape.kind == "decode":
        return []
    from ..models.mamba2 import mamba_dims

    dims = mamba_dims(cfg)
    b_l = max(1, shape.global_batch // dp) // (mb if shape.kind == "train" else 1)
    b_l = max(1, b_l)
    h = dims["n_heads"]
    h_l = h // tp if h % tp == 0 else h  # SSD heads shard over the TP axis
    n_mamba = sum(1 for k in cfg.layer_kinds() if k == "mamba")
    return [
        dict(count=n_mamba, b=b_l, s=shape.seq_len, h=h_l,
             p=dims["head_dim"], n=dims["d_state"], chunk=cfg.ssm.chunk)
    ]


@functools.lru_cache(maxsize=256)
def _measure_attention(
    b: int, sq: int, skv: int, hq: int, hkv: int, hd: int,
    causal: bool, window: Optional[int], train: bool,
) -> Tuple[float, float]:
    """(hbm_bytes, flops) of the standalone blocked-attention module under
    the same analyzer/traffic model as the full cell."""
    from ..models.blocked_attention import blocked_attention

    q = jax.ShapeDtypeStruct((b, sq, hq, hd), jnp.bfloat16)
    k = jax.ShapeDtypeStruct((b, skv, hkv, hd), jnp.bfloat16)
    v = jax.ShapeDtypeStruct((b, skv, hkv, hd), jnp.bfloat16)
    pq = jax.ShapeDtypeStruct((b, sq), jnp.int32)
    pk = jax.ShapeDtypeStruct((b, skv), jnp.int32)

    def fwd(q, k, v, pq, pk):
        return blocked_attention(q, k, v, pq, pk, causal, window, 1024, False)

    if train:
        def fn(q, k, v, pq, pk):
            return jax.grad(
                lambda q_, k_, v_: (fwd(q_, k_, v_, pq, pk).astype(jnp.float32) ** 2).sum(),
                argnums=(0, 1, 2),
            )(q, k, v)
    else:
        fn = fwd
    hlo = jax.jit(fn).lower(q, k, v, pq, pk).compile().as_text()
    a = analyze_hlo(hlo)
    return a["hbm_bytes"], a["flops"]


@functools.lru_cache(maxsize=64)
def _measure_ssd(
    b: int, s: int, h: int, p: int, n: int, chunk: int, train: bool
) -> Tuple[float, float]:
    from ..models.mamba2 import ssd_chunked

    x = jax.ShapeDtypeStruct((b, s, h, p), jnp.float32)
    da = jax.ShapeDtypeStruct((b, s, h), jnp.float32)
    bb = jax.ShapeDtypeStruct((b, s, h, n), jnp.float32)
    cc = jax.ShapeDtypeStruct((b, s, h, n), jnp.float32)
    q = min(chunk, s)

    def fwd(x, da, bb, cc):
        y, _ = ssd_chunked(x, da, bb, cc, q)
        return y

    if train:
        def fn(x, da, bb, cc):
            return jax.grad(
                lambda x_, b_, c_: (fwd(x_, da, b_, c_) ** 2).sum(),
                argnums=(0, 1, 2),
            )(x, bb, cc)
    else:
        fn = fwd
    hlo = jax.jit(fn).lower(x, da, bb, cc).compile().as_text()
    a = analyze_hlo(hlo)
    return a["hbm_bytes"], a["flops"]


def _attn_kernel_analytic(site: Dict, train: bool) -> Tuple[float, float]:
    """Pallas flash kernel: stream q,k,v once, write o (fwd); backward
    re-reads q,k,v,o,do and writes dq,dk,dv (recompute P in VMEM)."""
    qb = site["b"] * site["sq"] * site["hq"] * site["hd"] * BF16
    kb = site["b"] * site["skv"] * site["hkv"] * site["hd"] * BF16
    io_fwd = qb + 2 * kb + qb  # q + k + v + o
    io = io_fwd * 3 if train else io_fwd
    skv_eff = min(site["skv"], site["window"]) if site["window"] else site["skv"]
    causal_f = 0.5 if site["causal"] and not site["window"] else 1.0
    flops = (
        4.0 * site["b"] * site["hq"] * site["sq"] * skv_eff * site["hd"] * causal_f
    )
    flops = flops * 3.5 if train else flops  # bwd ≈ 2.5× fwd dots
    return io, flops


def _ssd_kernel_analytic(site: Dict, train: bool) -> Tuple[float, float]:
    xb = site["b"] * site["s"] * site["h"] * site["p"] * F32
    bcb = site["b"] * site["s"] * site["h"] * site["n"] * F32
    dab = site["b"] * site["s"] * site["h"] * F32
    io_fwd = 2 * xb + 2 * bcb + dab  # x, y, B, C, dA
    io = io_fwd * 3 if train else io_fwd
    q = min(site["chunk"], site["s"])
    nc = site["s"] // q
    flops = (
        site["b"] * site["h"] * nc
        * (2 * q * q * site["n"] + 2 * q * q * site["p"] + 4 * q * site["p"] * site["n"])
    )
    flops = flops * 3.5 if train else flops
    return io, flops


def substitution_for_cell(
    cfg: ModelConfig, shape: ShapeConfig, dp: int, tp: int, mb: int
) -> Dict:
    """Returns the per-device traffic/flops delta of swapping the lowered
    scan implementations for the Pallas kernels."""
    train = shape.kind == "train"
    sub_bytes = 0.0
    sub_flops = 0.0
    kernel_bytes = 0.0
    kernel_flops = 0.0
    for site in _attention_sites(cfg, shape, dp, tp, mb):
        mult = site["count"] * (mb if train else 1)
        mb_, mf_ = _measure_attention(
            site["b"], site["sq"], site["skv"], site["hq"], site["hkv"],
            site["hd"], site["causal"], site["window"], train,
        )
        kb_, kf_ = _attn_kernel_analytic(site, train)
        sub_bytes += mult * mb_
        sub_flops += mult * mf_
        kernel_bytes += mult * kb_
        kernel_flops += mult * kf_
    for site in _ssd_sites(cfg, shape, dp, mb, tp):
        mult = site["count"] * (mb if train else 1)
        mb_, mf_ = _measure_ssd(
            site["b"], site["s"], site["h"], site["p"], site["n"],
            site["chunk"], train,
        )
        kb_, kf_ = _ssd_kernel_analytic(site, train)
        sub_bytes += mult * mb_
        sub_flops += mult * mf_
        kernel_bytes += mult * kb_
        kernel_flops += mult * kf_
    return {
        "measured_scan_bytes": sub_bytes,
        "measured_scan_flops": sub_flops,
        "kernel_bytes": kernel_bytes,
        "kernel_flops": kernel_flops,
        "bytes_delta": sub_bytes - kernel_bytes,
        "flops_delta": sub_flops - kernel_flops,
    }
