"""Post-SPMD HLO analysis: trip-count-aware FLOPs, HBM traffic, and
collective bytes per mesh axis.

Why not ``compiled.cost_analysis()`` alone?  XLA's cost analysis counts a
``while`` body ONCE — our models scan over layer groups, so its numbers are
low by the trip count (measured: an 8-step scanned matmul reports 1/8 of
the unrolled FLOPs).  This module parses ``compiled.as_text()`` instead:

  * computations are segmented; a call-graph multiplier is propagated
    (while bodies × known_trip_count from backend_config, fallback: the
    largest integer constant in the loop condition; fusions/calls × 1);
  * **FLOPs** = Σ over ``dot`` instructions of 2·|result|·K (K = product of
    lhs contracting dims), × multiplier.  On TPU this is the MXU term —
    elementwise FLOPs are roofline-irrelevant;
  * **HBM bytes** = Σ over top-level instructions of operand+result bytes
    (× multiplier) under an each-op-touches-HBM-once model; slices count
    their result, dynamic-update-slices count 2× the update operand
    (read+write), layout-only ops (tuple/gte/bitcast/parameter) are free.
    This is a *traffic model*, not a simulation — documented in
    EXPERIMENTS.md;
  * **collective bytes** = Σ operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (× multiplier),
    classified per mesh axis by replica-group stride (device layout is
    row-major pod→data→model), so ICI vs DCN traffic separate cleanly.

All shapes in the post-partitioning module are per-device shards, so every
number reported here is **per device**.
"""

from __future__ import annotations

import collections
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+([\w\-]+)"
)
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
_LAYOUT_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota",
}
#: ops the TPU backend fuses into elementwise regions — a maximal connected
#: region reads its external inputs once and writes its outputs once
#: (fusion simulation; the CPU backend leaves these unfused, which would
#: overstate HBM traffic by 2-4x on train graphs)
_ELEMENTWISE = {
    "convert", "multiply", "add", "subtract", "divide", "select",
    "exponential", "exponential-minus-one", "negate", "maximum", "minimum",
    "and", "or", "not", "xor", "compare", "abs", "sqrt", "rsqrt", "power",
    "clamp", "tanh", "logistic", "log", "log-plus-one", "sign", "floor",
    "ceil", "round-nearest-afz", "copy", "broadcast", "transpose",
    "reshape", "bitcast-convert", "reverse", "pad", "real", "imag",
    "is-finite", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "expm1", "cosine", "sine", "atan2",
}


class _UF:
    def __init__(self):
        self.p = {}

    def find(self, x):
        self.p.setdefault(x, x)
        while self.p[x] != x:
            self.p[x] = self.p[self.p[x]]
            x = self.p[x]
        return x

    def union(self, a, b):
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.p[ra] = rb


def shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


# ------------------------------------------------------------------ parsing
def parse_computations(text: str) -> Tuple[Dict[str, List[str]], str]:
    comps: Dict[str, List[str]] = {}
    entry = ""
    current: Optional[str] = None
    for line in text.splitlines():
        m = _HEADER_RE.match(line)
        if m:
            current = m.group(2)
            comps[current] = []
            if m.group(1):
                entry = current
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(line)
    return comps, entry


def _called_edges(line: str) -> List[Tuple[str, str]]:
    """(callee, kind) pairs referenced by one instruction line."""
    edges = []
    m = re.search(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)", line)
    if m:
        edges.append((m.group(1), "while_cond"))
        edges.append((m.group(2), "while_body"))
    for pat in (r"calls=%?([\w.\-]+)", r"to_apply=%?([\w.\-]+)"):
        for name in re.findall(pat, line):
            edges.append((name, "call"))
    m = re.search(r"branches=\{([^}]*)\}", line)
    if m:
        for name in re.findall(r"%?([\w.\-]+)", m.group(1)):
            edges.append((name, "branch"))
    return edges


def _trip_count(line: str, cond_comp: List[str]) -> int:
    m = re.search(r'known_trip_count[^0-9]*(\d+)', line)
    if m:
        return int(m.group(1))
    best = 1
    for cl in cond_comp:
        for c in re.findall(r"constant\((\d+)\)", cl):
            best = max(best, int(c))
    return best


def comp_multipliers(comps: Dict[str, List[str]], entry: str) -> Dict[str, float]:
    """Execution-count multiplier per computation (entry = 1)."""
    mult: Dict[str, float] = collections.defaultdict(float)
    mult[entry] = 1.0
    # fixpoint over the (acyclic) call graph
    for _ in range(64):
        changed = False
        for comp, lines in comps.items():
            base = mult.get(comp, 0.0)
            if base == 0.0:
                continue
            for line in lines:
                for callee, kind in _called_edges(line):
                    if callee not in comps:
                        continue
                    factor = base
                    if kind == "while_body":
                        factor = base * _trip_count(line, comps.get(callee, []))
                    elif kind == "while_cond":
                        factor = base * (_trip_count(line, comps[callee]) + 1)
                    if factor > mult.get(callee, 0.0):
                        mult[callee] = factor
                        changed = True
        if not changed:
            break
    return dict(mult)


def _local_sizes(lines: List[str]) -> Dict[str, Tuple[int, List[int]]]:
    """name → (bytes, dims) for instructions defined in a computation."""
    out = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            name, type_str, _ = m.groups()
            out[name] = (shape_bytes(type_str), _shape_dims(type_str))
    return out


# ------------------------------------------------------------------ analysis
def analyze_hlo(text: str, mesh_shape: Optional[Dict[str, int]] = None) -> Dict:
    """Full per-device analysis: flops, hbm bytes, collective bytes/axis."""
    mesh_shape = mesh_shape or {}
    comps, entry = parse_computations(text)
    mult = comp_multipliers(comps, entry)

    flops = 0.0
    hbm_bytes = 0.0
    coll_op = collections.Counter()
    coll_axis = collections.Counter()
    coll_count = collections.Counter()
    op_hist = collections.Counter()
    # CPU-backend artifact detection: XLA CPU cannot run bf16 dots, so it
    # hoists fp32 copies of whole (stacked) bf16 weight tensors out of the
    # layer scan.  A real TPU (native bf16 MXU) never materializes these.
    # We record their unique footprint so the dry-run can report a
    # TPU-corrected peak alongside the raw host-platform number.
    bf16_param_dims = set()
    for comp, lines in comps.items():
        for line in lines:
            m = _DEF_RE.match(line)
            if m and m.group(3) == "parameter" and m.group(2).startswith("bf16"):
                bf16_param_dims.add(tuple(_shape_dims(m.group(2))))
    upcast_artifacts: Dict[tuple, int] = {}

    for comp, lines in comps.items():
        k = mult.get(comp, 0.0)
        if k == 0.0:
            continue
        sizes = _local_sizes(lines)
        info = {}  # name -> (op, operands, res_bytes, is_root)
        artifact_names = set()
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, type_str, op = m.groups()
            operands = re.findall(r"%([\w.\-]+)", line.split(op, 1)[1])
            info[name] = (
                op,
                [o for o in operands if o != name],
                shape_bytes(type_str),
                line.lstrip().startswith("ROOT"),
            )
            if (
                op == "convert"
                and type_str.startswith("f32")
                and shape_bytes(type_str) > 4 * 1024 * 1024
                and tuple(_shape_dims(type_str)) in bf16_param_dims
            ):
                artifact_names.add(name)
                upcast_artifacts[tuple(_shape_dims(type_str))] = shape_bytes(type_str)
        # ---- fusion simulation over elementwise regions ----
        uf = _UF()
        consumers = {}
        for name, (op, operands, _, _) in info.items():
            for v in operands:
                consumers.setdefault(v, set()).add(name)
            if op in _ELEMENTWISE:
                uf.find(name)
                for v in operands:
                    if v in info and info[v][0] in _ELEMENTWISE:
                        uf.union(name, v)
        regions = {}
        for name, (op, _, _, _) in info.items():
            if op in _ELEMENTWISE:
                regions.setdefault(uf.find(name), set()).add(name)
        region_bytes = 0.0
        for members in regions.values():
            ext_in = set()
            out_b = 0.0
            for u in members:
                _, operands, res_b, is_root = info[u]
                for v in operands:
                    if v not in members:
                        ext_in.add(v)
                cons = consumers.get(u, set())
                if is_root or any(c not in members for c in cons) or not cons:
                    out_b += res_b
            in_b = sum(
                0 if v in artifact_names else sizes.get(v, (0, []))[0]
                for v in ext_in
            )
            region_bytes += in_b + out_b
        hbm_bytes += k * region_bytes
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, type_str, op = m.groups()
            op_hist[op] += 1
            res_bytes = shape_bytes(type_str)
            operand_names = info.get(name, (None, [], 0, False))[1]
            # ---- FLOPs: dot ops ----
            if op == "dot":
                res_dims = _shape_dims(type_str)
                lhs = operand_names[0] if operand_names else None
                lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
                kdim = 1
                if lhs in sizes and lc:
                    lhs_dims = sizes[lhs][1]
                    for d in lc.group(1).split(","):
                        if d:
                            kdim *= lhs_dims[int(d)]
                n = 1
                for d in res_dims:
                    n *= d
                flops += k * 2.0 * n * kdim
            # ---- collective bytes ----
            base_op = None
            for c in _COLLECTIVES:
                if op == c or op.startswith(c + "-"):
                    base_op = c
                    break
            if base_op is not None and not op.endswith("-done"):
                nbytes = sum(sizes.get(nm, (0, []))[0] for nm in operand_names)
                if nbytes == 0:
                    nbytes = res_bytes
                coll_op[base_op] += k * nbytes
                coll_count[base_op] += int(k)
                coll_axis[_group_axis(line, mesh_shape)] += k * nbytes
            # ---- HBM traffic model (elementwise handled by regions) ----
            if op in _LAYOUT_OPS or op in _ELEMENTWISE:
                continue
            if op in ("slice", "dynamic-slice", "gather"):
                hbm_bytes += k * 2 * res_bytes  # read slice + write result
            elif op == "dynamic-update-slice":
                upd = (
                    sizes.get(operand_names[1], (res_bytes, []))[0]
                    if len(operand_names) > 1
                    else res_bytes
                )
                hbm_bytes += k * 2 * upd
            else:
                opb = sum(
                    0 if nm in artifact_names else sizes.get(nm, (0, []))[0]
                    for nm in operand_names
                )
                hbm_bytes += k * (opb + res_bytes)

    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "cpu_upcast_artifact_bytes": sum(upcast_artifacts.values()),
        "collective_bytes": sum(coll_op.values()),
        "collective_per_op": dict(coll_op),
        "collective_per_axis": dict(coll_axis),
        "collective_count": dict(coll_count),
        "op_hist": dict(op_hist.most_common(40)),
        "n_computations": len(comps),
    }


def _axis_of_stride(stride: int, mesh_shape: Dict[str, int]) -> str:
    stride = abs(stride)
    model = mesh_shape.get("model", 1)
    data = mesh_shape.get("data", 1)
    if stride == 1:
        return "model"
    if stride == model:
        return "data"
    if stride == model * data:
        return "pod"
    return f"stride{stride}"


def _group_axis(line: str, mesh_shape: Dict[str, int]) -> str:
    """Classify a collective's mesh axis from its group description.

    Handles: literal replica_groups={{0,1,..},..}, iota replica_groups
    [g,s]<=[n] (optionally transposed T(..)), and collective-permute
    source_target_pairs.
    """
    # iota format: replica_groups=[16,16]<=[256] or [16,16]<=[256]T(1,0)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\](T\(([0-9,]+)\))?", line)
    if m:
        g, s, n, t, perm = m.groups()
        if t and perm and perm.split(",")[0] == "1":
            return _axis_of_stride(int(g), mesh_shape)  # transposed: stride=g
        return _axis_of_stride(1, mesh_shape)  # row-major: consecutive ids
    # collective-permute: source_target_pairs={{0,1},{1,2},...}
    m = re.search(r"source_target_pairs=\{\{(\d+),(\d+)\}", line)
    if m:
        return _axis_of_stride(int(m.group(2)) - int(m.group(1)), mesh_shape)
    # literal groups
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x.strip()]
        if len(ids) < 2:
            return "single"
        return _axis_of_stride(ids[1] - ids[0], mesh_shape)
    return "unknown"


def analyze_collectives(
    hlo_text: str, mesh_shape: Optional[Dict[str, int]] = None
) -> Dict:
    """Back-compat wrapper returning just the collective summary."""
    full = analyze_hlo(hlo_text, mesh_shape)
    return {
        "per_op": full["collective_per_op"],
        "per_axis": full["collective_per_axis"],
        "count": full["collective_count"],
        "total_bytes": full["collective_bytes"],
    }
