import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract the roofline inputs.

The two lines ABOVE this docstring must run before ANY other import — jax
locks the device count at first init (assignment requirement).

Per cell:
  * train_4k / prefill_32k lower ``train_step`` / ``prefill_step``;
    decode_32k / long_500k lower ``serve_step`` with a full-length cache;
  * params/optimizer/batch/cache are ``ShapeDtypeStruct``s with
    NamedShardings from ``repro.distributed.sharding_rules`` — nothing is
    allocated;
  * ``compiled.memory_analysis()`` proves the per-device footprint,
    ``compiled.cost_analysis()`` + the trip-count-aware HLO parse give the
    roofline terms;
  * results land in ``experiments/dryrun/<mesh>/<arch>__<shape>.json``.

Usage:
  python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, cell_is_applicable, get_config, get_shape, list_archs
from ..configs.base import ModelConfig, ShapeConfig
from ..distributed.context import DistContext, distribution
from ..distributed.sharding_rules import (
    batch_specs,
    cache_specs,
    opt_specs,
    param_specs,
)
from ..models import build_model
from ..optim import init_adamw
from ..serving import make_serve_step
from ..training import make_train_step
from .hlo_analysis import analyze_hlo
from .mesh import make_production_mesh, mesh_batch_axes

#: per-(arch, shape) gradient-accumulation / prefill-chunking overrides —
#: the knob the memory term is iterated with (EXPERIMENTS.md §Perf).
#: train cells: gradient-accumulation microbatches; prefill cells: the
#: batch is processed in this many sequential lax.map chunks.
MICROBATCHES: Dict[Tuple[str, str], int] = {
    # train: gradient-accumulation; prefill: sequential batch chunks.
    # Values from the §Perf memory-term iteration (EXPERIMENTS.md).
    ("gemma3-12b", "train_4k"): 2,
    ("granite-34b", "train_4k"): 4,
    ("whisper-large-v3", "train_4k"): 2,
    ("mamba2-370m", "train_4k"): 4,
    ("granite-moe-3b-a800m", "train_4k"): 2,
    ("zamba2-1.2b", "train_4k"): 2,
    ("llava-next-mistral-7b", "train_4k"): 2,
    ("granite-34b", "prefill_32k"): 2,
    ("gemma3-12b", "prefill_32k"): 2,
    ("llava-next-mistral-7b", "prefill_32k"): 2,
    ("qwen3-moe-30b-a3b", "prefill_32k"): 2,
}

OUT_ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _struct(shape, dtype, sharding) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype, sharding=sharding)


def _ns_tree(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _with_shardings(shape_tree, ns_tree):
    return jax.tree.map(
        lambda sd, ns: _struct(sd.shape, sd.dtype, ns), shape_tree, ns_tree
    )


def input_specs(
    arch: str, shape_name: str, mesh, ctx: DistContext
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a cell —
    weak-type-correct, sharded, no device allocation."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    api = build_model(cfg, ep=ctx.model_size)
    spec_dict = api.batch_spec(shape)
    b_specs = batch_specs(spec_dict, ctx)
    return {
        name: _struct(shp, dtype, NamedSharding(mesh, b_specs[name]))
        for name, (shp, dtype) in spec_dict.items()
    }


def _model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS: 6·N·D (train), 2·N·D (prefill), 2·N_active·B (decode)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch  # one token per sequence


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    microbatches: Optional[int] = None,
    save: bool = True,
    hlo_analysis: bool = True,
    impl: str = "ref",
    variant: str = "",
    kv_dtype: str = "bfloat16",
) -> Dict[str, Any]:
    import dataclasses

    cfg = get_config(arch)
    if kv_dtype != "bfloat16":
        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_dtype)
    shape = get_shape(shape_name)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    if variant:
        mesh_name = f"{mesh_name}__{variant}"
    if not cell_is_applicable(cfg, shape):
        result = {
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_name,
            "status": "SKIP",
            "reason": "long_500k requires sub-quadratic attention "
            "(pure full-attention arch; see DESIGN.md §5)",
        }
        if save:
            _save(result, mesh_name, arch, shape_name)
        return result

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = DistContext(mesh=mesh, batch_axes=mesh_batch_axes(mesh))
    api = build_model(cfg, ep=ctx.model_size, impl=impl)
    mb = microbatches or MICROBATCHES.get((arch, shape_name), 1)
    rng = jax.random.PRNGKey(0)

    with distribution(ctx):
        params_shapes = jax.eval_shape(api.init, rng)
        p_spec = param_specs(params_shapes, cfg, ctx)
        p_ns = _ns_tree(mesh, p_spec)
        params_in = _with_shardings(params_shapes, p_ns)
        batch_in = input_specs(arch, shape_name, mesh, ctx)

        if shape.kind == "train":
            opt_shapes = jax.eval_shape(init_adamw, params_shapes)
            o_spec = opt_specs(opt_shapes, p_spec, cfg, ctx)
            o_ns = _ns_tree(mesh, o_spec)
            opt_in = _with_shardings(opt_shapes, o_ns)
            step_fn = make_train_step(api, microbatches=mb)
            out_shapes = jax.eval_shape(step_fn, params_in, opt_in, batch_in)
            metrics_ns = jax.tree.map(
                lambda _: NamedSharding(mesh, P()), out_shapes[2]
            )
            jitted = jax.jit(
                step_fn,
                out_shardings=(p_ns, o_ns, metrics_ns),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_in, opt_in, batch_in)
        elif shape.kind == "prefill":
            def one_chunk(params, chunk):
                if cfg.family == "encdec":
                    logits, _ = api.forward(
                        params, chunk["frames"], chunk["tokens"],
                        remat=False, last_only=True,
                    )
                elif cfg.family == "vlm":
                    logits, _ = api.forward(
                        params, chunk["tokens"],
                        prefix_embeds=chunk["prefix_embeds"],
                        remat=False, last_only=True,
                    )
                else:
                    logits, _ = api.forward(
                        params, chunk["tokens"], remat=False, last_only=True
                    )
                return logits

            # chunking must preserve DP divisibility: a chunk whose batch
            # no longer divides the DP axes would replicate activations
            # across them (measured: 153× FLOPs blowup on multipod MoE)
            bt = ctx.batch_size_total
            b_total = shape.global_batch
            mb_eff = mb
            while mb_eff > 1 and (b_total // mb_eff) % bt != 0:
                mb_eff //= 2

            def prefill_step(params, batch):
                if mb_eff == 1:
                    return one_chunk(params, batch)
                # memory-term lever: process the request batch in ``mb``
                # sequential chunks (live activations shrink by mb)
                chunked = jax.tree.map(
                    lambda x: x.reshape(mb_eff, x.shape[0] // mb_eff, *x.shape[1:]),
                    batch,
                )
                out = jax.lax.map(lambda c: one_chunk(params, c), chunked)
                return out.reshape(-1, *out.shape[2:])

            jitted = jax.jit(prefill_step, donate_argnums=())
            lowered = jitted.lower(params_in, batch_in)
        else:  # decode
            cache_shapes = jax.eval_shape(
                lambda: api.init_cache(shape.global_batch, shape.seq_len)
            )
            c_spec = cache_specs(cache_shapes, cfg, ctx)
            c_ns = _ns_tree(mesh, c_spec)
            cache_in = _with_shardings(cache_shapes, c_ns)
            serve_step = make_serve_step(api)
            tok_ns = batch_in["tokens"].sharding
            pos_in = _struct((), jnp.int32, NamedSharding(mesh, P()))
            jitted = jax.jit(
                serve_step,
                out_shardings=(tok_ns, c_ns),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(
                params_in, cache_in, batch_in["tokens"], pos_in
            )

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    analysis = (
        analyze_hlo(hlo, dict(mesh.shape)) if hlo_analysis else {}
    )
    n_dev = mesh.size
    hbm_per_dev = (
        mem.argument_size_in_bytes
        + mem.temp_size_in_bytes
        + mem.output_size_in_bytes
        - mem.alias_size_in_bytes
    )
    # host-platform bf16→f32 weight-copy artifact (see hlo_analysis):
    # subtract for the TPU-corrected footprint, report both.
    artifact = analysis.get("cpu_upcast_artifact_bytes", 0)
    hbm_corrected = hbm_per_dev - artifact
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "OK",
        "kind": shape.kind,
        "impl": impl,
        "variant": variant,
        "microbatches": mb,
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device": hbm_per_dev,
            "cpu_upcast_artifact_bytes": artifact,
            "peak_per_device_tpu_corrected": hbm_corrected,
            "fits_16GiB": bool(hbm_corrected <= 16 * (1 << 30)),
        },
        "xla_cost_analysis": {
            "flops_per_device_loopbody_once": cost.get("flops", 0.0),
            "bytes_accessed_loopbody_once": cost.get("bytes accessed", 0.0),
        },
        "hlo_analysis": analysis,
        "model_flops_global": _model_flops(cfg, shape),
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "hlo_len_lines": hlo.count("\n"),
    }
    if save:
        _save(result, mesh_name, arch, shape_name)
    return result


def _save(result: Dict, mesh_name: str, arch: str, shape_name: str) -> None:
    out_dir = os.path.join(os.path.abspath(OUT_ROOT), mesh_name)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}.json")
    with open(path, "w") as fh:
        json.dump(result, fh, indent=1)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-hlo", action="store_true", help="skip HLO parse")
    ap.add_argument("--impl", default="ref", help="attention impl: ref|blocked")
    ap.add_argument("--variant", default="", help="artifact subdir suffix")
    ap.add_argument("--kv-dtype", default="bfloat16", help="bfloat16|int8")
    args = ap.parse_args()

    cells = (
        [(a, s) for a in list_archs() for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    failures = 0
    for arch, shape_name in cells:
        mesh_name = "multipod_2x16x16" if args.multi_pod else "pod_16x16"
        if args.variant:
            mesh_name = f"{mesh_name}__{args.variant}"
        path = os.path.join(
            os.path.abspath(OUT_ROOT), mesh_name, f"{arch}__{shape_name}.json"
        )
        if args.skip_existing and os.path.exists(path):
            with open(path) as fh:
                if json.load(fh).get("status") in ("OK", "SKIP"):
                    print(f"[skip] {arch} × {shape_name} ({mesh_name})")
                    continue
        print(f"[cell] {arch} × {shape_name} ({mesh_name}) ...", flush=True)
        try:
            r = run_cell(
                arch,
                shape_name,
                multi_pod=args.multi_pod,
                microbatches=args.microbatches,
                hlo_analysis=not args.no_hlo,
                impl=args.impl,
                variant=args.variant,
                kv_dtype=args.kv_dtype,
            )
            if r["status"] == "OK":
                m = r["memory"]
                print(
                    f"   OK compile={r['compile_s']}s "
                    f"mem/dev={m['peak_per_device']/2**30:.2f}GiB "
                    f"(tpu-corr={m['peak_per_device_tpu_corrected']/2**30:.2f}) "
                    f"fits={m['fits_16GiB']} "
                    f"flops/dev={r['hlo_analysis'].get('flops', 0):.3e} "
                    f"coll/dev={r['hlo_analysis'].get('collective_bytes', 0):.3e}B",
                    flush=True,
                )
            else:
                print(f"   SKIP: {r['reason']}", flush=True)
        except Exception as exc:  # noqa: BLE001
            failures += 1
            print(f"   FAIL: {type(exc).__name__}: {exc}", flush=True)
            traceback.print_exc()
            _save(
                {
                    "arch": arch,
                    "shape": shape_name,
                    "mesh": mesh_name,
                    "status": "FAIL",
                    "error": f"{type(exc).__name__}: {exc}",
                },
                mesh_name,
                arch,
                shape_name,
            )
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
