"""Roofline analysis from dry-run artifacts (single-pod mesh).

Three terms per (arch × shape), all **seconds per step, per device**:

  compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw                (819 GB/s)
  collective = ICI_bytes / ICI_bw + DCN_bytes / DCN_bw
               (ICI ≈ 50 GB/s/link; pod-axis traffic crosses DCN ≈ 25 GB/s)

where HLO_FLOPs / HLO_bytes / collective_bytes come from the trip-count-
aware HLO parse (per-device; see hlo_analysis.py) — NOT from raw
``cost_analysis()``, which undercounts scanned loop bodies.

Reported per cell:
  * the three terms + the dominant one (the bottleneck),
  * MODEL_FLOPS (6·N·D train / 2·N·D prefill / 2·N_active·B decode) and the
    ratio MODEL_FLOPS/HLO_FLOPs — the "useful compute" fraction that
    catches remat/redundancy waste,
  * roofline fraction = (MODEL_FLOPS/dev ÷ peak) / max(terms) — the
    fraction of the modeled step time that is irreducible useful math;
    this is the §Perf score,
  * a one-line lever on the dominant term.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link
DCN_BW = 25e9  # bytes/s/chip-share across pods

DRYRUN_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"
)


def derive_terms(cell: Dict) -> Optional[Dict]:
    if cell.get("status") != "OK":
        return None
    h = cell.get("hlo_analysis") or {}
    n_dev = cell["n_devices"]
    flops_dev = h.get("flops", 0.0)
    bytes_dev = h.get("hbm_bytes", 0.0)
    # kernel substitution (§Perf I7): swap the lowered scan implementations'
    # modeled traffic/flops for the Pallas kernels' (see
    # kernel_substitution.py).  Clamped at 10% of raw as a sanity floor.
    sub = cell.get("kernel_substitution")
    raw_bytes, raw_flops = bytes_dev, flops_dev
    if sub:
        bytes_dev = max(0.1 * raw_bytes, bytes_dev - sub["bytes_delta"])
        flops_dev = max(0.1 * raw_flops, flops_dev - sub["flops_delta"])
    per_axis = h.get("collective_per_axis", {})
    dcn_bytes = per_axis.get("pod", 0.0)
    ici_bytes = sum(v for k, v in per_axis.items() if k != "pod")
    compute_t = flops_dev / PEAK_FLOPS
    memory_t = bytes_dev / HBM_BW
    coll_t = ici_bytes / ICI_BW + dcn_bytes / DCN_BW
    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    dominant = max(terms, key=terms.get)
    model_flops_dev = cell["model_flops_global"] / n_dev
    useful_ratio = model_flops_dev / flops_dev if flops_dev else 0.0
    step_t = max(terms.values()) if any(terms.values()) else float("inf")
    roofline_frac = (model_flops_dev / PEAK_FLOPS) / step_t if step_t else 0.0
    return {
        "arch": cell["arch"],
        "shape": cell["shape"],
        "kind": cell["kind"],
        "microbatches": cell.get("microbatches", 1),
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": coll_t,
        "dominant": dominant,
        "model_flops_dev": model_flops_dev,
        "hlo_flops_dev": flops_dev,
        "raw_bytes_dev": raw_bytes,
        "raw_flops_dev": raw_flops,
        "kernel_substituted": bool(sub),
        "useful_ratio": useful_ratio,
        "roofline_frac": roofline_frac,
        "mem_gib": cell["memory"].get(
            "peak_per_device_tpu_corrected", cell["memory"]["peak_per_device"]
        )
        / 2**30,
        "fits": cell["memory"]["fits_16GiB"],
        "lever": _lever(dominant, cell, terms),
    }


def _lever(dominant: str, cell: Dict, terms: Dict) -> str:
    kind = cell["kind"]
    if dominant == "compute":
        ratio = cell["model_flops_global"] / cell["n_devices"] / max(
            cell["hlo_analysis"].get("flops", 1), 1
        )
        if ratio < 0.6:
            return (
                "compute-bound with low useful ratio — cut recompute "
                "(remat policy: save attention outputs) or masked-block "
                "attention to skip fully-masked tiles"
            )
        return "compute-bound near useful peak — only better kernels help"
    if dominant == "memory":
        if kind == "decode":
            return (
                "decode is KV-cache streaming bound (expected) — shrink "
                "KV dtype (int8), or raise batch to amortize weights"
            )
        return (
            "memory-bound — fuse norms/elementwise (rmsnorm kernel), "
            "increase arithmetic intensity via larger per-device batch, "
            "or drop fp32 intermediates in the SSD/attention path"
        )
    return (
        "collective-bound — re-span collectives (SP all-gathers on ICI), "
        "overlap via latency-hiding scheduler, int8-compress DCN grads, "
        "or shrink TP degree in favor of DP"
    )


def load_cells(mesh_name: str = "pod_16x16") -> List[Dict]:
    out = []
    for path in sorted(
        glob.glob(os.path.join(os.path.abspath(DRYRUN_DIR), mesh_name, "*.json"))
    ):
        with open(path) as fh:
            out.append(json.load(fh))
    return out


_SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def roofline_table(mesh_name: str = "pod_16x16") -> str:
    """Markdown roofline table over all baselined cells."""
    rows = []
    skips = []
    fails = []
    for cell in load_cells(mesh_name):
        if cell["status"] == "SKIP":
            skips.append(cell)
            continue
        if cell["status"] != "OK":
            fails.append(cell)
            continue
        t = derive_terms(cell)
        if t:
            rows.append(t)
    rows.sort(key=lambda r: (r["arch"], _SHAPE_ORDER.get(r["shape"], 9)))
    lines = [
        "| arch | shape | mb | compute s | memory s | collective s | "
        "dominant | useful | roofline | mem GiB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['microbatches']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.2f} "
            f"| {r['mem_gib']:.1f} | {'Y' if r['fits'] else 'N'} |"
        )
    for s in skips:
        lines.append(
            f"| {s['arch']} | {s['shape']} | — | SKIP | | | | | | | |"
        )
    for f in fails:
        lines.append(
            f"| {f['arch']} | {f['shape']} | — | FAIL: "
            f"{f.get('error', '?')[:60]} | | | | | | | |"
        )
    return "\n".join(lines)


def levers_table(mesh_name: str = "pod_16x16") -> str:
    rows = [derive_terms(c) for c in load_cells(mesh_name)]
    rows = [r for r in rows if r]
    rows.sort(key=lambda r: (r["arch"], _SHAPE_ORDER.get(r["shape"], 9)))
    return "\n".join(
        f"- **{r['arch']} × {r['shape']}** ({r['dominant']}-bound): {r['lever']}"
        for r in rows
    )


if __name__ == "__main__":
    print(roofline_table())
    print()
    print(levers_table())
