from .checkpointer import Checkpointer, load_checkpoint_du

__all__ = ["Checkpointer", "load_checkpoint_du"]
