from .checkpointer import (
    Checkpointer,
    CheckpointError,
    CheckpointTimeout,
    checkpoint_files,
    decode_array,
    encode_array,
    flatten_tree,
    load_checkpoint_du,
    unflatten_tree,
)

__all__ = [
    "CheckpointError",
    "CheckpointTimeout",
    "Checkpointer",
    "checkpoint_files",
    "decode_array",
    "encode_array",
    "flatten_tree",
    "load_checkpoint_du",
    "unflatten_tree",
]
