"""Checkpointing as Data-Units.

A checkpoint is an immutable DU whose files are the serialized leaves of
(params, opt_state, step).  That buys, for free, everything the paper's DU
semantics give data:

  * location transparency — restart anywhere the DU has (or can get) a
    replica;
  * replication — group-replicate checkpoints across pods so a pod loss
    does not lose the run (Fig. 8 mechanics applied to model state);
  * affinity scheduling — the workload manager restarts the training CU
    near a checkpoint replica instead of dragging bytes across the DCN;
  * catalog — the coordination store maps ``ckpt:<run>`` to the DU chain.

Leaves are stored whole (single-process container); a multi-host deployment
would store per-shard files keyed by shard index — the DU file namespace
already accommodates that (``leaf/<path>/shard<k>.npy``).

Restore is *resharding*: arrays come back as numpy and are re-placed by
whatever sharding the new mesh prescribes, so restarts may change topology
(elastic restart).
"""

from __future__ import annotations

import io
import json
import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core import DataUnit, DataUnitDescription, PilotData, RuntimeContext, replicate_group


def _flatten(tree: Any, prefix: str = "") -> List[Tuple[str, Any]]:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out.extend(_flatten(tree[k], f"{prefix}{k}/"))
        return out
    return [(prefix.rstrip("/"), tree)]


def _unflatten(items: Dict[str, Any]) -> Any:
    root: Dict[str, Any] = {}
    for path, value in items.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    return root


def _encode(arr) -> bytes:
    buf = io.BytesIO()
    np.save(buf, np.asarray(arr), allow_pickle=False)
    return buf.getvalue()


def _decode(data: bytes) -> np.ndarray:
    return np.load(io.BytesIO(data), allow_pickle=False)


class Checkpointer:
    """Writes/reads checkpoint DUs; optionally async + group-replicated."""

    def __init__(
        self,
        ctx: RuntimeContext,
        run_name: str = "run",
        replicate_to: Optional[List[PilotData]] = None,
    ):
        self.ctx = ctx
        self.run_name = run_name
        self.replicate_to = replicate_to or []
        self._pending: List[threading.Thread] = []

    # ----------------------------------------------------------------- save
    def save(
        self,
        step: int,
        params: Any,
        opt_state: Optional[Any] = None,
        target: Optional[PilotData] = None,
        asynchronous: bool = False,
    ) -> DataUnit:
        du = DataUnit(
            DataUnitDescription(name=f"{self.run_name}.ckpt{step:08d}"),
            self.ctx.store,
        )
        self.ctx.register(du)
        meta = {"step": step, "run": self.run_name}
        du.add_file("meta.json", json.dumps(meta).encode())
        for path, leaf in _flatten({"params": params}):
            du.add_file(f"{path}.npy", _encode(leaf))
        if opt_state is not None:
            for path, leaf in _flatten({"opt": opt_state}):
                du.add_file(f"{path}.npy", _encode(leaf))

        def commit():
            if target is not None:
                self.ctx.transfer_service.ingest(du, target)
                if self.replicate_to:
                    replicate_group(du, target, self.replicate_to, self.ctx)
            du.seal()
            self.ctx.store.hset(f"ckpt:{self.run_name}", f"{step:08d}", du.id)

        if asynchronous:
            t = threading.Thread(target=commit, daemon=True)
            t.start()
            self._pending.append(t)
        else:
            commit()
        return du

    def wait(self, timeout: float = 30.0) -> None:
        for t in self._pending:
            t.join(timeout)
        self._pending = [t for t in self._pending if t.is_alive()]

    # -------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        index = self.ctx.store.hgetall(f"ckpt:{self.run_name}")
        return max((int(k) for k in index), default=None)

    def du_for_step(self, step: int) -> DataUnit:
        du_id = self.ctx.store.hget(f"ckpt:{self.run_name}", f"{step:08d}")
        if du_id is None:
            raise KeyError(f"no checkpoint for step {step}")
        return self.ctx.lookup(du_id)

    def restore(
        self, step: Optional[int] = None, location: Optional[str] = None
    ) -> Tuple[int, Any, Optional[Any]]:
        """Returns (step, params, opt_state) read from the nearest replica."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise KeyError(f"run {self.run_name!r} has no checkpoints")
        du = self.du_for_step(step)
        return load_checkpoint_du(self.ctx, du, location=location)


def load_checkpoint_du(
    ctx: RuntimeContext, du: DataUnit, location: Optional[str] = None
) -> Tuple[int, Any, Optional[Any]]:
    """Read a checkpoint DU (via the cheapest replica when location given)."""
    pd = None
    if du.locations:
        if location is not None and ctx.transfer_service is not None:
            pd, _ = ctx.transfer_service.resolve_access(du, location)
        if pd is None:
            pd = ctx.lookup(du.locations[0])

    def read(rel: str) -> bytes:
        return pd.fetch_du_file(du.id, rel) if pd is not None else du.read(rel)

    meta = json.loads(read("meta.json"))
    params_items, opt_items = {}, {}
    for rel in du.manifest:
        if not rel.endswith(".npy"):
            continue
        key = rel[: -len(".npy")]
        if key.startswith("params/"):
            params_items[key[len("params/") :]] = _decode(read(rel))
        elif key.startswith("opt/"):
            opt_items[key[len("opt/") :]] = _decode(read(rel))
    params = _unflatten(params_items)
    opt = _unflatten(opt_items) if opt_items else None
    return meta["step"], params, opt
