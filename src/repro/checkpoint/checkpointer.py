"""Checkpointing as Data-Units.

A checkpoint is an immutable DU whose files are the serialized leaves of
(params, opt_state, step).  That buys, for free, everything the paper's DU
semantics give data:

  * location transparency — restart anywhere the DU has (or can get) a
    replica;
  * replication — the DU carries a ``replication_factor``; sealing it
    hands dispersal and post-failure healing to the runtime's
    ReplicaManager/FaultManager (failure-domain-aware, chunk-striped),
    so a pod loss does not lose the run and NO checkpoint-layer code is
    involved in recovery;
  * affinity scheduling — the workload manager restarts the training CU
    near a checkpoint replica instead of dragging bytes across the DCN;
  * catalog — the coordination store maps ``ckpt:<run>`` to the DU chain.

Replication-factor enforcement requires the self-healing pipeline
(``enable_fault_manager=True`` on the Session/PilotManager); without it a
checkpoint still seals and restores, but keeps a single replica.

Leaves are stored whole (single-process container); a multi-host deployment
would store per-shard files keyed by shard index — the DU file namespace
already accommodates that (``leaf/<path>/shard<k>.npy``).

Restore is *resharding*: arrays come back as numpy and are re-placed by
whatever sharding the new mesh prescribes, so restarts may change topology
(elastic restart).
"""

from __future__ import annotations

import io
import json
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import wait as futures_wait
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core import DataUnit, DataUnitDescription, DUState, RuntimeContext


class CheckpointError(RuntimeError):
    """An asynchronous checkpoint commit failed."""


class CheckpointTimeout(CheckpointError, TimeoutError):
    """``wait()`` deadline elapsed with commits still in flight."""


def flatten_tree(tree: Any, prefix: str = "") -> List[Tuple[str, Any]]:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out.extend(flatten_tree(tree[k], f"{prefix}{k}/"))
        return out
    return [(prefix.rstrip("/"), tree)]


def unflatten_tree(items: Dict[str, Any]) -> Any:
    root: Dict[str, Any] = {}
    for path, value in items.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    return root


def encode_array(arr) -> bytes:
    buf = io.BytesIO()
    np.save(buf, np.asarray(arr), allow_pickle=False)
    return buf.getvalue()


def decode_array(data: bytes) -> np.ndarray:
    return np.load(io.BytesIO(data), allow_pickle=False)


def checkpoint_files(
    step: int, run_name: str, params: Any, opt_state: Optional[Any] = None
) -> Dict[str, bytes]:
    """Serialize (step, params, opt_state) into a checkpoint DU file-set."""
    files = {"meta.json": json.dumps({"step": step, "run": run_name}).encode()}
    for path, leaf in flatten_tree({"params": params}):
        files[f"{path}.npy"] = encode_array(leaf)
    if opt_state is not None:
        for path, leaf in flatten_tree({"opt": opt_state}):
            files[f"{path}.npy"] = encode_array(leaf)
    return files


class Checkpointer:
    """Writes/reads checkpoint DUs; replication rides the runtime.

    Attach to a :class:`~repro.core.session.Session` (or a PilotManager —
    anything with ``.ctx``/``.cds``); a bare :class:`RuntimeContext` also
    works but then every ``save`` needs an explicit ``target``.

    Asynchronous commits run on ONE background executor (not a thread per
    save) and their failures are never swallowed: the next ``save()``
    re-raises a completed commit's error, and :meth:`wait` raises — a
    :class:`CheckpointError` for failed commits, :class:`CheckpointTimeout`
    when the deadline elapses with commits still in flight.
    """

    def __init__(
        self,
        runtime: Any,
        run_name: str = "run",
        replication_factor: int = 1,
    ):
        self.ctx: RuntimeContext = getattr(runtime, "ctx", runtime)
        self.cds = getattr(runtime, "cds", None)
        self.run_name = run_name
        self.replication_factor = replication_factor
        self._pool: Optional[ThreadPoolExecutor] = None
        self._pending: List[Future] = []

    # ----------------------------------------------------------------- save
    def _commit(self, du: DataUnit, step: int, target) -> DataUnit:
        pd = target
        if pd is None and self.cds is not None:
            pd = self.cds.choose_pilot_data(du.description)
        if pd is None:
            raise CheckpointError(
                f"{self.run_name} step {step}: no Pilot-Data target "
                f"(start one, or pass target=)"
            )
        self.ctx.store.hset(f"du:{du.id}", "state", DUState.PENDING)
        self.ctx.transfer_service.ingest(du, pd)
        # Sealing publishes the immutable manifest; with the fault manager
        # enabled the ReplicaManager now disperses the DU to its declared
        # replication_factor across failure domains — off this thread.
        du.seal()
        self.ctx.store.hset(f"ckpt:{self.run_name}", f"{step:08d}", du.id)
        return du

    def save(
        self,
        step: int,
        params: Any,
        opt_state: Optional[Any] = None,
        target=None,
        asynchronous: bool = False,
    ) -> DataUnit:
        self.check()  # surface any completed async commit's failure NOW
        desc = DataUnitDescription(
            name=f"{self.run_name}.ckpt{step:08d}",
            files=checkpoint_files(step, self.run_name, params, opt_state),
            replication_factor=self.replication_factor,
        )
        du = DataUnit(desc, self.ctx.store)
        self.ctx.register(du)
        if asynchronous:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="ckpt-commit"
                )
            self._pending.append(self._pool.submit(self._commit, du, step, target))
        else:
            self._commit(du, step, target)
        return du

    def check(self) -> None:
        """Re-raise the first failure among *completed* async commits
        (commits still running are left pending)."""
        still, failed = [], []
        for fut in self._pending:
            if not fut.done():
                still.append(fut)
            elif fut.exception() is not None:
                failed.append(fut.exception())
        self._pending = still
        if failed:
            raise CheckpointError(
                f"{self.run_name}: async checkpoint commit failed: "
                f"{failed[0]}"
            ) from failed[0]

    def wait(self, timeout: float = 30.0) -> None:
        """Block until every pending async commit settles.

        Raises :class:`CheckpointError` if any commit failed and
        :class:`CheckpointTimeout` if the deadline elapses first (the
        unfinished commits stay pending for a later ``wait``)."""
        pending, self._pending = self._pending, []
        done, not_done = futures_wait(pending, timeout=timeout)
        failed = [f.exception() for f in done if f.exception() is not None]
        self._pending = list(not_done)
        if failed:
            raise CheckpointError(
                f"{self.run_name}: async checkpoint commit failed: "
                f"{failed[0]}"
            ) from failed[0]
        if not_done:
            raise CheckpointTimeout(
                f"{self.run_name}: {len(not_done)} checkpoint commit(s) "
                f"still in flight after {timeout}s"
            )

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    # -------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        index = self.ctx.store.hgetall(f"ckpt:{self.run_name}")
        return max((int(k) for k in index), default=None)

    def du_for_step(self, step: int) -> DataUnit:
        du_id = self.ctx.store.hget(f"ckpt:{self.run_name}", f"{step:08d}")
        if du_id is None:
            raise KeyError(f"no checkpoint for step {step}")
        return self.ctx.lookup(du_id)

    def restore(
        self, step: Optional[int] = None, location: Optional[str] = None
    ) -> Tuple[int, Any, Optional[Any]]:
        """Returns (step, params, opt_state) read from the nearest replica."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise KeyError(f"run {self.run_name!r} has no checkpoints")
        du = self.du_for_step(step)
        return load_checkpoint_du(self.ctx, du, location=location)


def load_checkpoint_du(
    ctx: RuntimeContext, du: DataUnit, location: Optional[str] = None
) -> Tuple[int, Any, Optional[Any]]:
    """Read a checkpoint DU (via the cheapest replica when location given)."""
    pd = None
    if du.locations:
        if location is not None and ctx.transfer_service is not None:
            pd, _ = ctx.transfer_service.resolve_access(du, location)
        if pd is None:
            pd = ctx.lookup(du.locations[0])

    def read(rel: str) -> bytes:
        return pd.fetch_du_file(du.id, rel) if pd is not None else du.read(rel)

    meta = json.loads(read("meta.json"))
    params_items, opt_items = {}, {}
    for rel in du.manifest:
        if not rel.endswith(".npy"):
            continue
        key = rel[: -len(".npy")]
        if key.startswith("params/"):
            params_items[key[len("params/") :]] = decode_array(read(rel))
        elif key.startswith("opt/"):
            opt_items[key[len("opt/") :]] = decode_array(read(rel))
    params = unflatten_tree(params_items)
    opt = unflatten_tree(opt_items) if opt_items else None
    return meta["step"], params, opt
