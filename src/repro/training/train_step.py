"""Train-step factory: loss → grads → clip → AdamW, with optional
microbatch gradient accumulation (scanned, so the HLO stays compact and the
live activation set is one microbatch).
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..models.registry import ModelApi
from ..optim import AdamWConfig, adamw_update, clip_by_global_norm
from ..optim.schedules import warmup_cosine


def make_train_step(
    api: ModelApi,
    opt_cfg: AdamWConfig = AdamWConfig(),
    peak_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10_000,
    max_grad_norm: float = 1.0,
    microbatches: int = 1,
    remat: bool = True,
    accum_dtype: Optional[Any] = None,
) -> Callable:
    """Returns train_step(params, opt_state, batch) → (params, opt_state,
    metrics).  ``batch`` leaves have the GLOBAL batch leading dim; with
    microbatches > 1 it must divide evenly."""

    def loss_of(params, mb):
        loss, metrics = api.loss_fn(params, mb, remat=remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def accumulate(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def to_micro(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        micro = jax.tree.map(to_micro, batch)

        def body(carry, mb):
            acc_grads, acc_loss = carry
            (loss, metrics), grads = grad_fn(params, mb)
            acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
            return (acc_grads, acc_loss + loss), metrics

        # accumulate in fp32 by default; param-dtype (bf16) accumulation
        # halves the accumulator footprint — the fp32 optimizer masters
        # still absorb rounding across steps (§Perf memory lever)
        adt = accum_dtype or jnp.float32
        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, adt), params
        )
        (grads, loss_sum), metrics = jax.lax.scan(
            body, (zero_grads, jnp.zeros((), jnp.float32)), micro
        )
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        last_metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / microbatches, last_metrics, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = accumulate(params, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = warmup_cosine(opt_state["step"], peak_lr, warmup_steps, total_steps)
        params, opt_state = adamw_update(grads, opt_state, params, lr, opt_cfg)
        metrics = dict(metrics)
        metrics.update({"grad_norm": gnorm, "lr": lr, "loss": loss})
        return params, opt_state, metrics

    return train_step


def make_eval_step(api: ModelApi) -> Callable:
    def eval_step(params, batch):
        loss, metrics = api.loss_fn(params, batch, remat=False)
        return metrics

    return eval_step
